"""Deterministic synthetic analogues of the GAP benchmark graphs.

The paper evaluates on the five GAP graphs (Table II): Kron, Urand, Road,
Twitter, Web — up to 4.2 B edges.  This container is laptop-scale, so we
generate topology-faithful synthetic stand-ins that preserve the properties
the paper's analysis hinges on:

* ``kron``    — RMAT/Kronecker, scale-free, *long-range* connections spread
  across the vertex id space (diffuse Fig-5 access matrix).
* ``urand``   — uniform random (Erdős–Rényi-ish), low diameter, no locality.
* ``road``    — 2-D grid mesh: tiny average degree, huge diameter (slow
  information transfer — the paper's explanation for Road's SSSP behaviour).
* ``twitter`` — power-law in-degree (Zipf popularity), asymmetric.
* ``web``     — block-diagonal clustered power-law: ~95 % of edges stay inside
  a contiguous vertex cluster, reproducing the diagonal-clustered access
  matrix of Fig 5 (the topology for which the paper shows delaying does NOT
  help).

All generators are deterministic in ``(name, scale, seed)``.
"""

from __future__ import annotations

import numpy as np

from repro.graphs.formats import CSRGraph

__all__ = ["make_graph", "GRAPH_GENERATORS", "pagerank_values", "sssp_values"]


def _dedup(n: int, src: np.ndarray, dst: np.ndarray):
    keep = src != dst  # drop self loops
    src, dst = src[keep], dst[keep]
    key = src.astype(np.int64) * n + dst
    key = np.unique(key)
    return key // n, key % n


def kron(scale: int, efactor: int = 16, seed: int = 7):
    """RMAT with GAP parameters (A=.57, B=.19, C=.19)."""
    n = 1 << scale
    m = n * efactor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    a, b, c = 0.57, 0.19, 0.19
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        src_bit = (r >= ab).astype(np.int64)
        dst_bit = (((r >= a) & (r < ab)) | (r >= abc)).astype(np.int64)
        src |= src_bit << bit
        dst |= dst_bit << bit
    # GAP permutes vertex ids so degree is not correlated with id.
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    src, dst = _dedup(n, src, dst)
    # symmetrize (GAP kron is undirected)
    src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    src, dst = _dedup(n, src, dst)
    return n, src, dst


def urand(scale: int, efactor: int = 16, seed: int = 11):
    n = 1 << scale
    m = n * efactor
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    src, dst = _dedup(n, src, dst)
    src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    src, dst = _dedup(n, src, dst)
    return n, src, dst


def road(scale: int, efactor: int = 0, seed: int = 0):
    """2-D grid mesh (row-major ids): degree ≤ 4, diameter 2·side."""
    side = int(np.sqrt(1 << scale))
    n = side * side
    ids = np.arange(n).reshape(side, side)
    right = np.stack([ids[:, :-1].ravel(), ids[:, 1:].ravel()])
    down = np.stack([ids[:-1, :].ravel(), ids[1:, :].ravel()])
    src = np.concatenate([right[0], down[0]])
    dst = np.concatenate([right[1], down[1]])
    src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    return n, src, dst


def twitter(scale: int, efactor: int = 16, seed: int = 13):
    """Asymmetric power-law: destinations drawn uniformly, sources Zipf."""
    n = 1 << scale
    m = n * efactor
    rng = np.random.default_rng(seed)
    # Zipf-ranked popularity for in-degree (celebrities get followed).
    ranks = rng.permutation(n)
    popularity = 1.0 / (1.0 + ranks.astype(np.float64))
    popularity /= popularity.sum()
    src = rng.choice(n, size=m, p=popularity)
    dst = rng.integers(0, n, m)
    src, dst = _dedup(n, src, dst)
    return n, src, dst


def web(scale: int, efactor: int = 16, seed: int = 17, locality: float = 0.95):
    """Clustered power-law: contiguous clusters, ~95 % intra-cluster edges.

    Vertex ids are laid out so clusters are contiguous — a blocked contiguous
    partition then assigns a cluster (mostly) to one worker, which reproduces
    the diagonal-dominant access matrix the paper reports for Web (Fig 5).
    """
    n = 1 << scale
    m = n * efactor
    rng = np.random.default_rng(seed)
    n_clusters = max(int(np.sqrt(n) / 4), 8)
    bounds = np.linspace(0, n, n_clusters + 1).astype(np.int64)
    sizes = np.diff(bounds)
    # pick a cluster per edge, weighted by size
    cl = rng.choice(n_clusters, size=m, p=sizes / sizes.sum())
    lo, width = bounds[cl], sizes[cl]
    u = lo + (rng.random(m) ** 2 * width).astype(np.int64)  # skewed in-cluster
    intra = rng.random(m) < locality
    v_in = lo + (rng.random(m) * width).astype(np.int64)
    v_out = rng.integers(0, n, m)
    v = np.where(intra, v_in, v_out)
    src, dst = _dedup(n, u, v)
    return n, src, dst


GRAPH_GENERATORS = {
    "kron": kron,
    "urand": urand,
    "road": road,
    "twitter": twitter,
    "web": web,
}


def pagerank_values(n: int, src: np.ndarray, damping: float = 0.85) -> np.ndarray:
    """Pull edge value for PR: damping / outdeg(src)."""
    outdeg = np.zeros(n, dtype=np.int64)
    np.add.at(outdeg, src, 1)
    return (damping / np.maximum(outdeg[src], 1)).astype(np.float32)


def sssp_values(src: np.ndarray, seed: int = 23) -> np.ndarray:
    """Positive integer weights in [1, 255], as in GAP SSSP inputs."""
    rng = np.random.default_rng(seed)
    return rng.integers(1, 256, size=src.shape[0]).astype(np.int32)


def make_graph(
    name: str,
    scale: int = 14,
    efactor: int = 16,
    seed: int | None = None,
    kind: str = "pagerank",
    damping: float = 0.85,
) -> CSRGraph:
    """Build a named synthetic graph with edge values for ``kind``.

    ``kind``: ``pagerank`` (values = damping/outdeg) | ``sssp`` (int weights)
    | ``unit`` (all-ones).
    """
    gen = GRAPH_GENERATORS[name]
    kwargs = {} if seed is None else {"seed": seed}
    if name == "road":
        n, src, dst = gen(scale, **kwargs)
    else:
        n, src, dst = gen(scale, efactor, **kwargs)
    if kind == "pagerank":
        values = pagerank_values(n, src, damping)
    elif kind == "sssp":
        values = sssp_values(src)
    elif kind == "unit":
        values = np.ones(src.shape[0], dtype=np.float32)
    else:
        raise ValueError(f"unknown kind {kind!r}")
    return CSRGraph.from_edges(
        n, src, dst, values, name=f"{name}-s{scale}", dedup=False
    )
