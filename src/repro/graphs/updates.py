"""Typed edge-update batches for evolving graphs (numpy only, no jax).

An :class:`EdgeBatch` is the mutation unit of ``repro.evolve``: a set of
edge **inserts**, **deletes**, and **reweights**, all expressed against the
*pre-batch* graph and applied atomically by
:meth:`repro.graphs.formats.CSRGraph.apply_updates`.  Application is strict —
inserting an edge that exists, or deleting/reweighting one that doesn't, is a
``ValueError`` (silent upserts would hide producer bugs and make the inverse
batch ill-defined) — and incremental: the CSR is rebuilt by merging the kept
edge list with the sorted inserts, never by re-sorting from a raw edge list.

The returned :class:`UpdateReport` carries the **affected-vertex frontier**
(every destination row whose in-edge list changed — what schedule-stripe
invalidation and warm-restart repair key off) plus the displaced old values,
so ``batch.inverse(report)`` is the exact undo batch.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["EdgeBatch", "UpdateReport", "apply_edge_batch"]


def _as_edge_arrays(pairs, n_vals: int | None):
    """Normalize ``[(src, dst[, val]), ...]`` into flat int64/value arrays."""
    src = np.asarray([p[0] for p in pairs], dtype=np.int64)
    dst = np.asarray([p[1] for p in pairs], dtype=np.int64)
    if n_vals is None:
        return src, dst, None
    val = np.asarray([p[2] for p in pairs])
    return src, dst, val


@dataclasses.dataclass(frozen=True)
class EdgeBatch:
    """One atomic set of edge mutations against a :class:`CSRGraph`.

    All six arrays are host-side; ``insert_val`` may be ``None`` (defaults to
    ones in the graph's value dtype).  A single ``(src, dst)`` pair may appear
    in **at most one** operation across the whole batch — mixed semantics
    (delete *and* insert the same edge to "move" its weight) must be expressed
    as a reweight, otherwise apply order would be ambiguous.
    """

    insert_src: np.ndarray
    insert_dst: np.ndarray
    insert_val: np.ndarray | None
    delete_src: np.ndarray
    delete_dst: np.ndarray
    reweight_src: np.ndarray
    reweight_dst: np.ndarray
    reweight_val: np.ndarray

    @classmethod
    def from_ops(cls, inserts=(), deletes=(), reweights=()) -> "EdgeBatch":
        """Build from op lists: ``inserts``/``reweights`` are ``(src, dst,
        val)`` triples, ``deletes`` are ``(src, dst)`` pairs."""
        ins_s, ins_d, ins_v = _as_edge_arrays(inserts, 3)
        del_s, del_d, _ = _as_edge_arrays(deletes, None)
        rw_s, rw_d, rw_v = _as_edge_arrays(reweights, 3)
        return cls(
            insert_src=ins_s,
            insert_dst=ins_d,
            insert_val=ins_v,
            delete_src=del_s,
            delete_dst=del_d,
            reweight_src=rw_s,
            reweight_dst=rw_d,
            reweight_val=rw_v,
        )

    @property
    def n_inserts(self) -> int:
        return int(self.insert_src.shape[0])

    @property
    def n_deletes(self) -> int:
        return int(self.delete_src.shape[0])

    @property
    def n_reweights(self) -> int:
        return int(self.reweight_src.shape[0])

    @property
    def size(self) -> int:
        """Total edge operations in the batch."""
        return self.n_inserts + self.n_deletes + self.n_reweights

    def all_vertices(self) -> np.ndarray:
        """Every vertex id the batch mentions (validation / quota checks)."""
        return np.concatenate(
            [
                self.insert_src,
                self.insert_dst,
                self.delete_src,
                self.delete_dst,
                self.reweight_src,
                self.reweight_dst,
            ]
        )

    def inverse(self, report: "UpdateReport") -> "EdgeBatch":
        """The exact undo batch, given the report from applying this one.

        Applying ``batch`` then ``batch.inverse(report)`` restores the
        original graph bit-identically (CSR order is canonical, so the
        round-trip is an array-equality check, not a set check).
        """
        return EdgeBatch(
            insert_src=self.delete_src,
            insert_dst=self.delete_dst,
            insert_val=report.deleted_values,
            delete_src=self.insert_src,
            delete_dst=self.insert_dst,
            reweight_src=self.reweight_src,
            reweight_dst=self.reweight_dst,
            reweight_val=report.reweight_old_values,
        )


@dataclasses.dataclass(frozen=True)
class UpdateReport:
    """What one applied :class:`EdgeBatch` changed.

    ``affected_rows`` is the sorted-unique set of destination vertices whose
    in-edge list changed in topology **or** value — the invalidation frontier
    for schedule stripes (rows live in worker blocks) and the seed set for
    min-plus label repair.  ``deleted_values`` / ``reweight_old_values`` are
    aligned to the batch's delete / reweight entries (they make
    :meth:`EdgeBatch.inverse` exact).
    """

    inserted: int
    deleted: int
    reweighted: int
    affected_rows: np.ndarray  # sorted unique int64 destination rows
    deleted_values: np.ndarray
    reweight_old_values: np.ndarray

    @property
    def size(self) -> int:
        return self.inserted + self.deleted + self.reweighted


def _edge_positions(keys: np.ndarray, src, dst, n: int, kind: str) -> np.ndarray:
    """Positions of ``(src, dst)`` in the sorted edge-key array, or raise."""
    want = dst * n + src
    if keys.shape[0] == 0:
        if want.shape[0]:
            raise ValueError(
                f"{kind} of missing edge ({int(src[0])} -> {int(dst[0])})"
            )
        return np.zeros(0, dtype=np.int64)
    pos = np.searchsorted(keys, want)
    ok = (pos < keys.shape[0]) & (keys[np.minimum(pos, keys.shape[0] - 1)] == want)
    if not ok.all():
        i = int(np.nonzero(~ok)[0][0])
        raise ValueError(
            f"{kind} of missing edge ({int(src[i])} -> {int(dst[i])})"
        )
    return pos


def apply_edge_batch(graph, batch: EdgeBatch):
    """Apply ``batch`` to ``graph``; return ``(new_graph, UpdateReport)``.

    Strict semantics (each violation is a ``ValueError``): inserts require the
    edge absent, deletes/reweights require it present, every vertex id must be
    in ``[0, n)``, and no ``(src, dst)`` pair may appear twice in the batch.
    The rebuild is incremental — kept edges are copied in their canonical
    order and sorted inserts are merged in, so the output CSR is bit-identical
    to ``CSRGraph.from_edges`` on the mutated edge list.
    """
    n = graph.n
    verts = batch.all_vertices()
    if verts.size and (verts.min() < 0 or verts.max() >= n):
        raise ValueError(f"edge endpoint out of range [0, {n})")

    op_keys = np.concatenate(
        [
            batch.insert_dst * n + batch.insert_src,
            batch.delete_dst * n + batch.delete_src,
            batch.reweight_dst * n + batch.reweight_src,
        ]
    )
    if np.unique(op_keys).shape[0] != op_keys.shape[0]:
        raise ValueError("duplicate (src, dst) across the batch's operations")

    dst_of_edge = np.repeat(
        np.arange(n, dtype=np.int64), np.diff(graph.indptr)
    )
    keys = dst_of_edge * n + graph.indices.astype(np.int64)

    del_pos = _edge_positions(keys, batch.delete_src, batch.delete_dst, n, "delete")
    rw_pos = _edge_positions(
        keys, batch.reweight_src, batch.reweight_dst, n, "reweight"
    )

    ins_keys = batch.insert_dst * n + batch.insert_src
    if keys.shape[0]:
        ins_pos = np.searchsorted(keys, ins_keys)
        present = (ins_pos < keys.shape[0]) & (
            keys[np.minimum(ins_pos, keys.shape[0] - 1)] == ins_keys
        )
    else:
        present = np.zeros(ins_keys.shape[0], dtype=bool)
    if present.any():
        i = int(np.nonzero(present)[0][0])
        raise ValueError(
            f"insert of existing edge "
            f"({int(batch.insert_src[i])} -> {int(batch.insert_dst[i])})"
        )

    deleted_values = graph.values[del_pos].copy()
    reweight_old = graph.values[rw_pos].copy()

    new_values = graph.values.copy()
    new_values[rw_pos] = np.asarray(batch.reweight_val, dtype=new_values.dtype)
    keep = np.ones(keys.shape[0], dtype=bool)
    keep[del_pos] = False

    ins_val = batch.insert_val
    if ins_val is None:
        ins_val = np.ones(batch.n_inserts, dtype=graph.values.dtype)
    ins_order = np.argsort(ins_keys, kind="stable")

    kept_keys = keys[keep]
    merged_keys = np.concatenate([kept_keys, ins_keys[ins_order]])
    merged_src = np.concatenate(
        [graph.indices[keep], batch.insert_src[ins_order].astype(np.int32)]
    )
    merged_val = np.concatenate(
        [new_values[keep], np.asarray(ins_val, dtype=new_values.dtype)[ins_order]]
    )
    order = np.argsort(merged_keys, kind="stable")

    new_dst = merged_keys[order] // n
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, new_dst + 1, 1)
    np.cumsum(indptr, out=indptr)

    new_graph = dataclasses.replace(
        graph,
        indptr=indptr,
        indices=merged_src[order],
        values=merged_val[order],
    )
    affected = np.unique(
        np.concatenate([batch.insert_dst, batch.delete_dst, batch.reweight_dst])
    )
    report = UpdateReport(
        inserted=batch.n_inserts,
        deleted=batch.n_deletes,
        reweighted=batch.n_reweights,
        affected_rows=affected,
        deleted_values=deleted_values,
        reweight_old_values=reweight_old,
    )
    return new_graph, report
