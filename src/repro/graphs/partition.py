"""Static work partitioning (paper §III-A, "Blocked partitioning of work").

Vertices are assigned to workers in contiguous blocks by vertex id, sized so
the aggregate number of in-neighbours per worker is as balanced as possible.
The partition is static across all rounds, exactly as in the paper.
"""

from __future__ import annotations

import numpy as np

from repro.graphs.formats import CSRGraph

__all__ = ["balanced_blocks", "equal_blocks"]


def equal_blocks(n: int, P: int) -> np.ndarray:
    """Equal vertex-count contiguous blocks: bounds of shape (P + 1,)."""
    return np.linspace(0, n, P + 1).astype(np.int64)


def balanced_blocks(graph: CSRGraph, P: int) -> np.ndarray:
    """Contiguous blocks balancing aggregate in-degree (paper's policy).

    Greedy prefix-sum split: cut points at multiples of nnz / P in the
    cumulative in-degree.  Returns bounds of shape (P + 1,).
    """
    cum = graph.indptr  # cumulative in-degree by construction
    total = cum[-1]
    targets = (np.arange(1, P) * total) // P
    cuts = np.searchsorted(cum, targets, side="left")
    bounds = np.concatenate([[0], cuts, [graph.n]]).astype(np.int64)
    # Guarantee monotonicity (degenerate graphs can collapse cuts).
    bounds = np.maximum.accumulate(bounds)
    return bounds
