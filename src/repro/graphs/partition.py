"""Static work partitioning (paper §III-A, "Blocked partitioning of work").

Vertices are assigned to workers in contiguous blocks by vertex id, sized so
the aggregate number of in-neighbours per worker is as balanced as possible.
The partition is static across all rounds, exactly as in the paper.

Beyond the raw block bounds, :class:`Partition` materializes everything the
distribution layer needs to go from a *replicated* frontier to an
*owner-computes* one: the owner map, local↔global index maps, per-shard halo
in/out sets (the cut-edge endpoints a shard reads from / publishes to remote
shards), and edge-cut statistics.  ``repro.dist.engine_sharded`` builds its
per-commit-step halo-exchange plan on top of these sets; the Fig-5/Table-II
benchmarks report the same numbers to quantify the paper's "clustered on the
main diagonal" insight.
"""

from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np

from repro.graphs.formats import CSRGraph

__all__ = [
    "Partition",
    "PARTITION_METHODS",
    "balanced_blocks",
    "equal_blocks",
    "greedy_degree_blocks",
    "make_partition",
    "refine_blocks",
]


def equal_blocks(n: int, P: int) -> np.ndarray:
    """Equal vertex-count contiguous blocks: bounds of shape (P + 1,)."""
    return np.linspace(0, n, P + 1).astype(np.int64)


def balanced_blocks(graph: CSRGraph, P: int) -> np.ndarray:
    """Contiguous blocks balancing aggregate in-degree (paper's policy).

    Greedy prefix-sum split: cut points at multiples of nnz / P in the
    cumulative in-degree.  Returns bounds of shape (P + 1,).
    """
    cum = graph.indptr  # cumulative in-degree by construction
    total = cum[-1]
    targets = (np.arange(1, P) * total) // P
    cuts = np.searchsorted(cum, targets, side="left")
    bounds = np.concatenate([[0], cuts, [graph.n]]).astype(np.int64)
    # Guarantee monotonicity (degenerate graphs can collapse cuts).
    bounds = np.maximum.accumulate(bounds)
    return bounds


def greedy_degree_blocks(graph: CSRGraph, P: int, alpha: float = 0.5) -> np.ndarray:
    """Degree-aware greedy contiguous blocks: bounds of shape (P + 1,).

    Balances per-vertex cost ``in_degree + alpha · out_degree`` — in-degree is
    the pull-update compute a block owns, out-degree is how often its values
    are read (and therefore shipped) by other blocks.  Unlike
    :func:`balanced_blocks`' fixed prefix targets, each cut re-targets the
    *remaining* cost over the *remaining* blocks, so one hub vertex inflates
    only its own block instead of skewing every later cut.
    """
    if not 0 <= alpha:
        raise ValueError(f"alpha must be >= 0, got {alpha}")
    cost = graph.in_degree.astype(np.float64) + alpha * graph.out_degree
    cum = np.concatenate([[0.0], np.cumsum(cost)])
    bounds = np.zeros(P + 1, dtype=np.int64)
    bounds[P] = graph.n
    lo = 0
    for p in range(1, P):
        remaining = cum[-1] - cum[lo]
        target = cum[lo] + remaining / (P - p + 1)
        # first cut whose prefix cost reaches the adaptive target, keeping at
        # least the empty block (lo) admissible for degenerate graphs
        cut = int(np.searchsorted(cum, target, side="left"))
        bounds[p] = min(max(cut, lo), graph.n)
        lo = bounds[p]
    return np.maximum.accumulate(bounds)


def refine_blocks(
    graph: CSRGraph, P: int, alpha: float = 0.5, passes: int = 4
) -> np.ndarray:
    """Boundary-refined contiguous blocks: bounds of shape (P + 1,).

    Seeds with :func:`greedy_degree_blocks`, then runs Fiduccia–Mattheyses-
    style single-vertex moves restricted to the contiguous layout: each cut
    point may shift by one vertex at a time (the boundary vertex changes
    block), accepted only when the move *strictly* reduces the directed edge
    cut.  The gain of moving ``v`` from block A to adjacent block B is
    ``|neighbors(v) ∩ A| − |neighbors(v) ∩ B|`` over in- and out-edges
    (self-loops excluded): edges into the abandoned block become cut, edges
    into the destination block heal.  Strict improvement guarantees both
    termination (each move is −1 cut edge at least) and the invariant the
    tests pin: **edge cut ≤ the greedy_degree seed's**.  Per pass, each cut
    point walks at most a quarter of its span so one hub cannot drag a
    boundary across the whole graph; blocks never shrink below one vertex
    (empty seed blocks stay empty).
    """
    bounds = np.array(greedy_degree_blocks(graph, P, alpha), dtype=np.int64)
    if graph.n == 0 or P <= 1:
        return bounds
    indptr = graph.indptr
    in_nbrs = graph.indices.astype(np.int64)
    # Reverse adjacency (out-edges), built once: edge e is (indices[e] →
    # dst_of_edge[e]); stable-sorting by source groups each vertex's outs.
    dst_of_edge = np.repeat(np.arange(graph.n, dtype=np.int64), np.diff(indptr))
    order = np.argsort(in_nbrs, kind="stable")
    out_nbrs = dst_of_edge[order]
    out_ptr = np.concatenate(
        [[0], np.cumsum(np.bincount(in_nbrs, minlength=graph.n))]
    ).astype(np.int64)

    def neighbors(v: int) -> np.ndarray:
        nb = np.concatenate(
            [
                in_nbrs[indptr[v] : indptr[v + 1]],
                out_nbrs[out_ptr[v] : out_ptr[v + 1]],
            ]
        )
        return nb[nb != v]

    def count_in(nb: np.ndarray, lo: int, hi: int) -> int:
        return int(np.count_nonzero((nb >= lo) & (nb < hi)))

    for _ in range(max(passes, 0)):
        improved = False
        for p in range(1, P):
            max_shift = max(1, int(bounds[p + 1] - bounds[p - 1]) // 4)
            for _ in range(max_shift):
                b = int(bounds[p])
                moved = False
                if b - bounds[p - 1] >= 2:  # v = b−1 leaves block p−1 for p
                    nb = neighbors(b - 1)
                    gain = count_in(nb, int(bounds[p - 1]), b - 1) - count_in(
                        nb, b, int(bounds[p + 1])
                    )
                    if gain < 0:
                        bounds[p] = b - 1
                        improved = moved = True
                if not moved and bounds[p + 1] - b >= 2:  # v = b joins p−1
                    nb = neighbors(b)
                    gain = count_in(nb, b, int(bounds[p + 1])) - count_in(
                        nb, int(bounds[p - 1]), b
                    )
                    if gain < 0:
                        bounds[p] = b + 1
                        improved = moved = True
                if not moved:
                    break
        if not improved:
            break
    return bounds


PARTITION_METHODS = {
    "equal": lambda g, P: equal_blocks(g.n, P),
    "balanced": balanced_blocks,
    "greedy_degree": greedy_degree_blocks,
    "refine": refine_blocks,
}


@dataclasses.dataclass(frozen=True)
class Partition:
    """A contiguous P-way vertex partition plus its distribution metadata.

    * ``bounds``   — (P + 1,) block bounds; shard ``p`` owns ``[bounds[p],
      bounds[p+1])``.
    * ``owner``    — (n,) int32 owner shard of every vertex.
    * ``halo_in``  — per shard, the sorted global ids of *remote* vertices the
      shard reads (sources of its cut in-edges).  These are the entries an
      owner-computes engine must receive at each commit.
    * ``halo_out`` — per shard, the sorted global ids of *owned* vertices some
      other shard reads — what the shard must publish beyond its boundary.
    * ``edge_cut`` — number of edges whose source owner ≠ destination owner.

    Local index layout of shard ``p`` (used by the frontier-sharded engine):
    slots ``[0, owned_p)`` hold the owned block in vertex order, slots
    ``[owned_p, owned_p + |halo_in[p]|)`` hold the halo copies in sorted
    global order.  :meth:`local_index` / :meth:`global_index` are inverse maps
    over exactly that layout.
    """

    n: int
    P: int
    bounds: np.ndarray  # (P + 1,) int64
    owner: np.ndarray  # (n,) int32
    halo_in: tuple  # P × sorted int64 arrays
    halo_out: tuple  # P × sorted int64 arrays
    edge_cut: int
    edges: int

    @staticmethod
    def from_bounds(graph: CSRGraph, bounds: np.ndarray) -> "Partition":
        """Materialize owner/halo/cut metadata for contiguous ``bounds``."""
        bounds = np.asarray(bounds, dtype=np.int64)
        P = bounds.shape[0] - 1
        assert bounds[0] == 0 and bounds[-1] == graph.n
        owner = np.searchsorted(bounds[1:], np.arange(graph.n), side="right").astype(
            np.int32
        )
        dst_of_edge = np.repeat(
            np.arange(graph.n, dtype=np.int64), np.diff(graph.indptr)
        )
        src = graph.indices.astype(np.int64)
        o_src = owner[src] if graph.n else np.zeros(0, np.int32)
        o_dst = owner[dst_of_edge] if graph.n else np.zeros(0, np.int32)
        cut = o_src != o_dst
        halo_in = tuple(np.unique(src[cut & (o_dst == p)]) for p in range(P))
        halo_out = tuple(np.unique(src[cut & (o_src == p)]) for p in range(P))
        return Partition(
            n=graph.n,
            P=P,
            bounds=bounds,
            owner=owner,
            halo_in=halo_in,
            halo_out=halo_out,
            edge_cut=int(cut.sum()),
            edges=graph.nnz,
        )

    # ------------------------------------------------------------------ #
    # Index maps
    # ------------------------------------------------------------------ #
    @cached_property
    def owned_sizes(self) -> np.ndarray:
        return np.diff(self.bounds)

    @cached_property
    def local_sizes(self) -> np.ndarray:
        """Owned + halo slots per shard (without padding/dump)."""
        return self.owned_sizes + np.array(
            [h.shape[0] for h in self.halo_in], dtype=np.int64
        )

    def global_index(self, p: int) -> np.ndarray:
        """Local slot → global vertex id for shard ``p`` (owned then halo)."""
        return np.concatenate(
            [np.arange(self.bounds[p], self.bounds[p + 1]), self.halo_in[p]]
        )

    def local_index(self, p: int, vertices: np.ndarray) -> np.ndarray:
        """Global vertex ids → shard-``p`` local slots (-1 if not resident)."""
        v = np.asarray(vertices, dtype=np.int64)
        lo, hi = self.bounds[p], self.bounds[p + 1]
        out = np.full(v.shape, -1, dtype=np.int64)
        owned = (v >= lo) & (v < hi)
        out[owned] = v[owned] - lo
        halo = self.halo_in[p]
        if halo.size:
            pos = np.searchsorted(halo, v)
            pos_c = np.minimum(pos, halo.size - 1)
            hit = ~owned & (halo[pos_c] == v)
            out[hit] = (hi - lo) + pos_c[hit]
        return out

    # ------------------------------------------------------------------ #
    # Stats
    # ------------------------------------------------------------------ #
    @property
    def cut_fraction(self) -> float:
        return self.edge_cut / max(self.edges, 1)

    @property
    def halo_total(self) -> int:
        """Total halo copies across shards (remote reads deduplicated)."""
        return int(sum(h.shape[0] for h in self.halo_in))

    @property
    def halo_max(self) -> int:
        return int(max((h.shape[0] for h in self.halo_in), default=0))

    @property
    def replication_factor(self) -> float:
        """Resident vertex copies / vertices (1.0 = no halo at all)."""
        return (self.n + self.halo_total) / max(self.n, 1)

    def stats(self) -> dict:
        return {
            "P": self.P,
            "edge_cut": self.edge_cut,
            "cut_fraction": round(self.cut_fraction, 4),
            "halo_total": self.halo_total,
            "halo_max": self.halo_max,
            "replication_factor": round(self.replication_factor, 4),
        }


def make_partition(
    graph: CSRGraph, P: int, method: str = "balanced", **kwargs
) -> Partition:
    """Build a :class:`Partition` with one of :data:`PARTITION_METHODS`."""
    try:
        blocks = PARTITION_METHODS[method]
    except KeyError:
        raise ValueError(
            f"unknown partition method {method!r}; "
            f"choose from {sorted(PARTITION_METHODS)}"
        ) from None
    return Partition.from_bounds(graph, blocks(graph, P, **kwargs))
