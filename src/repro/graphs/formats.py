"""Graph storage formats.

Two layouts:

* :class:`CSRGraph` — host-side (numpy) pull-oriented CSR: for each destination
  vertex ``u`` we store its *in*-neighbours ``v`` and per-edge values.  This is
  the canonical format produced by the generators and consumed by analysis
  tools (access matrices, partitioning).

* :class:`StripeSchedule` — the TPU execution layout.  The delayed-async
  engine processes vertices in ``S`` *commit steps* per round; commit step
  ``s`` covers chunk ``s`` (of size ``delta``) of every worker's block
  simultaneously (see DESIGN.md §5).  The schedule stores, for every
  ``(step, worker)`` cell, a padded edge list so each commit step is a single
  static-shape gather / segment-reduce / scatter.  Padding entries carry the
  semiring's annihilating edge value so they contribute the ⊕-identity.
"""

from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np

__all__ = [
    "CSRGraph",
    "StripeSchedule",
    "assemble_stripe_schedule",
    "build_stripe_schedule",
    "build_worker_stripe",
]


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Pull-oriented CSR graph (host side, numpy).

    ``indptr[u] : indptr[u + 1]`` slices the in-edges of destination ``u``;
    ``indices`` holds the source vertex of each in-edge and ``values`` the
    edge value (e.g. ``1 / outdeg(src)`` for PageRank, a positive length for
    SSSP).
    """

    n: int
    indptr: np.ndarray  # (n + 1,) int64
    indices: np.ndarray  # (nnz,) int32 — source vertex per in-edge
    values: np.ndarray  # (nnz,) float32 or int32 — edge values
    name: str = "graph"

    # ------------------------------------------------------------------ #
    # Constructors
    # ------------------------------------------------------------------ #
    @staticmethod
    def from_edges(
        n: int,
        src: np.ndarray,
        dst: np.ndarray,
        values: np.ndarray | None = None,
        name: str = "graph",
        dedup: bool = True,
    ) -> "CSRGraph":
        """Build pull-CSR from a directed edge list ``src -> dst``."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if values is None:
            values = np.ones(src.shape[0], dtype=np.float32)
        values = np.asarray(values)
        if dedup:
            key = dst * n + src
            order = np.argsort(key, kind="stable")
            key = key[order]
            keep = np.ones(key.shape[0], dtype=bool)
            keep[1:] = key[1:] != key[:-1]
            order = order[keep]
            src, dst, values = src[order], dst[order], values[order]
        else:
            order = np.argsort(dst * n + src, kind="stable")
            src, dst, values = src[order], dst[order], values[order]
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, dst + 1, 1)
        np.cumsum(indptr, out=indptr)
        return CSRGraph(
            n=n,
            indptr=indptr,
            indices=src.astype(np.int32),
            values=values,
            name=name,
        )

    # ------------------------------------------------------------------ #
    # Stats
    # ------------------------------------------------------------------ #
    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @cached_property
    def in_degree(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    @cached_property
    def out_degree(self) -> np.ndarray:
        deg = np.zeros(self.n, dtype=np.int64)
        np.add.at(deg, self.indices, 1)
        return deg

    def with_values(self, values: np.ndarray, name: str | None = None) -> "CSRGraph":
        assert values.shape[0] == self.nnz
        return dataclasses.replace(self, values=values, name=name or self.name)

    def apply_updates(self, batch):
        """Apply an :class:`repro.graphs.updates.EdgeBatch` incrementally.

        Returns ``(new_graph, report)`` where ``report`` is an
        :class:`repro.graphs.updates.UpdateReport` carrying the
        affected-vertex frontier and the displaced old values (so
        ``batch.inverse(report)`` is the exact undo).  The vertex set is
        immutable — only edges change.
        """
        from repro.graphs.updates import apply_edge_batch

        return apply_edge_batch(self, batch)

    def stats(self) -> dict:
        ind = self.in_degree
        return {
            "name": self.name,
            "vertices": self.n,
            "edges": self.nnz,
            "avg_in_degree": float(ind.mean()) if self.n else 0.0,
            "max_in_degree": int(ind.max()) if self.n else 0,
        }


@dataclasses.dataclass(frozen=True)
class StripeSchedule:
    """Execution schedule for the delayed-async engine.

    Shapes (``S`` commit steps, ``P`` workers, ``M`` padded edges per cell,
    ``delta`` rows per cell):

    * ``src[S, P, M]``       — source vertex gathered from the frontier.
    * ``val[S, P, M]``       — edge value (``pad_val`` on padding entries).
    * ``dst_local[S, P, M]`` — destination row *within the cell*, in
      ``[0, delta]`` where ``delta`` is the dump slot for padding.
    * ``rows[S, P, delta]``  — global row id of each cell row (``n_slots - 1``
      = dump slot for rows beyond the worker's block).

    The frontier vector used by the engine has length ``n_slots = n + 1``;
    index ``n`` is a write-only dump slot.
    """

    n: int
    P: int
    delta: int
    S: int
    M: int
    src: np.ndarray  # (S, P, M) int32
    val: np.ndarray  # (S, P, M) value dtype
    dst_local: np.ndarray  # (S, P, M) int32
    rows: np.ndarray  # (S, P, delta) int32
    block_bounds: np.ndarray  # (P + 1,) int64 — contiguous vertex blocks
    edges: int  # true edge count (before padding)

    @property
    def n_slots(self) -> int:
        return self.n + 1

    @property
    def padded_edges(self) -> int:
        return int(self.src.size)

    @property
    def padding_overhead(self) -> float:
        return self.padded_edges / max(self.edges, 1)

    @property
    def flushes_per_round(self) -> int:
        """Commit collectives per round (sync ⇒ 1)."""
        return self.S

    def flush_bytes_per_round(self, bytes_per_elem: int = 4) -> int:
        """Bytes published to the global store per round (all workers)."""
        return self.S * self.P * self.delta * bytes_per_elem


def build_stripe_schedule(
    graph: CSRGraph,
    block_bounds: np.ndarray,
    delta: int,
    pad_val,
) -> StripeSchedule:
    """Precompute the static-shape stripe schedule for ``(graph, blocks, δ)``.

    ``block_bounds`` is the contiguous partition of vertices into ``P`` worker
    blocks (see :func:`repro.graphs.partition.balanced_blocks`).  ``delta`` is
    the paper's δ in vertex elements; chunk ``s`` of worker ``w`` covers rows
    ``block_bounds[w] + [s·δ, (s+1)·δ)`` clipped to the block.

    ``pad_val`` must be the semiring's annihilating edge value
    (``x ⊗ pad_val = ⊕-identity``): ``0`` for plus-times, ``+INF`` for
    min-plus.
    """
    block_bounds = np.asarray(block_bounds, dtype=np.int64)
    B = int(np.diff(block_bounds).max())
    delta = int(min(delta, B))
    assert delta >= 1
    S = -(-B // delta)  # ceil
    stripes = [
        build_worker_stripe(
            graph, int(block_bounds[w]), int(block_bounds[w + 1]), S, delta, pad_val
        )
        for w in range(block_bounds.shape[0] - 1)
    ]
    return assemble_stripe_schedule(graph, block_bounds, delta, pad_val, stripes)


def build_worker_stripe(
    graph: CSRGraph, lo: int, hi: int, S: int, delta: int, pad_val
) -> dict:
    """One worker's stripe arrays for block ``[lo, hi)`` at natural width.

    The unit of targeted schedule invalidation: its content depends only on
    the block's own rows (``indptr[lo:hi+1]`` relative slices, the in-edge
    sources/values of those rows), ``n``, ``S``, ``delta``, and ``pad_val`` —
    so a stripe can be content-addressed and reused across graph mutations
    that never touch this block.  Arrays are ``(S, M_w)`` with the worker's
    own padded width ``M_w``; :func:`assemble_stripe_schedule` pads to the
    global ``M`` with the same fill convention, bit-identically to a
    monolithic build.
    """
    indptr = graph.indptr
    r0s = [min(lo + s * delta, hi) for s in range(S)]
    r1s = [min(lo + (s + 1) * delta, hi) for s in range(S)]
    counts = [int(indptr[r1] - indptr[r0]) for r0, r1 in zip(r0s, r1s)]
    M_w = max(counts) if counts else 0

    src = np.zeros((S, M_w), dtype=np.int32)
    val = np.full((S, M_w), pad_val, dtype=graph.values.dtype)
    dst_local = np.full((S, M_w), delta, dtype=np.int32)  # dump slot
    rows = np.full((S, delta), graph.n, dtype=np.int32)  # dump slot of frontier
    for s, (r0, r1) in enumerate(zip(r0s, r1s)):
        if r1 <= r0:
            continue
        e0, e1 = indptr[r0], indptr[r1]
        m = e1 - e0
        src[s, :m] = graph.indices[e0:e1]
        val[s, :m] = graph.values[e0:e1]
        # destination row within the cell for each edge
        row_of_edge = np.repeat(np.arange(r0, r1), np.diff(indptr[r0 : r1 + 1])) - r0
        dst_local[s, :m] = row_of_edge.astype(np.int32)
        rows[s, : r1 - r0] = np.arange(r0, r1, dtype=np.int32)
    return {"src": src, "val": val, "dst_local": dst_local, "rows": rows}


def assemble_stripe_schedule(
    graph: CSRGraph, block_bounds: np.ndarray, delta: int, pad_val, stripes: list
) -> StripeSchedule:
    """Pad per-worker stripes to the global ``M`` and stack the schedule.

    ``stripes[w]`` is :func:`build_worker_stripe`'s dict for worker ``w``
    (freshly built or loaded from the content-addressed store); the output is
    bit-identical to the monolithic :func:`build_stripe_schedule`.
    """
    block_bounds = np.asarray(block_bounds, dtype=np.int64)
    P = block_bounds.shape[0] - 1
    n = graph.n
    S = stripes[0]["src"].shape[0] if stripes else 1
    M = max(1, max(st["src"].shape[1] for st in stripes)) if stripes else 1

    val_dtype = graph.values.dtype
    src = np.zeros((S, P, M), dtype=np.int32)
    val = np.full((S, P, M), pad_val, dtype=val_dtype)
    dst_local = np.full((S, P, M), delta, dtype=np.int32)  # dump slot
    rows = np.full((S, P, delta), n, dtype=np.int32)
    for w, st in enumerate(stripes):
        m = st["src"].shape[1]
        src[:, w, :m] = st["src"]
        val[:, w, :m] = st["val"]
        dst_local[:, w, :m] = st["dst_local"]
        rows[:, w, :] = st["rows"]

    return StripeSchedule(
        n=n,
        P=P,
        delta=delta,
        S=S,
        M=M,
        src=src,
        val=val,
        dst_local=dst_local,
        rows=rows,
        block_bounds=block_bounds,
        edges=graph.nnz,
    )
