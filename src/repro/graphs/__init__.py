from repro.graphs.formats import CSRGraph, StripeSchedule, build_stripe_schedule
from repro.graphs.generators import make_graph, GRAPH_GENERATORS
from repro.graphs.partition import (
    PARTITION_METHODS,
    Partition,
    balanced_blocks,
    equal_blocks,
    greedy_degree_blocks,
    make_partition,
)
from repro.graphs.updates import EdgeBatch, UpdateReport, apply_edge_batch

__all__ = [
    "CSRGraph",
    "EdgeBatch",
    "StripeSchedule",
    "UpdateReport",
    "apply_edge_batch",
    "build_stripe_schedule",
    "make_graph",
    "GRAPH_GENERATORS",
    "PARTITION_METHODS",
    "Partition",
    "balanced_blocks",
    "equal_blocks",
    "greedy_degree_blocks",
    "make_partition",
]
