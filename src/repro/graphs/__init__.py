from repro.graphs.formats import CSRGraph, StripeSchedule, build_stripe_schedule
from repro.graphs.generators import make_graph, GRAPH_GENERATORS
from repro.graphs.partition import balanced_blocks

__all__ = [
    "CSRGraph",
    "StripeSchedule",
    "build_stripe_schedule",
    "make_graph",
    "GRAPH_GENERATORS",
    "balanced_blocks",
]
