"""Sharded checkpointing with manifest + atomic commit + elastic restore.

Layout::

    <dir>/step_000100/
        manifest.json        # step, tree structure, leaf shapes/dtypes, shard map
        shard_00000.npz      # one npz per host: its slice of every leaf
        _COMMITTED           # written last — restart scans for the newest
                             # committed step and ignores torn writes

Design points for 1000+ nodes:

* every host writes only its own addressable shards (no cross-host traffic);
* the manifest stores the *global* layout, so restoring onto a different
  device count / mesh re-slices automatically (elastic re-shard);
* commit marker is rename-based (atomic on POSIX), a torn checkpoint is
  invisible;
* writes stream through a background thread (training continues) —
  ``save(..., block=False)``.
"""

from __future__ import annotations

import errno
import json
import os
import threading
import time
from pathlib import Path

import jax
import numpy as np

from repro.ft.inject import fire

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "CheckpointManager"]


def _write_fsync(path: Path, data):
    """Write + flush + fsync so a committed marker implies durable bytes."""
    mode = "wb" if isinstance(data, bytes) else "w"
    with open(path, mode) as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save_checkpoint(
    directory,
    step: int,
    tree,
    host_index: int = 0,
    n_hosts: int = 1,
    block: bool = True,
):
    """Save ``tree``; each host writes leaves sliced on axis 0 where possible."""
    directory = Path(directory)
    step_dir = directory / f"step_{step:09d}"
    # pid + thread in the staging name: concurrent savers (two managers, or a
    # restarted process racing a stale background writer) never share tmps
    tmp_dir = directory / (
        f".tmp_step_{step:09d}_{host_index}_{os.getpid()}_{threading.get_ident()}"
    )
    tmp_dir.mkdir(parents=True, exist_ok=True)
    step_dir.mkdir(parents=True, exist_ok=True)

    names, leaves, _ = _flatten_with_names(tree)
    host_arrays = {}
    shard_info = {}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(leaf)
        if arr.ndim >= 1 and arr.shape[0] >= n_hosts and arr.shape[0] % n_hosts == 0:
            per = arr.shape[0] // n_hosts
            sl = arr[host_index * per : (host_index + 1) * per]
            shard_info[name] = {"axis": 0, "per_host": per}
        else:
            sl = arr if host_index == 0 else np.zeros((0,), arr.dtype)
            shard_info[name] = {"axis": None, "per_host": None}
        host_arrays[name] = sl

    def _write():
        kind = fire("ckpt.write", step=step)
        if kind == "eio":
            raise OSError(errno.EIO, f"injected EIO writing checkpoint step {step}")
        fn = tmp_dir / f"shard_{host_index:05d}.npz"
        np.savez(fn, **{n.replace("/", "|"): a for n, a in host_arrays.items()})
        with open(fn, "rb+") as f:
            os.fsync(f.fileno())
        fn.rename(step_dir / f"shard_{host_index:05d}.npz")
        if host_index == 0:
            manifest = {
                "step": step,
                "n_hosts": n_hosts,
                "time": time.time(),
                "leaves": {
                    n: {
                        "shape": list(np.asarray(l).shape),
                        "dtype": str(np.asarray(l).dtype),
                        **shard_info[n],
                    }
                    for n, l in zip(names, leaves)
                },
            }
            mf = tmp_dir / "manifest.json"
            _write_fsync(mf, json.dumps(manifest, indent=1))
            mf.rename(step_dir / "manifest.json")
            if kind == "torn":
                # emulate a kill between data and commit: shards + manifest
                # are on disk but _COMMITTED never lands, so restart skips it
                _cleanup(tmp_dir)
                return
            marker = tmp_dir / "_COMMITTED"
            _write_fsync(marker, "ok")
            marker.rename(step_dir / "_COMMITTED")
        _cleanup(tmp_dir)

    def _cleanup(d):
        for leftover in d.iterdir():
            leftover.unlink()
        d.rmdir()

    if block:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(directory) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in directory.iterdir()
        if p.name.startswith("step_") and (p / "_COMMITTED").exists()
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory, step: int, like_tree):
    """Restore into the structure of ``like_tree`` (elastic: any host count)."""
    step_dir = Path(directory) / f"step_{step:09d}"
    manifest = json.loads((step_dir / "manifest.json").read_text())
    n_hosts = manifest["n_hosts"]
    shards = [
        np.load(step_dir / f"shard_{h:05d}.npz") for h in range(n_hosts)
    ]
    names, leaves, treedef = _flatten_with_names(like_tree)
    out = []
    for name, leaf in zip(names, leaves):
        info = manifest["leaves"][name]
        key = name.replace("/", "|")
        if info["axis"] == 0:
            arr = np.concatenate([s[key] for s in shards], axis=0)
        else:
            arr = shards[0][key]
        arr = arr.reshape(info["shape"]).astype(info["dtype"])
        expect = tuple(getattr(leaf, "shape", arr.shape))
        assert tuple(arr.shape) == expect, f"{name}: {arr.shape} != {expect}"
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Keep-last-k manager with async save and restart discovery."""

    def __init__(self, directory, keep: int = 3, host_index: int = 0, n_hosts: int = 1):
        self.directory = Path(directory)
        self.keep = keep
        self.host_index = host_index
        self.n_hosts = n_hosts
        self._pending: threading.Thread | None = None

    def save(self, step: int, tree, block: bool = False):
        self.wait()
        self._pending = save_checkpoint(
            self.directory, step, tree, self.host_index, self.n_hosts, block=block
        )
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_latest(self, like_tree):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, restore_checkpoint(self.directory, step, like_tree)

    def _gc(self):
        if self.host_index != 0:
            return
        steps = sorted(
            p
            for p in self.directory.iterdir()
            if p.name.startswith("step_") and (p / "_COMMITTED").exists()
        )
        for p in steps[: -self.keep]:
            for f in p.iterdir():
                f.unlink()
            p.rmdir()
