"""Deterministic, shard-aware data pipeline.

Production posture without external deps:

* ``SyntheticLM`` — deterministic counter-based token stream (feeds the same
  global batch to any device layout: batch index → PRNG fold, so restarts and
  elastic re-shards reproduce the exact stream; no host state).
* ``FileBackedLM`` — memory-mapped token file with epoch shuffling by
  bijective index permutation (Feistel-ish multiplicative hash), sharded by
  (host, step) without coordination.

Both yield ``{"tokens": (B, S) int32, "labels": (B, S) int32}`` with labels =
next-token shift; the final position is masked (-1).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticLM", "FileBackedLM", "make_vlm_batch", "make_encdec_batch"]


def _hash_u64(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64)
    h = np.uint64(0x9E3779B97F4A7C15)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31)) ^ h


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> dict:
        """Global batch for ``step`` — identical regardless of sharding."""
        b = np.arange(self.global_batch, dtype=np.uint64)[:, None]
        s = np.arange(self.seq_len + 1, dtype=np.uint64)[None, :]
        raw = _hash_u64(
            b * np.uint64(1_000_003)
            + s
            + np.uint64(step) * np.uint64(0x5DEECE66D)
            + np.uint64(self.seed)
        )
        toks = (raw % np.uint64(self.vocab)).astype(np.int32)
        tokens, labels = toks[:, :-1], toks[:, 1:].copy()
        labels[:, -1] = -1
        return {"tokens": tokens, "labels": labels}

    def shard(self, step: int, host_index: int, n_hosts: int) -> dict:
        full = self.batch(step)
        lo = self.global_batch * host_index // n_hosts
        hi = self.global_batch * (host_index + 1) // n_hosts
        return {k: v[lo:hi] for k, v in full.items()}


@dataclasses.dataclass
class FileBackedLM:
    path: str
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")
        self._n_seqs = (self._data.shape[0] - 1) // self.seq_len

    def _perm(self, idx: np.ndarray, epoch: int) -> np.ndarray:
        # bijective-enough shuffle: multiplicative hash mod n_seqs
        return (
            (idx.astype(np.uint64) * np.uint64(2654435761) + np.uint64(epoch * 40503))
            % np.uint64(self._n_seqs)
        ).astype(np.int64)

    def batch(self, step: int) -> dict:
        start = step * self.global_batch
        epoch = start // self._n_seqs
        idx = (start + np.arange(self.global_batch)) % self._n_seqs
        idx = self._perm(idx, epoch)
        offs = idx[:, None] * self.seq_len + np.arange(self.seq_len + 1)[None, :]
        toks = self._data[offs].astype(np.int32)
        tokens, labels = toks[:, :-1], toks[:, 1:].copy()
        labels[:, -1] = -1
        return {"tokens": tokens, "labels": labels}


def make_vlm_batch(base: dict, d_model: int, seed: int = 0) -> dict:
    """VLM stub: precomputed patch/token embeddings replace token ids."""
    tokens = base["tokens"]
    B, S = tokens.shape
    rng = np.random.default_rng(seed + int(tokens[0, 0]))
    embeds = rng.standard_normal((B, S, d_model), dtype=np.float32) * 0.02
    positions = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))
    positions = np.broadcast_to(positions[:, None, :], (B, 3, S)).copy()
    return {"embeds": embeds, "positions": positions, "labels": base["labels"]}


def make_encdec_batch(base: dict, d_model: int, enc_seq: int, seed: int = 0) -> dict:
    """Whisper stub: precomputed conv-frontend frame embeddings."""
    tokens = base["tokens"]
    B = tokens.shape[0]
    rng = np.random.default_rng(seed + int(tokens[0, 0]))
    frames = rng.standard_normal((B, enc_seq, d_model), dtype=np.float32) * 0.02
    return {"tokens": tokens, "frames": frames, "labels": base["labels"]}
