"""Warm-restart state repair: seed an incremental solve from a prior fixed point.

The delayed-async engine's row updates are *monotone in one direction*:
plus-times problems (PageRank / PPR / Jacobi) are contractions that converge
from **any** starting state, and min-plus problems (SSSP / CC) only ever
*lower* labels (``new = min(old, reduced)``).  That asymmetry decides the
warm-start rule per semiring:

* **plus-times** — the previous fixed point passes through unchanged.  For a
  linear fixed point ``x = b + Mx``, iterating the full system from ``x*``
  is round-for-round identical to Maiter's delta-accumulative scheme
  (iterate the perturbation ``e = r + M'e`` from ``e₀ = 0`` and add ``x*``
  back): both start from the same state and apply the same linear operator,
  so the residual sequence coincides and convergence inherits the
  contraction argument.

* **min-plus** — inserts and weight *decreases* only create shorter paths,
  so ``x*`` remains an upper bound and the monotone iteration repairs it
  directly.  Deletes and weight *increases* can strand labels **below** their
  new fixed point, and a min-propagation can never raise them — the
  *deletion invalidation cone* must be re-raised to its base value first:

  - strictly positive weights (SSSP): a support-chain fix-point.  A vertex is
    *supported* if its old label is still attained by its base value or by a
    supported in-neighbour through the **new** graph.  Unsupported vertices
    form exactly the cone of labels that depended on a deleted/raised edge;
    they reset to ``x0``.  Positive weights make support chains strictly
    decreasing in label, so the recursion grounds at the base (no cyclic
    self-support) and the marking is complete.
  - all-zero weights (CC): support chains *can* be cyclic (two stale-label
    vertices supporting each other across a deleted bridge), so supportedness
    must instead be **certified** from the label originators — a multi-source
    BFS from every vertex whose label is its own base value, walking
    same-old-label edges of the new graph.  Uncertified vertices reset.

  Either way the repaired state ``y`` satisfies ``x*_new ≤ y ≤ x0``
  pointwise, and the min-plus iteration from any such ``y`` converges to
  exactly ``x*_new`` — bit-identical labels to a cold solve.

Mixed zero/positive min-plus weights defeat both arguments; those fall back
to a cold start (correct, no speedup) unless the caller forces a repair mode.
"""

from __future__ import annotations

from collections import deque

import numpy as np

from repro.core.semiring import INT_INF

__all__ = ["warm_start_state", "minplus_cone_repair", "minplus_certificate_repair"]


def _out_adjacency(graph):
    """CSR-by-source view of a pull-CSR graph: who reads vertex ``v``."""
    order = np.argsort(graph.indices, kind="stable")
    out_ptr = np.zeros(graph.n + 1, dtype=np.int64)
    np.add.at(out_ptr, graph.indices.astype(np.int64) + 1, 1)
    np.cumsum(out_ptr, out=out_ptr)
    dst_of_edge = np.repeat(np.arange(graph.n, dtype=np.int64), np.diff(graph.indptr))
    return out_ptr, dst_of_edge[order]


def minplus_cone_repair(graph, x_prev, x0, seed_rows) -> np.ndarray:
    """Re-raise the deletion cone for strictly positive min-plus weights.

    ``graph`` is the *new* (post-update) schedule graph, ``x_prev`` the old
    fixed point, ``x0`` the problem's base state on the new graph, and
    ``seed_rows`` the rows whose in-edge lists changed.  Returns the repaired
    warm state: supported vertices keep their old label, unsupported ones
    reset to ``x0``.  Marking extra vertices unsupported is safe (they just
    re-lower); missing one is not — the worklist therefore recursively
    rechecks every reader of a newly unsupported vertex until no support
    changes, which terminates because vertices are only ever marked once.
    """
    n = graph.n
    x = x_prev.astype(np.int64)
    base = x0.astype(np.int64)
    src = graph.indices.astype(np.int64)
    w = graph.values.astype(np.int64)
    indptr = graph.indptr
    out_ptr, out_dst = _out_adjacency(graph)

    supported = np.ones(n, dtype=bool)
    queued = np.zeros(n, dtype=bool)
    work = deque(int(u) for u in seed_rows)
    queued[np.asarray(seed_rows, dtype=np.int64)] = True
    while work:
        u = work.popleft()
        queued[u] = False
        if not supported[u]:
            continue
        e0, e1 = indptr[u], indptr[u + 1]
        vs = src[e0:e1]
        cand = np.where(
            supported[vs], np.minimum(x[vs] + w[e0:e1], INT_INF), INT_INF
        )
        best = min(int(base[u]), int(cand.min()) if cand.size else INT_INF)
        if best > x[u]:
            supported[u] = False
            for t in out_dst[out_ptr[u] : out_ptr[u + 1]]:
                if supported[t] and not queued[t]:
                    queued[t] = True
                    work.append(int(t))
    y = np.where(supported, x_prev, x0)
    return y.astype(x_prev.dtype)


def minplus_certificate_repair(graph, x_prev, x0) -> np.ndarray:
    """Certify labels from their originators (all-zero weights, e.g. CC).

    A vertex keeps its old label only if it reaches, through new-graph edges
    whose endpoints share that old label, some *originator* — a vertex whose
    old label equals its own base value (for CC: ``x*[r] == r``).  Plain
    support-checking is insufficient here: zero-weight support cycles let two
    stale vertices vouch for each other after the bridge to their label's
    originator was deleted.  Assumes the undirected convention CC requires
    (every edge present in both pull directions), so the pull-CSR in-edges
    double as out-edges for the BFS.
    """
    n = graph.n
    src = graph.indices.astype(np.int64)
    indptr = graph.indptr
    x = np.asarray(x_prev)
    base = np.asarray(x0)

    certified = x == base
    work = deque(int(u) for u in np.nonzero(certified)[0])
    while work:
        u = work.popleft()
        for v in src[indptr[u] : indptr[u + 1]]:
            if not certified[v] and x[v] == x[u]:
                certified[v] = True
                work.append(int(v))
    return np.where(certified, x_prev, x0).astype(x_prev.dtype)


def _has_raises(batch, report) -> bool:
    """Did the batch delete any edge or raise any weight?"""
    if report.deleted:
        return True
    if report.reweighted:
        new = np.asarray(batch.reweight_val)
        old = np.asarray(report.reweight_old_values)
        return bool(np.any(new.astype(np.float64) > old.astype(np.float64)))
    return False


def warm_start_state(problem, graph, sched_graph, x_prev, batch=None, report=None):
    """The warm initial state for re-solving ``problem`` after ``batch``.

    ``graph`` is the post-update base graph (feeds ``problem.x0``),
    ``sched_graph`` the post-update schedule graph (edge-value overrides
    applied — the weights the iteration actually runs on), ``x_prev`` the
    fixed point of the pre-update solve.  With no batch/report (plain warm
    re-solve) or for plus-times problems, ``x_prev`` passes through.
    """
    if batch is None or report is None:
        return x_prev
    if np.dtype(problem.semiring.dtype).kind == "f":
        # plus-times contraction: converges from any x0, and starting at the
        # old fixed point is Maiter's accumulative delta iteration in disguise
        return x_prev
    if not _has_raises(batch, report):
        return x_prev  # inserts/decreases only: x_prev stays an upper bound
    x0 = np.asarray(problem.x0(graph))
    vals = np.asarray(sched_graph.values)
    if vals.size == 0 or (vals == 0).all():
        return minplus_certificate_repair(sched_graph, np.asarray(x_prev), x0)
    if (vals > 0).all():
        # seed with every changed row; inserts are harmless extra rechecks
        return minplus_cone_repair(
            sched_graph, np.asarray(x_prev), x0, report.affected_rows
        )
    return x0  # mixed zero/positive weights: cold start is the safe repair
