"""Incremental solving on evolving graphs.

The subsystem spans four layers (each living with the machinery it extends,
re-exported here as the one façade):

1. **mutation** — :class:`~repro.graphs.updates.EdgeBatch` +
   ``CSRGraph.apply_updates``: typed insert/delete/reweight batches applied
   incrementally, reporting the affected-vertex frontier;
2. **restart** — :mod:`repro.evolve.restart`: repair the previous fixed point
   into a valid warm state (delta-accumulative for plus-times, monotone repair
   with the deletion cone re-raised for min-plus), consumed by
   ``Solver.resolve(updates=...)``;
3. **persistence** — targeted invalidation: per-worker schedule stripes and
   per-shard plan pieces are content-addressed in :mod:`repro.persist`, so a
   mutation rebuilds only the touched blocks;
4. **serving** — ``UpdateRequest`` lifecycle in
   :class:`repro.launch.service.ContinuousScheduler`: batches apply at round
   boundaries against quiesced lanes, so in-flight queries always retire on a
   consistent snapshot.
"""

from repro.evolve.restart import (
    minplus_certificate_repair,
    minplus_cone_repair,
    warm_start_state,
)
from repro.graphs.updates import EdgeBatch, UpdateReport, apply_edge_batch

__all__ = [
    "EdgeBatch",
    "UpdateReport",
    "apply_edge_batch",
    "minplus_certificate_repair",
    "minplus_cone_repair",
    "warm_start_state",
]
