"""Analytic δ-selector (beyond paper — their stated future work).

The paper shows the best δ depends on platform, topology, and algorithm, and
leaves "what buffer size to use" open.  On TPU the commit cost is *explicit*
(a collective), so we can model the total time directly:

    T(δ) = rounds(δ) · [ compute_round + flushes(δ) · (α + P·δ·bytes / β) ]

with α the collective latency, β the ICI bandwidth, flushes(δ) = ⌈B/δ⌉.
``rounds(δ)`` is interpolated from two cheap probes (sync and finest-δ runs on
a sampled subgraph) with the freshness model

    rounds(δ) ≈ r_async + (r_sync − r_async) · log(δ/δ_min) / log(B/δ_min)

(log because information freshness scales with the *number of commit
horizons* per round, which is geometric in δ).  The selector also consumes the
Fig-5 locality fraction: when the access matrix is diagonal-dominant the
freshness term is discounted (delaying can't relieve contention the topology
never creates — paper §IV-C).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.access_matrix import access_matrix, locality_fraction
from repro.graphs.formats import CSRGraph
from repro.graphs.partition import balanced_blocks

__all__ = [
    "DeltaModel",
    "fit_delta_model",
    "refit_delta_model",
    "refit_delta_models",
    "TPUCostParams",
]


@dataclasses.dataclass(frozen=True)
class TPUCostParams:
    """Per-chip TPU v5e constants (same as benchmarks/roofline.py)."""

    peak_flops: float = 197e12  # bf16 FLOP/s
    hbm_bw: float = 819e9  # B/s
    ici_bw: float = 50e9  # B/s per link
    collective_latency_s: float = 1e-6  # α per commit


@dataclasses.dataclass(frozen=True)
class DeltaModel:
    P: int
    B: int  # max block size (elements)
    delta_min: int
    r_sync: int
    r_async: int
    locality: float
    edges: int
    bytes_per_elem: int
    hw: TPUCostParams

    def rounds(self, delta: int) -> float:
        # Exactly the linear-in-(r_sync, r_async) form that
        # refit_delta_model's least squares inverts — any change to the
        # interpolation must go through _freshness_weight or the refit
        # silently fits a different curve than best_delta evaluates.
        w = self._freshness_weight(delta)
        return float(self.r_sync) * (1.0 - w) + float(self.r_async) * w

    def round_cost_s(self, delta: int) -> float:
        hw = self.hw
        compute = 2.0 * self.edges / self.P / hw.peak_flops  # ⊕/⊗ per edge
        mem_bytes = (2 * self.edges + 2 * self.P * self.B) * self.bytes_per_elem
        memory = mem_bytes / self.P / hw.hbm_bw
        flushes = -(-self.B // delta)
        commit = flushes * (
            hw.collective_latency_s + self.P * delta * self.bytes_per_elem / hw.ici_bw
        )
        return compute + memory + commit

    def total_time_s(self, delta: int) -> float:
        return self.rounds(delta) * self.round_cost_s(delta)

    def best_delta(self, grid=None) -> int:
        if grid is None:
            grid = [2**k for k in range(4, 16)]
        grid = [int(min(d, self.B)) for d in grid if d >= self.delta_min] or [self.B]
        return int(min(grid, key=self.total_time_s))

    # ------------------------------------------------------------------ #
    # persistence (repro.persist stores the fitted model as JSON)
    # ------------------------------------------------------------------ #
    def to_dict(self) -> dict:
        return {
            "P": int(self.P),
            "B": int(self.B),
            "delta_min": int(self.delta_min),
            "r_sync": float(self.r_sync),
            "r_async": float(self.r_async),
            "locality": float(self.locality),
            "edges": int(self.edges),
            "bytes_per_elem": int(self.bytes_per_elem),
            "hw": dataclasses.asdict(self.hw),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "DeltaModel":
        return cls(
            P=int(d["P"]),
            B=int(d["B"]),
            delta_min=int(d["delta_min"]),
            r_sync=d["r_sync"],
            r_async=d["r_async"],
            locality=float(d["locality"]),
            edges=int(d["edges"]),
            bytes_per_elem=int(d["bytes_per_elem"]),
            hw=TPUCostParams(**d["hw"]),
        )

    def _freshness_weight(self, delta: int) -> float:
        """w(δ) with rounds(δ) = r_sync·(1 − w) + r_async·w (linear form).

        Diagonal-clustered topologies get little freshness benefit from
        remote commits (paper Fig 5) — ``locality`` discounts the async gain.
        """
        if self.B <= self.delta_min:
            return 0.0
        frac = np.log(max(delta, self.delta_min) / self.delta_min) / np.log(
            self.B / self.delta_min
        )
        frac = float(np.clip(frac, 0.0, 1.0))
        return (1.0 - self.locality) * (1.0 - frac)


def fit_delta_model(
    graph: CSRGraph,
    P: int,
    r_sync: int,
    r_async: int,
    delta_min: int = 128,
    bytes_per_elem: int = 4,
    hw: TPUCostParams | None = None,
) -> DeltaModel:
    """Fit the model from two measured probes (sync & async round counts)."""
    bounds = balanced_blocks(graph, P)
    B = int(np.diff(bounds).max())
    loc = locality_fraction(access_matrix(graph, bounds))
    return DeltaModel(
        P=P,
        B=B,
        delta_min=min(delta_min, B),
        r_sync=r_sync,
        r_async=r_async,
        locality=loc,
        edges=graph.nnz,
        bytes_per_elem=bytes_per_elem,
        hw=hw or TPUCostParams(),
    )


def refit_delta_model(model: DeltaModel, observations) -> DeltaModel:
    """Re-fit ``(r_sync, r_async)`` from production-observed ``(δ, rounds)``.

    The freshness model is *linear* in its two round counts:
    ``rounds(δ) = r_sync·(1 − w) + r_async·w`` with
    ``w(δ) = (1 − locality)·(1 − frac(δ))`` — so observations accumulated from
    real :class:`~repro.core.engine.EngineResult` runs refit by least squares,
    no re-probing solves required.  The current model's own predictions at the
    two anchor points (δ_min and B) join as prior pseudo-observations, keeping
    the fit well-posed from a single observed δ and the migration smooth
    (new data *pulls* the curve rather than replacing it).

    ``observations`` is an iterable of ``(delta, rounds)`` pairs; non-positive
    round counts are discarded.  Returns a new model (the input is frozen);
    topology-derived fields (locality, B, cost params) are unchanged — only
    the round-count curve moves.
    """
    obs = [(int(d), float(r)) for d, r in observations if r > 0]
    anchors = [
        (model.delta_min, model.rounds(model.delta_min)),
        (model.B, model.rounds(model.B)),
    ]
    pts = obs + anchors
    w = np.array([model._freshness_weight(d) for d, _ in pts])
    design = np.stack([1.0 - w, w], axis=1)
    target = np.array([r for _, r in pts])
    (r_sync, r_async), *_ = np.linalg.lstsq(design, target, rcond=None)
    return dataclasses.replace(
        model, r_sync=max(float(r_sync), 1.0), r_async=max(float(r_async), 1.0)
    )


def refit_delta_models(model: DeltaModel, rows) -> dict:
    """Per-regime refits from tagged observation rows.

    ``rows`` are :meth:`repro.persist.store.SolverCache.load_observations`
    dicts (each carrying ``delta``, ``rounds``, ``regime``).  Incremental
    warm restarts converge in far fewer rounds than cold solves at the same δ,
    so one pooled fit would drag the cold curve down and push the incremental
    curve up; instead each regime refits independently, seeded from the same
    base ``model`` (whose anchors keep a sparsely observed regime well-posed).
    Returns ``{regime: refitted_model}`` — only regimes with ≥ 1 usable
    observation appear.
    """
    by_regime: dict[str, list] = {}
    for row in rows:
        by_regime.setdefault(row.get("regime", "cold"), []).append(
            (row["delta"], row["rounds"])
        )
    return {
        regime: refit_delta_model(model, pairs)
        for regime, pairs in by_regime.items()
        if any(r > 0 for _, r in pairs)
    }
