"""Analytic δ-selector (beyond paper — their stated future work).

The paper shows the best δ depends on platform, topology, and algorithm, and
leaves "what buffer size to use" open.  On TPU the commit cost is *explicit*
(a collective), so we can model the total time directly:

    T(δ) = rounds(δ) · [ compute_round + flushes(δ) · (α + P·δ·bytes / β) ]

with α the collective latency, β the ICI bandwidth, flushes(δ) = ⌈B/δ⌉.
``rounds(δ)`` is interpolated from two cheap probes (sync and finest-δ runs on
a sampled subgraph) with the freshness model

    rounds(δ) ≈ r_async + (r_sync − r_async) · log(δ/δ_min) / log(B/δ_min)

(log because information freshness scales with the *number of commit
horizons* per round, which is geometric in δ).  The selector also consumes the
Fig-5 locality fraction: when the access matrix is diagonal-dominant the
freshness term is discounted (delaying can't relieve contention the topology
never creates — paper §IV-C).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.access_matrix import access_matrix, locality_fraction
from repro.graphs.formats import CSRGraph
from repro.graphs.partition import balanced_blocks

__all__ = ["DeltaModel", "fit_delta_model", "TPUCostParams"]


@dataclasses.dataclass(frozen=True)
class TPUCostParams:
    """Per-chip TPU v5e constants (same as benchmarks/roofline.py)."""

    peak_flops: float = 197e12  # bf16 FLOP/s
    hbm_bw: float = 819e9  # B/s
    ici_bw: float = 50e9  # B/s per link
    collective_latency_s: float = 1e-6  # α per commit


@dataclasses.dataclass(frozen=True)
class DeltaModel:
    P: int
    B: int  # max block size (elements)
    delta_min: int
    r_sync: int
    r_async: int
    locality: float
    edges: int
    bytes_per_elem: int
    hw: TPUCostParams

    def rounds(self, delta: int) -> float:
        if self.B <= self.delta_min:
            return float(self.r_sync)
        frac = np.log(max(delta, self.delta_min) / self.delta_min) / np.log(
            self.B / self.delta_min
        )
        frac = float(np.clip(frac, 0.0, 1.0))
        # Diagonal-clustered topologies get little freshness benefit from
        # remote commits (paper Fig 5) — discount the async gain.
        gain = (self.r_sync - self.r_async) * (1.0 - self.locality)
        return self.r_sync - gain * (1.0 - frac)

    def round_cost_s(self, delta: int) -> float:
        hw = self.hw
        compute = 2.0 * self.edges / self.P / hw.peak_flops  # ⊕/⊗ per edge
        mem_bytes = (2 * self.edges + 2 * self.P * self.B) * self.bytes_per_elem
        memory = mem_bytes / self.P / hw.hbm_bw
        flushes = -(-self.B // delta)
        commit = flushes * (
            hw.collective_latency_s + self.P * delta * self.bytes_per_elem / hw.ici_bw
        )
        return compute + memory + commit

    def total_time_s(self, delta: int) -> float:
        return self.rounds(delta) * self.round_cost_s(delta)

    def best_delta(self, grid=None) -> int:
        if grid is None:
            grid = [2**k for k in range(4, 16)]
        grid = [int(min(d, self.B)) for d in grid if d >= self.delta_min] or [self.B]
        return int(min(grid, key=self.total_time_s))


def fit_delta_model(
    graph: CSRGraph,
    P: int,
    r_sync: int,
    r_async: int,
    delta_min: int = 128,
    bytes_per_elem: int = 4,
    hw: TPUCostParams | None = None,
) -> DeltaModel:
    """Fit the model from two measured probes (sync & async round counts)."""
    bounds = balanced_blocks(graph, P)
    B = int(np.diff(bounds).max())
    loc = locality_fraction(access_matrix(graph, bounds))
    return DeltaModel(
        P=P,
        B=B,
        delta_min=min(delta_min, B),
        r_sync=r_sync,
        r_async=r_async,
        locality=loc,
        edges=graph.nnz,
        bytes_per_elem=bytes_per_elem,
        hw=hw or TPUCostParams(),
    )
