"""Worker-to-worker access instrumentation (paper Fig 5).

For a given partition, counts how many reads worker ``r`` (owner of the
destination vertex) makes into vertex data owned by worker ``o`` (owner of the
source vertex) in one pull round.  The paper uses the resulting P×P matrix to
explain *when delaying helps*: diagonal-clustered topologies (Web) consume
their own updates and gain nothing from buffering.
"""

from __future__ import annotations

import numpy as np

from repro.graphs.formats import CSRGraph

__all__ = ["access_matrix", "locality_fraction"]


def access_matrix(graph: CSRGraph, block_bounds: np.ndarray) -> np.ndarray:
    """P×P matrix: ``A[r, o]`` = reads by worker r of worker o's data."""
    bounds = np.asarray(block_bounds)
    P = bounds.shape[0] - 1
    # owner of each vertex id (contiguous blocks → searchsorted)
    dst_of_edge = np.repeat(
        np.arange(graph.n, dtype=np.int64), np.diff(graph.indptr)
    )
    r = np.searchsorted(bounds, dst_of_edge, side="right") - 1
    o = np.searchsorted(bounds, graph.indices.astype(np.int64), side="right") - 1
    mat = np.zeros((P, P), dtype=np.int64)
    np.add.at(mat, (r, o), 1)
    return mat


def locality_fraction(mat: np.ndarray) -> float:
    """Fraction of reads that hit the reader's own block (diagonal mass)."""
    total = mat.sum()
    return float(np.trace(mat) / total) if total else 0.0
