"""Worker-to-worker access instrumentation (paper Fig 5).

For a given partition, counts how many reads worker ``r`` (owner of the
destination vertex) makes into vertex data owned by worker ``o`` (owner of the
source vertex) in one pull round.  The paper uses the resulting P×P matrix to
explain *when delaying helps*: diagonal-clustered topologies (Web) consume
their own updates and gain nothing from buffering.

The off-diagonal mass of the same matrix is exactly the partition's edge cut
(every edge is one read), so :func:`partition_report` fuses the Fig-5 locality
view with the :class:`repro.graphs.partition.Partition` halo/cut stats the
frontier-sharded engine pays for.
"""

from __future__ import annotations

import numpy as np

from repro.graphs.formats import CSRGraph
from repro.graphs.partition import Partition

__all__ = [
    "access_matrix",
    "locality_fraction",
    "remote_read_fraction",
    "partition_report",
]


def _bounds_of(block_bounds) -> np.ndarray:
    if isinstance(block_bounds, Partition):
        return block_bounds.bounds
    return np.asarray(block_bounds)


def access_matrix(graph: CSRGraph, block_bounds) -> np.ndarray:
    """P×P matrix: ``A[r, o]`` = reads by worker r of worker o's data.

    ``block_bounds`` is a (P + 1,) bounds array or a :class:`Partition`.
    """
    bounds = _bounds_of(block_bounds)
    P = bounds.shape[0] - 1
    # owner of each vertex id (contiguous blocks → searchsorted)
    dst_of_edge = np.repeat(np.arange(graph.n, dtype=np.int64), np.diff(graph.indptr))
    r = np.searchsorted(bounds, dst_of_edge, side="right") - 1
    o = np.searchsorted(bounds, graph.indices.astype(np.int64), side="right") - 1
    mat = np.zeros((P, P), dtype=np.int64)
    np.add.at(mat, (r, o), 1)
    return mat


def locality_fraction(mat: np.ndarray) -> float:
    """Fraction of reads that hit the reader's own block (diagonal mass)."""
    total = mat.sum()
    return float(np.trace(mat) / total) if total else 0.0


def remote_read_fraction(mat: np.ndarray) -> float:
    """Fraction of reads crossing shards — the edge-cut mass the halo pays."""
    return 1.0 - locality_fraction(mat)


def partition_report(
    graph: CSRGraph, partition: Partition, mat: np.ndarray | None = None
) -> dict:
    """Fig-5 locality numbers + the halo/cut stats of the same partition.

    ``off_diagonal_reads`` from the access matrix equals ``partition.edge_cut``
    by construction (each edge is one read) — asserted here so the two
    instrumentation paths can never drift apart.  Pass a precomputed ``mat``
    (from :func:`access_matrix` on the same partition) to skip the edge scan.
    """
    if mat is None:
        mat = access_matrix(graph, partition)
    off_diag = int(mat.sum() - np.trace(mat))
    assert off_diag == partition.edge_cut, (off_diag, partition.edge_cut)
    report = {
        "locality_fraction": round(locality_fraction(mat), 4),
        "remote_read_fraction": round(remote_read_fraction(mat), 4),
    }
    report.update(partition.stats())
    return report
