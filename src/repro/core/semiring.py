"""Semiring algebra for pull-style iterative graph algorithms.

A pull update is ``x'[u] = row_update(x[u], ⊕_{v ∈ in(u)} x[v] ⊗ A[v, u])``.
The semiring supplies ⊕ (as a segment reduction), ⊗, the ⊕-identity, and the
*annihilating edge value* used for schedule padding (``x ⊗ pad = ⊕-identity``
for every ``x``), so padded edges are no-ops.

Frontier "rows" need not be scalars: every op here is shape-generic over
trailing feature axes, so the same semiring drives ``(N,)`` vector frontiers
and ``(N, F)`` matrix frontiers (random-walk-with-restart embeddings, F-class
label propagation).  The contract each op must honor:

* ``mul(frontier_vals, edge_vals)`` — ``frontier_vals`` is ``(...,) + feat``
  while ``edge_vals`` arrives pre-expanded with trailing length-1 axes, so a
  plain broadcasting elementwise op (``*``, saturating ``+``) just works.
* ``segment_reduce(vals, seg_ids, num)`` — reduces over the *leading* axis
  only; ``vals`` may carry trailing feature axes (``jax.ops.segment_sum`` /
  ``segment_min`` already do).
* ``add`` — elementwise, broadcasting.

With ``feat = ()`` all of this degenerates to the historical vector engine,
bit for bit.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Semiring", "PLUS_TIMES", "MIN_PLUS", "INT_INF", "min_plus_int32"]

# Largest "infinity" such that INF ⊗ INF never overflows int32 under min-plus.
INT_INF = np.int32(2**30 - 1)


@dataclasses.dataclass(frozen=True)
class Semiring:
    """A (⊕, ⊗) pair plus the identities the schedule padding relies on."""

    name: str
    dtype: np.dtype
    zero: object  # ⊕ identity
    pad_edge_val: object  # annihilator: x ⊗ pad == zero
    mul: Callable  # ⊗(frontier_vals, edge_vals) -> contributions
    segment_reduce: Callable  # ⊕ over segments: (vals, seg_ids, num) -> out
    add: Callable  # elementwise ⊕ (for combining with old values)


def _segment_sum(vals, seg_ids, num):
    """Leading-axis segment-⊕ for plus-times; trailing feature axes ride along."""
    return jax.ops.segment_sum(vals, seg_ids, num_segments=num)


def _segment_min(vals, seg_ids, num):
    """Leading-axis segment-⊕ for min-plus; trailing feature axes ride along."""
    return jax.ops.segment_min(vals, seg_ids, num_segments=num)


PLUS_TIMES = Semiring(
    name="plus_times",
    dtype=np.dtype(np.float32),
    zero=np.float32(0.0),
    pad_edge_val=np.float32(0.0),
    mul=lambda x, a: x * a,
    segment_reduce=_segment_sum,
    add=lambda a, b: a + b,
)

# min-plus over saturating int32 (paper's SSSP uses 32-bit integers).
MIN_PLUS = Semiring(
    name="min_plus",
    dtype=np.dtype(np.int32),
    zero=INT_INF,
    pad_edge_val=INT_INF,
    mul=lambda x, a: jnp.minimum(x + a, INT_INF),
    segment_reduce=_segment_min,
    add=jnp.minimum,
)


def min_plus_int32() -> Semiring:
    """The saturating-int32 min-plus semiring (kept for API compatibility)."""
    return MIN_PLUS
