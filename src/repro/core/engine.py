"""The delayed-asynchronous iterative engine (the paper's contribution).

One *round* processes every vertex once, in ``S`` **commit steps**.  Commit
step ``s`` computes, for every worker in parallel, the pull-update of chunk
``s`` (δ rows) of that worker's block reading the *current committed* frontier,
then publishes all workers' chunks to the frontier simultaneously.  This is a
deterministic block Gauss–Seidel schedule with commit period δ — the TPU-native
semantics of the paper's thread-local buffer flush (DESIGN.md §2, §5):

* ``S == 1``   (δ = block size)  → exact Jacobi          = paper's *synchronous*
* ``S == B/δ_min`` (finest δ)    → finest block GS       = paper's *asynchronous*
* in between                     → *delayed asynchronous* (the hybrid)

The engine is mode-free: the mode IS the schedule's δ.  Counters for flushes
and flush bytes (the TPU analogue of cache-line invalidation traffic) are
reported on every run.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.semiring import Semiring
from repro.ft.inject import fire
from repro.graphs.formats import CSRGraph, StripeSchedule, build_stripe_schedule
from repro.graphs.partition import balanced_blocks

__all__ = [
    "EngineResult",
    "DeviceSchedule",
    "make_schedule",
    "round_fn",
    "round_fn_q",
    "round_fn_pallas",
    "round_fn_pallas_q",
    "make_solve_fn",
    "make_solve_fn_q",
    "make_solve_fn_q_dyn",
    "round_fn_q_dyn",
    "schedule_args",
    "host_loop",
    "execute_solve_fn",
    "run_host",
    "run_jit",
    "extend_frontier",
    "MIN_CHUNK",
]

# Finest vectorizable commit granularity (DESIGN.md §2): the TPU analogue of
# the paper's one-cache-line δ=16.  One VPU lane row = 128 elements.
MIN_CHUNK = 128


def extend_frontier(x0, semiring: Semiring):
    """Append the padding-dump slot: ``(n,)+feat → (n+1,)+feat``.

    The frontier may be a vector ``(n,)`` or a matrix ``(n, F)``; the dump
    row (index ``n``, where padded edges and padded δ-rows land) is filled
    with the ⊕-identity either way.  One authority for the extended-frontier
    layout shared by every runner, the Solver, and the batch path.
    """
    x0 = jnp.asarray(x0, dtype=semiring.dtype)
    pad = jnp.full((1,) + x0.shape[1:], semiring.zero, dtype=semiring.dtype)
    return jnp.concatenate([x0, pad])


@dataclasses.dataclass(frozen=True)
class DeviceSchedule:
    """StripeSchedule moved to device (jnp arrays) + metadata."""

    n: int
    P: int
    delta: int
    S: int
    M: int
    src: jnp.ndarray  # (S, P, M) int32
    val: jnp.ndarray  # (S, P, M)
    dst_local: jnp.ndarray  # (S, P, M) int32
    rows: jnp.ndarray  # (S, P, delta) int32
    edges: int
    padding_overhead: float
    block_bounds: np.ndarray | None = None  # (P + 1,) int64 host-side bounds

    @property
    def n_slots(self) -> int:
        return self.n + 1

    # ------------------------------------------------------------------ #
    # persistence (repro.persist stores schedules as plain npz archives)
    # ------------------------------------------------------------------ #
    def to_host_arrays(self) -> dict:
        """Flat ``{name: ndarray}`` dict round-trippable through ``np.savez``."""
        return {
            "n": np.int64(self.n),
            "P": np.int64(self.P),
            "delta": np.int64(self.delta),
            "S": np.int64(self.S),
            "M": np.int64(self.M),
            "src": np.asarray(self.src),
            "val": np.asarray(self.val),
            "dst_local": np.asarray(self.dst_local),
            "rows": np.asarray(self.rows),
            "edges": np.int64(self.edges),
            "padding_overhead": np.float64(self.padding_overhead),
            "block_bounds": np.asarray(
                self.block_bounds if self.block_bounds is not None else []
            ),
        }

    @classmethod
    def from_host_arrays(cls, arrays) -> "DeviceSchedule":
        """Rebuild from :meth:`to_host_arrays` output (shape-validated)."""
        n, P = int(arrays["n"]), int(arrays["P"])
        delta, S, M = int(arrays["delta"]), int(arrays["S"]), int(arrays["M"])
        src = np.asarray(arrays["src"])
        val = np.asarray(arrays["val"])
        dst_local = np.asarray(arrays["dst_local"])
        rows = np.asarray(arrays["rows"])
        bb = np.asarray(arrays["block_bounds"])
        if (
            src.shape != (S, P, M)
            or val.shape != (S, P, M)
            or dst_local.shape != (S, P, M)
            or rows.shape != (S, P, delta)
        ):
            raise ValueError("schedule arrays inconsistent with (S, P, M, delta)")
        return cls(
            n=n,
            P=P,
            delta=delta,
            S=S,
            M=M,
            src=jnp.asarray(src),
            val=jnp.asarray(val),
            dst_local=jnp.asarray(dst_local),
            rows=jnp.asarray(rows),
            edges=int(arrays["edges"]),
            padding_overhead=float(arrays["padding_overhead"]),
            block_bounds=bb.astype(np.int64) if bb.size else None,
        )


def make_schedule(
    graph: CSRGraph,
    P: int,
    delta: int | None,
    semiring: Semiring,
    mode: str = "delayed",
    min_chunk: int = MIN_CHUNK,
    bounds: np.ndarray | None = None,
) -> DeviceSchedule:
    """Build the device schedule for ``mode`` ∈ {sync, async, delayed}.

    * ``sync``    → δ = max block size (one commit per round).
    * ``async``   → δ = ``min_chunk`` (finest vectorizable commit).
    * ``delayed`` → δ as given (the paper's tunable).

    ``bounds`` overrides the default :func:`balanced_blocks` partition (any
    contiguous (P + 1,) bounds, e.g. from
    :func:`repro.graphs.partition.make_partition`).
    """
    if bounds is None:
        bounds = balanced_blocks(graph, P)
    else:
        bounds = np.asarray(bounds, dtype=np.int64)
        if bounds.shape != (P + 1,):
            raise ValueError(f"bounds must have shape ({P + 1},), got {bounds.shape}")
        if bounds[0] != 0 or bounds[-1] != graph.n or (np.diff(bounds) < 0).any():
            raise ValueError("bounds must cover [0, n] with monotone cuts")
    B = int(np.diff(bounds).max())
    if mode == "sync":
        delta_eff = B
    elif mode == "async":
        delta_eff = min(min_chunk, B)
    elif mode == "delayed":
        assert delta is not None, "delayed mode needs δ"
        delta_eff = int(min(max(delta, 1), B))
    else:
        raise ValueError(f"unknown mode {mode!r}")
    host = build_stripe_schedule(graph, bounds, delta_eff, semiring.pad_edge_val)
    return DeviceSchedule(
        n=host.n,
        P=host.P,
        delta=host.delta,
        S=host.S,
        M=host.M,
        src=jnp.asarray(host.src),
        val=jnp.asarray(host.val),
        dst_local=jnp.asarray(host.dst_local),
        rows=jnp.asarray(host.rows),
        edges=host.edges,
        padding_overhead=host.padding_overhead,
        block_bounds=np.asarray(host.block_bounds),
    )


def _commit_step(
    s, x_ext, sched: DeviceSchedule, semiring: Semiring, row_update, q=None
):
    """One commit step: chunk-SpMV for all workers + publish.

    Shape-generic over the frontier's trailing feature axes: ``x_ext`` may be
    ``(n+1,)`` (the classic vector engine) or ``(n+1, F)`` (matrix frontiers).
    For the vector case every reshape below is the identity, so the emitted
    computation — and therefore the result — is bit-identical to the
    historical vector-only commit step.
    """
    P, delta = sched.P, sched.delta
    feat = x_ext.shape[1:]  # () for vector state, (F,) for matrix state
    src_s = jax.lax.dynamic_index_in_dim(sched.src, s, 0, keepdims=False)
    val_s = jax.lax.dynamic_index_in_dim(sched.val, s, 0, keepdims=False)
    dst_s = jax.lax.dynamic_index_in_dim(sched.dst_local, s, 0, keepdims=False)
    rows_s = jax.lax.dynamic_index_in_dim(sched.rows, s, 0, keepdims=False)

    gathered = x_ext[src_s]  # (P, M) + feat — reads the committed frontier
    # Edge values broadcast over the feature axis: one ⊗ weight per edge.
    val_b = val_s.reshape(val_s.shape + (1,) * len(feat))
    contrib = semiring.mul(gathered, val_b)  # (P, M) + feat
    # Per-worker segment-⊕ into δ + 1 slots (last = padding dump).
    seg = dst_s + (jnp.arange(P, dtype=jnp.int32) * (delta + 1))[:, None]
    reduced = semiring.segment_reduce(
        contrib.reshape((-1,) + feat), seg.reshape(-1), P * (delta + 1)
    ).reshape((P, delta + 1) + feat)[:, :delta]
    old = x_ext[rows_s]  # (P, delta) + feat
    if q is None:
        new = row_update(old, reduced, rows_s)
    else:
        new = row_update(old, reduced, rows_s, q)
    # Publish: the flush.  Padding rows all point at the dump slot (index n).
    return x_ext.at[rows_s.reshape(-1)].set(
        new.reshape((-1,) + feat).astype(x_ext.dtype),
        mode="drop",
        unique_indices=False,
    )


def round_fn(sched: DeviceSchedule, semiring: Semiring, row_update) -> Callable:
    """Return jit-able ``x_ext -> x_ext`` running one full round (S commits)."""

    def body(x_ext):
        step = partial(
            _commit_step, sched=sched, semiring=semiring, row_update=row_update
        )
        return jax.lax.fori_loop(0, sched.S, step, x_ext)

    return body


def round_fn_q(sched: DeviceSchedule, semiring: Semiring, row_update) -> Callable:
    """Return jit-able ``(x_ext, q) -> x_ext`` for query-parameterized problems.

    ``q`` is a per-query pytree (e.g. a personalized-PageRank teleport vector)
    threaded to ``row_update(old, reduced, rows, q)``.  Keeping ``q`` a formal
    argument (rather than a closure constant) is what lets
    :func:`repro.solve.batch.solve_batch` vmap one round function over a batch
    of queries in a single lowering.
    """

    def body(x_ext, q):
        step = partial(
            _commit_step, sched=sched, semiring=semiring, row_update=row_update, q=q
        )
        return jax.lax.fori_loop(0, sched.S, step, x_ext)

    return body


def round_fn_pallas(
    sched: DeviceSchedule, semiring: Semiring, row_update, interpret: bool | None = None
) -> Callable:
    """``x_ext -> x_ext``: one round as a single fused Pallas kernel.

    Drop-in for :func:`round_fn` — same schedule, same commit-step order,
    bit-identical per round — but all ``S`` commit steps execute inside one
    ``pallas_call`` with the frontier input/output-aliased in VMEM, so the
    δ-buffer flush never round-trips through HBM between commits (see
    :mod:`repro.kernels.round_block`).  ``interpret=None`` auto-dispatches:
    compiled on TPU, interpret-mode emulation elsewhere.
    """
    from repro.kernels.round_block import fused_round_fn

    return fused_round_fn(sched, semiring, row_update, interpret=interpret)


def round_fn_pallas_q(
    sched: DeviceSchedule, semiring: Semiring, row_update, interpret: bool | None = None
) -> Callable:
    """``(x_ext, q) -> x_ext``: the fused Pallas round with query threading.

    Drop-in for :func:`round_fn_q`; ``q``'s pytree leaves ride along as
    VMEM-resident kernel inputs, so the returned callable vmaps for
    :func:`repro.solve.batch.solve_batch` exactly like the XLA round.
    """
    from repro.kernels.round_block import fused_round_fn_q

    return fused_round_fn_q(sched, semiring, row_update, interpret=interpret)


def schedule_args(sched: DeviceSchedule) -> tuple:
    """The schedule's *data* arrays, in :func:`round_fn_q_dyn` argument order.

    Everything else on a :class:`DeviceSchedule` — ``n``, ``P``, ``delta``,
    ``S``, ``M`` — is shape metadata that must stay static for the compiled
    round; these four arrays are the edge content that an
    :class:`repro.graphs.updates.EdgeBatch` can change without changing
    shapes, so the dynamic round takes them as traced inputs.
    """
    return sched.src, sched.val, sched.dst_local, sched.rows


def round_fn_q_dyn(sched: DeviceSchedule, semiring: Semiring, row_update) -> Callable:
    """``(x_ext, q, src, val, dst_local, rows) -> x_ext``: schedule-as-data round.

    Same commit-step semantics as :func:`round_fn_q`, but the schedule arrays
    arrive as traced arguments instead of closure constants — ``sched`` only
    pins the static shape metadata ``(S, P, M, delta, n)``.  This is the
    evolving-graph hot path: after ``Solver.apply_updates`` patches a
    schedule's stripes in place, the same compiled executable replays with the
    new arrays (mirroring how ``sharded_round_fn_q`` already treats its plan),
    so small edge batches never pay a retrace.
    """

    def body(x_ext, q, src, val, dst_local, rows):
        dyn = dataclasses.replace(
            sched, src=src, val=val, dst_local=dst_local, rows=rows
        )
        step = partial(
            _commit_step, sched=dyn, semiring=semiring, row_update=row_update, q=q
        )
        return jax.lax.fori_loop(0, sched.S, step, x_ext)

    return body


def make_solve_fn_q_dyn(
    sched: DeviceSchedule,
    semiring: Semiring,
    row_update,
    residual_fn,
) -> Callable:
    """``(x_ext, q, src, val, dst_local, rows, tol, max_rounds) -> carry``.

    The fused while-loop of :func:`make_solve_fn_q` over the dynamic round:
    one compiled executable per ``(S, P, M, delta)`` shape class serves every
    same-shape mutation of the graph.
    """
    rnd = round_fn_q_dyn(sched, semiring, row_update)

    def solve_loop(x_ext, q, src, val, dst_local, rows, tol, max_rounds):
        def cond(carry):
            _, _, rounds, converged = carry
            return jnp.logical_and(rounds < max_rounds, jnp.logical_not(converged))

        def body(carry):
            x, _, rounds, _ = carry
            x_new = rnd(x, q, src, val, dst_local, rows)
            res = residual_fn(x[:-1], x_new[:-1]).astype(jnp.float32)
            return x_new, res, rounds + 1, res <= tol

        init = (
            x_ext,
            jnp.asarray(np.inf, jnp.float32),
            jnp.asarray(0, jnp.int32),
            jnp.asarray(False),
        )
        return jax.lax.while_loop(cond, body, init)

    return solve_loop


def make_solve_fn_q(
    sched: DeviceSchedule,
    semiring: Semiring,
    row_update,
    residual_fn,
    round_builder: Callable = round_fn_q,
) -> Callable:
    """Fused device loop ``(x_ext, q, tol, max_rounds) -> carry``.

    The returned function runs rounds until ``residual ≤ tol`` or
    ``max_rounds``, entirely on device (``lax.while_loop``), and returns the
    carry ``(x_ext, residual, rounds, converged)``.  ``tol``/``max_rounds``
    are traced arguments, so changing them never retraces.

    ``round_builder`` swaps the round implementation the loop iterates —
    :func:`round_fn_q` (the XLA round) or :func:`round_fn_pallas_q` (the
    fused kernel) — while the convergence/residual/counter semantics stay in
    this one place.
    """
    rnd = round_builder(sched, semiring, row_update)

    def solve_loop(x_ext, q, tol, max_rounds):
        def cond(carry):
            _, _, rounds, converged = carry
            return jnp.logical_and(rounds < max_rounds, jnp.logical_not(converged))

        def body(carry):
            x, _, rounds, _ = carry
            x_new = rnd(x, q)
            res = residual_fn(x[:-1], x_new[:-1]).astype(jnp.float32)
            return x_new, res, rounds + 1, res <= tol

        init = (
            x_ext,
            jnp.asarray(np.inf, jnp.float32),
            jnp.asarray(0, jnp.int32),
            jnp.asarray(False),
        )
        return jax.lax.while_loop(cond, body, init)

    return solve_loop


def make_solve_fn(
    sched: DeviceSchedule,
    semiring: Semiring,
    row_update,
    residual_fn,
    round_builder: Callable = round_fn_q,
) -> Callable:
    """``(x_ext, tol, max_rounds) -> carry``: query-free fused device loop."""
    fn_q = make_solve_fn_q(
        sched,
        semiring,
        lambda old, red, rows, q: row_update(old, red, rows),
        residual_fn,
        round_builder=round_builder,
    )

    def solve_loop(x_ext, tol, max_rounds):
        return fn_q(x_ext, jnp.zeros((), jnp.int32), tol, max_rounds)

    return solve_loop


@dataclasses.dataclass
class EngineResult:
    x: np.ndarray  # (n,) or (n, F) converged vertex values
    rounds: int
    converged: bool
    flushes: int  # total commit collectives executed
    flush_bytes: int  # total bytes published to the global store
    residuals: list  # per-round convergence residuals
    round_times_s: list  # host-measured wall time per round, compile excluded
    delta: int
    P: int
    compile_time_s: float = 0.0  # trace+compile cost paid by THIS run (0 = warm)
    total_time_s: float = 0.0  # device execution wall time, compile excluded

    @property
    def avg_round_time_s(self) -> float:
        if self.round_times_s:
            return float(np.mean(self.round_times_s))
        return self.total_time_s / self.rounds if self.rounds else 0.0

    @classmethod
    def from_run(
        cls,
        sched: DeviceSchedule,
        semiring: Semiring,
        x_ext,
        *,
        rounds: int,
        converged: bool,
        residuals: list,
        round_times_s: list,
        compile_time_s: float = 0.0,
        total_time_s: float | None = None,
    ) -> "EngineResult":
        """Single authority for counter/timing semantics across every runner.

        ``flushes`` counts commit collectives actually executed — ``rounds·S``,
        including the round that detected convergence.  Timings are normalized
        so host-loop and fused-device runs compare like with like: compile cost
        is reported separately in ``compile_time_s`` (never folded into a round
        time), and ``total_time_s`` is post-compile execution wall time, so
        ``rounds · avg_round_time_s ≈ total_time_s`` on both paths.

        Matrix frontiers publish F values per row per commit, so
        ``flush_bytes`` scales by the feature width (``F = 1`` reduces to the
        historical vector accounting, byte for byte).
        """
        F = int(np.prod(np.shape(x_ext)[1:], dtype=np.int64))
        bytes_per = np.dtype(semiring.dtype).itemsize * max(F, 1)
        flushes = rounds * sched.S
        if total_time_s is None:
            total_time_s = float(np.sum(round_times_s)) if round_times_s else 0.0
        return cls(
            x=np.asarray(x_ext[:-1]),
            rounds=rounds,
            converged=converged,
            flushes=flushes,
            flush_bytes=flushes * sched.P * sched.delta * bytes_per,
            residuals=residuals,
            round_times_s=round_times_s,
            delta=sched.delta,
            P=sched.P,
            compile_time_s=compile_time_s,
            total_time_s=total_time_s,
        )


def run_host(
    sched: DeviceSchedule,
    semiring: Semiring,
    x0: np.ndarray,
    row_update: Callable,
    residual_fn: Callable,
    tol: float,
    max_rounds: int = 1000,
) -> EngineResult:
    """Host-driven loop: one jitted round per iteration, instrumented.

    ``residual_fn(x_prev, x_new) -> scalar``; converged when ``residual ≤ tol``.
    Used by benchmarks (per-round times/residuals like the paper's Table I).
    The round function is compiled ahead of the loop so every entry of
    ``round_times_s`` is a post-compile measurement.
    """
    x_ext = extend_frontier(x0, semiring)
    t0 = time.perf_counter()
    rnd = jax.jit(round_fn(sched, semiring, row_update)).lower(x_ext).compile()
    compile_time_s = time.perf_counter() - t0
    return host_loop(
        rnd,
        sched,
        semiring,
        x_ext,
        residual_fn,
        tol,
        max_rounds,
        compile_time_s=compile_time_s,
    )


def host_loop(
    rnd: Callable,
    sched: DeviceSchedule,
    semiring: Semiring,
    x_ext,
    residual_fn: Callable,
    tol: float,
    max_rounds: int,
    compile_time_s: float = 0.0,
) -> EngineResult:
    """The host-driven convergence loop over a compiled round ``x_ext -> x_ext``.

    Shared by :func:`run_host` and every :class:`repro.solve.Solver` backend
    that steps rounds from the host (host + sharded) — one copy of the
    timing/stopping semantics.
    """
    residuals, times = [], []
    converged = False
    rounds = 0
    for rounds in range(1, max_rounds + 1):
        # chaos hook at the natural recovery boundary: between committed
        # rounds, with `round` = rounds already executed (0-based)
        fire("solver.round", round=rounds - 1)
        t0 = time.perf_counter()
        x_new = rnd(x_ext)
        x_new.block_until_ready()
        times.append(time.perf_counter() - t0)
        res = float(residual_fn(x_ext[:-1], x_new[:-1]))
        residuals.append(res)
        x_ext = x_new
        if res <= tol:
            converged = True
            break
    return EngineResult.from_run(
        sched,
        semiring,
        x_ext,
        rounds=rounds,
        converged=converged,
        residuals=residuals,
        round_times_s=times,
        compile_time_s=compile_time_s,
    )


def execute_solve_fn(
    fn: Callable,
    sched: DeviceSchedule,
    semiring: Semiring,
    x_ext,
    q,
    tol: float,
    max_rounds: int,
    compile_time_s: float = 0.0,
) -> EngineResult:
    """Run a compiled fused loop and normalize its result.

    ``fn`` is a compiled :func:`make_solve_fn_q` (pass its ``q``) or
    :func:`make_solve_fn` (pass ``q=None``).  Shared by :func:`run_jit` and
    the Solver's jit backend — one copy of the execution/timing semantics.
    """
    tol_a = jnp.asarray(tol, jnp.float32)
    mr_a = jnp.asarray(max_rounds, jnp.int32)
    args = (x_ext, tol_a, mr_a) if q is None else (x_ext, q, tol_a, mr_a)
    t0 = time.perf_counter()
    x_out, res, rounds, converged = fn(*args)
    x_out.block_until_ready()
    total_time_s = time.perf_counter() - t0
    return EngineResult.from_run(
        sched,
        semiring,
        x_out,
        rounds=int(rounds),
        converged=bool(converged),
        residuals=[float(res)],
        round_times_s=[],
        compile_time_s=compile_time_s,
        total_time_s=total_time_s,
    )


def run_jit(
    sched: DeviceSchedule,
    semiring: Semiring,
    x0: jnp.ndarray,
    row_update: Callable,
    residual_fn: Callable,
    tol: float,
    max_rounds: int = 1000,
) -> EngineResult:
    """Fully fused device loop (``lax.while_loop``) — production path."""
    x_ext = extend_frontier(x0, semiring)
    tol_a = jnp.asarray(tol, jnp.float32)
    mr_a = jnp.asarray(max_rounds, jnp.int32)
    jitted = jax.jit(make_solve_fn(sched, semiring, row_update, residual_fn))
    t0 = time.perf_counter()
    fn = jitted.lower(x_ext, tol_a, mr_a).compile()
    compile_time_s = time.perf_counter() - t0
    return execute_solve_fn(
        fn, sched, semiring, x_ext, None, tol, max_rounds, compile_time_s=compile_time_s
    )
