"""The delayed-asynchronous iterative engine (the paper's contribution).

One *round* processes every vertex once, in ``S`` **commit steps**.  Commit
step ``s`` computes, for every worker in parallel, the pull-update of chunk
``s`` (δ rows) of that worker's block reading the *current committed* frontier,
then publishes all workers' chunks to the frontier simultaneously.  This is a
deterministic block Gauss–Seidel schedule with commit period δ — the TPU-native
semantics of the paper's thread-local buffer flush (DESIGN.md §2, §5):

* ``S == 1``   (δ = block size)  → exact Jacobi          = paper's *synchronous*
* ``S == B/δ_min`` (finest δ)    → finest block GS       = paper's *asynchronous*
* in between                     → *delayed asynchronous* (the hybrid)

The engine is mode-free: the mode IS the schedule's δ.  Counters for flushes
and flush bytes (the TPU analogue of cache-line invalidation traffic) are
reported on every run.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.semiring import Semiring
from repro.graphs.formats import CSRGraph, StripeSchedule, build_stripe_schedule
from repro.graphs.partition import balanced_blocks

__all__ = [
    "EngineResult",
    "DeviceSchedule",
    "make_schedule",
    "round_fn",
    "run_host",
    "run_jit",
    "MIN_CHUNK",
]

# Finest vectorizable commit granularity (DESIGN.md §2): the TPU analogue of
# the paper's one-cache-line δ=16.  One VPU lane row = 128 elements.
MIN_CHUNK = 128


@dataclasses.dataclass(frozen=True)
class DeviceSchedule:
    """StripeSchedule moved to device (jnp arrays) + metadata."""

    n: int
    P: int
    delta: int
    S: int
    M: int
    src: jnp.ndarray  # (S, P, M) int32
    val: jnp.ndarray  # (S, P, M)
    dst_local: jnp.ndarray  # (S, P, M) int32
    rows: jnp.ndarray  # (S, P, delta) int32
    edges: int
    padding_overhead: float

    @property
    def n_slots(self) -> int:
        return self.n + 1


def make_schedule(
    graph: CSRGraph,
    P: int,
    delta: int | None,
    semiring: Semiring,
    mode: str = "delayed",
    min_chunk: int = MIN_CHUNK,
) -> DeviceSchedule:
    """Build the device schedule for ``mode`` ∈ {sync, async, delayed}.

    * ``sync``    → δ = max block size (one commit per round).
    * ``async``   → δ = ``min_chunk`` (finest vectorizable commit).
    * ``delayed`` → δ as given (the paper's tunable).
    """
    bounds = balanced_blocks(graph, P)
    B = int(np.diff(bounds).max())
    if mode == "sync":
        delta_eff = B
    elif mode == "async":
        delta_eff = min(min_chunk, B)
    elif mode == "delayed":
        assert delta is not None, "delayed mode needs δ"
        delta_eff = int(min(max(delta, 1), B))
    else:
        raise ValueError(f"unknown mode {mode!r}")
    host = build_stripe_schedule(graph, bounds, delta_eff, semiring.pad_edge_val)
    return DeviceSchedule(
        n=host.n,
        P=host.P,
        delta=host.delta,
        S=host.S,
        M=host.M,
        src=jnp.asarray(host.src),
        val=jnp.asarray(host.val),
        dst_local=jnp.asarray(host.dst_local),
        rows=jnp.asarray(host.rows),
        edges=host.edges,
        padding_overhead=host.padding_overhead,
    )


def _commit_step(s, x_ext, sched: DeviceSchedule, semiring: Semiring, row_update):
    """One commit step: chunk-SpMV for all workers + publish."""
    P, delta = sched.P, sched.delta
    src_s = jax.lax.dynamic_index_in_dim(sched.src, s, 0, keepdims=False)
    val_s = jax.lax.dynamic_index_in_dim(sched.val, s, 0, keepdims=False)
    dst_s = jax.lax.dynamic_index_in_dim(sched.dst_local, s, 0, keepdims=False)
    rows_s = jax.lax.dynamic_index_in_dim(sched.rows, s, 0, keepdims=False)

    gathered = x_ext[src_s]  # (P, M) — reads the committed frontier
    contrib = semiring.mul(gathered, val_s)  # (P, M)
    # Per-worker segment-⊕ into δ + 1 slots (last = padding dump).
    seg = dst_s + (jnp.arange(P, dtype=jnp.int32) * (delta + 1))[:, None]
    reduced = semiring.segment_reduce(
        contrib.reshape(-1), seg.reshape(-1), P * (delta + 1)
    ).reshape(P, delta + 1)[:, :delta]
    old = x_ext[rows_s]  # (P, delta)
    new = row_update(old, reduced, rows_s)
    # Publish: the flush.  Padding rows all point at the dump slot (index n).
    return x_ext.at[rows_s.reshape(-1)].set(
        new.reshape(-1).astype(x_ext.dtype), mode="drop", unique_indices=False
    )


def round_fn(sched: DeviceSchedule, semiring: Semiring, row_update) -> Callable:
    """Return jit-able ``x_ext -> x_ext`` running one full round (S commits)."""

    def body(x_ext):
        step = partial(
            _commit_step, sched=sched, semiring=semiring, row_update=row_update
        )
        return jax.lax.fori_loop(0, sched.S, step, x_ext)

    return body


@dataclasses.dataclass
class EngineResult:
    x: np.ndarray  # (n,) converged vertex values
    rounds: int
    converged: bool
    flushes: int  # total commit collectives executed
    flush_bytes: int  # total bytes published to the global store
    residuals: list  # per-round convergence residuals
    round_times_s: list  # host-measured wall time per round (jitted round)
    delta: int
    P: int

    @property
    def avg_round_time_s(self) -> float:
        # Skip round 0 (compile) when more rounds exist.
        ts = self.round_times_s[1:] or self.round_times_s
        return float(np.mean(ts)) if ts else 0.0


def run_host(
    sched: DeviceSchedule,
    semiring: Semiring,
    x0: np.ndarray,
    row_update: Callable,
    residual_fn: Callable,
    tol: float,
    max_rounds: int = 1000,
) -> EngineResult:
    """Host-driven loop: one jitted round per iteration, instrumented.

    ``residual_fn(x_prev, x_new) -> scalar``; converged when ``residual ≤ tol``.
    Used by benchmarks (per-round times/residuals like the paper's Table I).
    """
    x_ext = jnp.concatenate(
        [jnp.asarray(x0, dtype=semiring.dtype), jnp.asarray([semiring.zero])]
    )
    rnd = jax.jit(round_fn(sched, semiring, row_update))
    residuals, times = [], []
    converged = False
    rounds = 0
    for rounds in range(1, max_rounds + 1):
        t0 = time.perf_counter()
        x_new = rnd(x_ext)
        x_new.block_until_ready()
        times.append(time.perf_counter() - t0)
        res = float(residual_fn(x_ext[:-1], x_new[:-1]))
        residuals.append(res)
        x_ext = x_new
        if res <= tol:
            converged = True
            break
    bytes_per = np.dtype(semiring.dtype).itemsize
    return EngineResult(
        x=np.asarray(x_ext[:-1]),
        rounds=rounds,
        converged=converged,
        flushes=rounds * sched.S,
        flush_bytes=rounds * sched.S * sched.P * sched.delta * bytes_per,
        residuals=residuals,
        round_times_s=times,
        delta=sched.delta,
        P=sched.P,
    )


def run_jit(
    sched: DeviceSchedule,
    semiring: Semiring,
    x0: jnp.ndarray,
    row_update: Callable,
    residual_fn: Callable,
    tol: float,
    max_rounds: int = 1000,
) -> EngineResult:
    """Fully fused device loop (``lax.while_loop``) — production path."""
    rnd = round_fn(sched, semiring, row_update)

    def cond(carry):
        _, res, rounds, converged = carry
        return jnp.logical_and(rounds < max_rounds, jnp.logical_not(converged))

    def body(carry):
        x_ext, _, rounds, _ = carry
        x_new = rnd(x_ext)
        res = residual_fn(x_ext[:-1], x_new[:-1]).astype(jnp.float32)
        return x_new, res, rounds + 1, res <= tol

    x_ext = jnp.concatenate(
        [jnp.asarray(x0, dtype=semiring.dtype), jnp.asarray([semiring.zero])]
    )
    init = (x_ext, jnp.asarray(np.inf, jnp.float32), jnp.asarray(0), jnp.asarray(False))
    x_ext, res, rounds, converged = jax.jit(
        lambda c: jax.lax.while_loop(cond, body, c)
    )(init)
    rounds = int(rounds)
    bytes_per = np.dtype(semiring.dtype).itemsize
    return EngineResult(
        x=np.asarray(x_ext[:-1]),
        rounds=rounds,
        converged=bool(converged),
        flushes=rounds * sched.S,
        flush_bytes=rounds * sched.S * sched.P * sched.delta * bytes_per,
        residuals=[float(res)],
        round_times_s=[],
        delta=sched.delta,
        P=sched.P,
    )
