# The paper's primary contribution: the delayed-asynchronous iterative
# engine (sync / async / delayed-δ hybrid execution of pull-style graph
# algorithms) plus its analysis tools (δ cost model, access matrices).
from repro.core.engine import (
    MIN_CHUNK,
    DeviceSchedule,
    EngineResult,
    make_schedule,
    make_solve_fn,
    make_solve_fn_q,
    round_fn,
    round_fn_q,
    run_host,
    run_jit,
)
from repro.core.semiring import INT_INF, MIN_PLUS, PLUS_TIMES, Semiring

__all__ = [
    "MIN_CHUNK",
    "DeviceSchedule",
    "EngineResult",
    "make_schedule",
    "make_solve_fn",
    "make_solve_fn_q",
    "round_fn",
    "round_fn_q",
    "run_host",
    "run_jit",
    "INT_INF",
    "MIN_PLUS",
    "PLUS_TIMES",
    "Semiring",
]
