"""minitron-8b — width-pruned nemotron.  [arXiv:2407.14679; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=16384,
    vocab=256000,
)


def reduced():
    return ModelConfig(
        name="minitron-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=2,
        d_ff=192,
        vocab=512,
        q_chunk=16,
        kv_chunk=16,
        remat=False,
    )
