"""recurrentgemma-9b — RG-LRU + local attention, pattern (rglru, rglru, attn).
[arXiv:2402.19427; unverified]
38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, window=2048.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    window=2048,
    pattern=("rglru", "rglru", "attn"),
    lru_width=4096,
    act="gelu",
)


def reduced():
    return ModelConfig(
        name="recurrentgemma-reduced",
        family="hybrid",
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv=1,
        d_ff=128,
        vocab=256,
        head_dim=16,
        window=32,
        pattern=("rglru", "rglru", "attn"),
        lru_width=64,
        act="gelu",
        q_chunk=16,
        kv_chunk=16,
        remat=False,
    )
