"""granite-8b — llama-arch code model.  [arXiv:2405.04324; hf]
36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=49152,
    rope_theta=1e7,
)


def reduced():
    return ModelConfig(
        name="granite-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=2,
        d_ff=128,
        vocab=256,
        q_chunk=16,
        kv_chunk=16,
        remat=False,
    )
