"""qwen2-vl-7b — M-RoPE, dynamic-resolution VLM (backbone only; vision
frontend stubbed to precomputed patch embeddings).  [arXiv:2409.12191; hf]
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv=4,
    d_ff=18944,
    vocab=152064,
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
)


def reduced():
    return ModelConfig(
        name="qwen2-vl-reduced",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=2,
        d_ff=128,
        vocab=256,
        mrope_sections=(4, 2, 2),
        q_chunk=16,
        kv_chunk=16,
        remat=False,
    )
