"""qwen3-moe-30b-a3b — 128 experts, top-8, qk-norm, head_dim=128.
[hf:Qwen/Qwen3-30B-A3B; hf]
48L d_model=2048 32H (GQA kv=4) d_ff=768 (per-expert) vocab=151936.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv=4,
    d_ff=768,
    vocab=151936,
    n_experts=128,
    top_k=8,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
)


def reduced():
    return ModelConfig(
        name="qwen3-moe-reduced",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=2,
        d_ff=64,
        vocab=256,
        n_experts=8,
        top_k=2,
        head_dim=16,
        qk_norm=True,
        q_chunk=16,
        kv_chunk=16,
        remat=False,
    )
