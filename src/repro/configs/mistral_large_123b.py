"""mistral-large-123b.  [hf:mistralai/Mistral-Large-Instruct-2407; unverified]
88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768, head_dim=128.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv=8,
    d_ff=28672,
    vocab=32768,
    head_dim=128,
    rope_theta=1e6,
)


def reduced():
    return ModelConfig(
        name="mistral-large-reduced",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=8,
        n_kv=2,
        d_ff=160,
        vocab=256,
        head_dim=8,
        q_chunk=16,
        kv_chunk=16,
        remat=False,
    )
