"""Architecture registry: ``--arch <id>`` resolves here.

Each module defines ``CONFIG`` (exact assigned configuration) and
``reduced()`` (a small same-family config for CPU smoke tests).  Input shapes
are defined in :mod:`repro.configs.shapes`.
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "mamba2_1p3b",
    "qwen2_vl_7b",
    "granite_8b",
    "minicpm_2b",
    "minitron_8b",
    "mistral_large_123b",
    "phi3p5_moe_42b",
    "qwen3_moe_30b",
    "recurrentgemma_9b",
    "whisper_base",
]

# external ids (hyphen form) → module names
ALIASES = {i.replace("_", "-").replace("p", "."): i for i in ARCH_IDS}
ALIASES.update({i: i for i in ARCH_IDS})
ALIASES.update(
    {
        "mamba2-1.3b": "mamba2_1p3b",
        "qwen2-vl-7b": "qwen2_vl_7b",
        "granite-8b": "granite_8b",
        "minicpm-2b": "minicpm_2b",
        "minitron-8b": "minitron_8b",
        "mistral-large-123b": "mistral_large_123b",
        "phi3.5-moe-42b-a6.6b": "phi3p5_moe_42b",
        "qwen3-moe-30b-a3b": "qwen3_moe_30b",
        "recurrentgemma-9b": "recurrentgemma_9b",
        "whisper-base": "whisper_base",
    }
)


def get_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{ALIASES[arch]}")
    return mod.CONFIG


def get_reduced(arch: str):
    mod = importlib.import_module(f"repro.configs.{ALIASES[arch]}")
    return mod.reduced()


def all_arch_ids():
    return list(ARCH_IDS)
