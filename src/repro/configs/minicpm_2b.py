"""minicpm-2b — llama-like, trained with the WSD schedule (implemented in
repro.train.optimizer).  [arXiv:2404.06395; hf]
40L d_model=2304 36H (kv=36, MHA) d_ff=5760 vocab=122753.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv=36,
    d_ff=5760,
    vocab=122753,
    tie_embeddings=True,
)


def reduced():
    return ModelConfig(
        name="minicpm-reduced",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=6,
        n_kv=6,
        d_ff=96,
        vocab=256,
        tie_embeddings=True,
        q_chunk=16,
        kv_chunk=16,
        remat=False,
    )
