"""whisper-base — encoder-decoder; conv frontend stubbed to precomputed frame
embeddings (input_specs).  [arXiv:2212.04356; unverified]
6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    encoder_layers=6,
    enc_seq=1500,
    d_model=512,
    n_heads=8,
    n_kv=8,
    d_ff=2048,
    vocab=51865,
    act="gelu",
    scan_layers=False,
)


def reduced():
    return ModelConfig(
        name="whisper-reduced",
        family="encdec",
        n_layers=2,
        encoder_layers=2,
        enc_seq=32,
        d_model=64,
        n_heads=4,
        n_kv=4,
        d_ff=128,
        vocab=256,
        act="gelu",
        scan_layers=False,
        q_chunk=16,
        kv_chunk=16,
        remat=False,
    )
