"""mamba2-1.3b — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]  48L d_model=2048 d_ff=0 vocab=50280 ssm_state=128.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    tie_embeddings=True,
)


def reduced():
    return ModelConfig(
        name="mamba2-reduced",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=0,
        n_kv=0,
        d_ff=0,
        vocab=256,
        ssm_state=16,
        ssm_headdim=16,
        ssm_expand=2,
        ssm_chunk=8,
        tie_embeddings=True,
        remat=False,
    )
