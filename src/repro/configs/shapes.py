"""Assigned input-shape set (LM-family: seq_len × global_batch).

``train_*`` lowers train_step; ``prefill_*`` lowers the prefill serve step;
``decode_*`` / ``long_*`` lower the single-token decode step with a KV cache
(or recurrent state) of the given context length.  ``long_500k`` requires
sub-quadratic attention → only SSM/hybrid archs run it (DESIGN.md §6).
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# families that can run long_500k (sub-quadratic context handling)
LONG_OK_FAMILIES = {"ssm", "hybrid"}


def applicable_shapes(family: str):
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if family in LONG_OK_FAMILIES:
        out.append("long_500k")
    return out
