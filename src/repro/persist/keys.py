"""Content-addressed cache keys: what makes a persisted entry *safe* to reuse.

The store never trusts a path: every namespace is derived from the content it
caches results for, so a stale or mismatched entry is a **miss**, never a
wrong answer.  A solver namespace hashes together

* the **graph content** (indptr / indices / values bytes — the schedule graph,
  i.e. after any ``Problem.edge_values`` override);
* the **problem fingerprint** — name, tolerance, semiring, and a digest of the
  row-update's *traced jaxpr including its closure constants* (so two Jacobi
  problems with different right-hand sides never share executables);
* the solver shape knobs (``n_workers``, ``partition_method``, ``min_chunk``);
* the solver's effective ``tol``/``max_rounds`` (constructor overrides
  applied), so different convergence regimes never share a δ-model;
* the **environment** (cache format, repro / jax / numpy versions) — a version
  bump silently retires every old namespace.

Known limit: *source edits* to schedule/engine construction code are not
content-hashed (package version strings don't change in a dev checkout, and
``PYTHONPATH=src`` runs pin the fallback version), so after changing how
schedules or rounds are *built*, bump :data:`CACHE_FORMAT` to retire every
persisted entry — that is what the constant is for.

Anything not captured by the namespace (δ, backend, frontier, mesh width,
argument shapes) is keyed per entry inside the namespace by
:mod:`repro.persist.store`.
"""

from __future__ import annotations

import hashlib

import jax
import numpy as np

__all__ = [
    "CACHE_FORMAT",
    "env_fingerprint",
    "graph_fingerprint",
    "plan_shard_fingerprint",
    "problem_fingerprint",
    "row_update_digest",
    "solver_namespace",
    "stripe_fingerprint",
]

# Bump to retire every existing cache entry (layout or semantics change).
# 2: FrontierPlan src_loc/rows_loc went shard-major (D, S, P_loc, ·).
CACHE_FORMAT = 2

try:  # installed package
    import importlib.metadata

    _REPRO_VERSION = importlib.metadata.version("repro")
except Exception:  # pragma: no cover - PYTHONPATH runs carry no dist metadata
    _REPRO_VERSION = "0.1.0"


def env_fingerprint() -> str:
    """The toolchain part of every namespace key (mismatch ⇒ cold build)."""
    return (
        f"format{CACHE_FORMAT}-repro{_REPRO_VERSION}"
        f"-jax{jax.__version__}-numpy{np.__version__}"
    )


def _digest(*parts: bytes) -> str:
    h = hashlib.sha256()
    for p in parts:
        h.update(p)
        h.update(b"\x00")  # unambiguous part boundaries
    return h.hexdigest()


def graph_fingerprint(graph) -> str:
    """Content hash of a :class:`~repro.graphs.formats.CSRGraph` (not its name)."""
    return _digest(
        str(graph.n).encode(),
        str(graph.indptr.dtype).encode(),
        np.ascontiguousarray(graph.indptr).tobytes(),
        str(graph.indices.dtype).encode(),
        np.ascontiguousarray(graph.indices).tobytes(),
        str(graph.values.dtype).encode(),
        np.ascontiguousarray(graph.values).tobytes(),
    )


def stripe_fingerprint(graph, lo: int, hi: int, S: int, delta: int, pad_val) -> str:
    """Content key of one worker stripe — the unit of evolve-aware reuse.

    Hashes exactly what :func:`repro.graphs.formats.build_worker_stripe`
    reads: the block's *relative* indptr slice plus its in-edge sources and
    values, the global ``n`` (source ids and the dump row reference it), the
    shape knobs ``(S, delta)``, the pad value/dtype, and the environment.
    Two graphs that differ only outside ``[lo, hi)`` produce the same digest
    for this block, which is what lets a mutated graph's schedule reuse every
    untouched stripe from the shared store.
    """
    indptr = np.asarray(graph.indptr)
    e0, e1 = int(indptr[lo]), int(indptr[hi])
    rel_ptr = indptr[lo : hi + 1] - e0
    return _digest(
        env_fingerprint().encode(),
        str(int(graph.n)).encode(),
        str(int(lo)).encode(),
        str(int(hi)).encode(),
        str(int(S)).encode(),
        str(int(delta)).encode(),
        repr(pad_val).encode(),
        str(graph.values.dtype).encode(),
        np.ascontiguousarray(rel_ptr).tobytes(),
        np.ascontiguousarray(graph.indices[e0:e1]).tobytes(),
        np.ascontiguousarray(graph.values[e0:e1]).tobytes(),
    )


def plan_shard_fingerprint(sched, vb_lo: int, vb_hi: int, w0: int, w1: int) -> str:
    """Content key of one frontier-plan shard piece (workers ``[w0, w1)``).

    Hashes what :func:`repro.dist.engine_sharded.build_plan_shard` reads: the
    shard's slices of the schedule's ``src``/``dst_local``/``rows`` arrays,
    its owned vertex interval, and ``(n, delta)``.  The shard-local index
    arrays (halo, src_loc, rows_loc) depend on nothing else, so a mutation
    that leaves these workers' stripes byte-identical reuses the piece.
    """
    return _digest(
        env_fingerprint().encode(),
        str(int(sched.n)).encode(),
        str(int(sched.delta)).encode(),
        str(int(vb_lo)).encode(),
        str(int(vb_hi)).encode(),
        np.ascontiguousarray(np.asarray(sched.src)[:, w0:w1]).tobytes(),
        np.ascontiguousarray(np.asarray(sched.dst_local)[:, w0:w1]).tobytes(),
        np.ascontiguousarray(np.asarray(sched.rows)[:, w0:w1]).tobytes(),
    )


def row_update_digest(row_update_q, semiring, q_template, feature_dim: int = 1) -> str:
    """Digest of the row update's traced jaxpr **plus closure constants**.

    ``row_update_q`` is the normalized 4-arg form
    ``(old, reduced, rows, q) -> new``.  Tracing with tiny abstract row blocks
    captures the update's computation graph and hoists its closure constants
    (Jacobi's ``b/diag`` table, PageRank's teleport scalar) into ``consts`` —
    both are hashed, so problems that differ only in baked-in data get
    distinct namespaces.  Untraceable updates degrade to a sentinel (their
    problems then only share entries with themselves via name/tol/semiring).

    ``feature_dim > 1`` traces with a trailing feature axis — matrix-frontier
    updates (row-normalizing label propagation, per-column RWR) see the rank
    they will run at; ``feature_dim == 1`` keeps the historical trace shapes,
    so every pre-existing vector digest is unchanged.
    """
    sds = jax.ShapeDtypeStruct
    dt = np.dtype(semiring.dtype)
    feat = (int(feature_dim),) if feature_dim > 1 else ()
    args = (
        sds((2, 3) + feat, dt),
        sds((2, 3) + feat, dt),
        sds((2, 3), np.int32),
        jax.tree_util.tree_map(
            lambda a: sds(np.shape(a), np.asarray(a).dtype), q_template
        ),
    )
    try:
        closed = jax.make_jaxpr(row_update_q)(*args)
    except Exception:
        return "untraceable"
    h = hashlib.sha256(str(closed.jaxpr).encode())
    for c in closed.consts:
        arr = np.asarray(c)
        h.update(str(arr.dtype).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def problem_fingerprint(problem, row_update_q, semiring, q_template) -> str:
    """Fingerprint of a :class:`~repro.solve.problem.Problem` instance.

    Matrix problems (``feature_dim > 1``) contribute an extra ``F<dim>`` part
    and trace the row update at matrix rank; vector problems hash exactly the
    historical parts, so existing on-disk namespaces stay warm.
    """
    feature_dim = int(getattr(problem, "feature_dim", 1))
    parts = [
        problem.name.encode(),
        repr(float(problem.tol)).encode(),
        str(int(problem.max_rounds)).encode(),
        str(np.dtype(semiring.dtype)).encode(),
        repr(semiring.zero).encode(),
        str(bool(problem.takes_query)).encode(),
        row_update_digest(
            row_update_q, semiring, q_template, feature_dim=feature_dim
        ).encode(),
    ]
    if feature_dim > 1:
        parts.append(f"F{feature_dim}".encode())
    return _digest(*parts)


def solver_namespace(
    graph,
    problem,
    row_update_q,
    q_template,
    n_workers: int,
    partition_method: str,
    min_chunk: int,
    tol: float,
    max_rounds: int,
) -> str:
    """The namespace key one Solver's persisted entries live under.

    ``tol``/``max_rounds`` are the solver's *effective* values (constructor
    overrides applied) — two solvers on one problem with different
    convergence regimes must not share a δ-model or observation log.
    """
    return _digest(
        env_fingerprint().encode(),
        graph_fingerprint(graph).encode(),
        problem_fingerprint(
            problem, row_update_q, problem.semiring, q_template
        ).encode(),
        str(int(n_workers)).encode(),
        partition_method.encode(),
        str(int(min_chunk)).encode(),
        repr(float(tol)).encode(),
        str(int(max_rounds)).encode(),
    )
