# Persistent warm-start caches: content-addressed on-disk storage for
# everything a Solver otherwise recomputes per process — stripe schedules,
# frontier halo plans, the fitted δ-model, and AOT-exported executables —
# plus the production (δ, rounds, time) observation log that online δ
# re-probing refits from.  See persist/keys.py for what makes an entry safe.
from repro.persist.keys import (
    CACHE_FORMAT,
    env_fingerprint,
    graph_fingerprint,
    problem_fingerprint,
    row_update_digest,
    solver_namespace,
)
from repro.persist.store import SolverCache

__all__ = [
    "CACHE_FORMAT",
    "SolverCache",
    "env_fingerprint",
    "graph_fingerprint",
    "problem_fingerprint",
    "row_update_digest",
    "solver_namespace",
]
