"""The versioned on-disk solver cache: schedules, plans, δ-model, executables.

Layout (one namespace directory per solver content key, see
:mod:`repro.persist.keys`)::

    <cache_dir>/v<CACHE_FORMAT>/<namespace[:16]>/
        meta.json             human-readable key anatomy (debugging only)
        sched_d<δ>.npz        DeviceSchedule stripe arrays
        plan_d<δ>_D<D>.npz    FrontierPlan halo indices per mesh width
        exec_<digest>.bin     jax.export blob per (key, arg shapes/dtypes)
        delta_model.json      fitted DeltaModel + the δ* currently served
        observations.jsonl    (δ, rounds, time) from production EngineResults

Every write is atomic (tmp file + ``os.replace``) so a killed process never
leaves a truncated entry; every load is wrapped so a corrupt, partial, or
foreign entry is a **miss** (the caller rebuilds cold and overwrites), never
an exception on the solve path and never a wrong answer.  Entries are safe to
share between hosts with the same jax/numpy versions; the executable blobs
additionally assume the same platform (they are skipped, not trusted, when
they fail to deserialize).
"""

from __future__ import annotations

import errno
import hashlib
import io
import itertools
import json
import os
import threading
from pathlib import Path

import jax
import numpy as np

from repro.core.delta_model import DeltaModel
from repro.core.engine import DeviceSchedule
from repro.dist.compat import export_deserialize, export_serialize
from repro.ft.inject import fire
from repro.persist.keys import (
    CACHE_FORMAT,
    env_fingerprint,
    graph_fingerprint,
    problem_fingerprint,
    solver_namespace,
)

__all__ = ["SolverCache"]

# tmp names are unique per (pid, thread, write): two *threads* of one process
# used to share a pid-only tmp name, so one thread's write_bytes could land in
# a file the other was about to os.replace — a torn entry under a valid name.
_TMP_COUNTER = itertools.count()
# serializes the observation log's check-compact-append sequence per process
_OBS_LOCK = threading.Lock()


def _atomic_write_bytes(path: Path, data: bytes) -> None:
    """Crash- and race-safe publish: unique tmp + fsync + atomic replace.

    Concurrent writers of one key are last-writer-wins: each stages into its
    own tmp file and publishes with a single ``os.replace``, so a concurrent
    reader sees the old complete entry or the new complete entry, never a
    mix; the fsync before replace means the rename can never promote
    still-unwritten bytes after a crash.
    """
    kind = fire("persist.write", key=path.name)
    if kind == "eio":
        raise OSError(errno.EIO, f"injected EIO writing {path.name}")
    if kind == "corrupt":  # bit-flip the head: loaders must treat it as a miss
        data = bytes(b ^ 0xFF for b in data[:64]) + data[64:]
    if kind == "torn":  # a kill mid-write: only a prefix reaches the tmp file
        data = data[: max(1, len(data) // 2)]
    tmp = path.with_name(
        f"{path.name}.tmp-{os.getpid()}-{threading.get_ident()}-{next(_TMP_COUNTER)}"
    )
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _read_fault(path: Path) -> None:
    """Chaos hook for the load path; called inside each loader's try block so
    an injected read fault surfaces as a cache miss, never an exception."""
    kind = fire("persist.read", key=path.name)
    if kind is not None:
        raise OSError(errno.EIO, f"injected {kind} fault reading {path.name}")


def _save_npz(path: Path, arrays: dict) -> None:
    """Best-effort atomic ``np.savez``; a full disk degrades, never raises."""
    try:
        buf = io.BytesIO()
        np.savez(buf, **arrays)
        _atomic_write_bytes(path, buf.getvalue())
    except OSError:  # pragma: no cover - best-effort persistence
        pass


class SolverCache:
    """One solver's persisted entries under a content-derived namespace.

    Construct via :meth:`for_solver`; all ``load_*`` methods return ``None``
    on any miss/mismatch/corruption, all ``save_*`` methods are atomic and
    best-effort (a full disk degrades to a process-local cache, it does not
    break solving).
    """

    def __init__(self, root, namespace: str, meta: dict | None = None):
        self.root = Path(root)
        self.namespace = namespace
        self.dir = self.root / f"v{CACHE_FORMAT}" / namespace[:16]
        try:
            self.dir.mkdir(parents=True, exist_ok=True)
        except OSError:
            # read-only mount / full disk: every load below misses and every
            # save is a no-op — the solver degrades to its process-local cache
            return
        meta_path = self.dir / "meta.json"
        if meta is not None and not meta_path.exists():
            try:
                _atomic_write_bytes(
                    meta_path, json.dumps(meta, indent=1).encode()
                )
            except OSError:  # pragma: no cover - best-effort debug aid
                pass

    @classmethod
    def for_solver(
        cls,
        root,
        graph,
        problem,
        row_update_q,
        q_template,
        n_workers: int,
        partition_method: str,
        min_chunk: int,
        tol: float,
        max_rounds: int,
    ) -> "SolverCache":
        """The namespace for one ``(graph, problem, shape knobs)`` binding.

        ``graph`` must be the *schedule* graph (edge-value overrides applied)
        so e.g. CC's zeroed weights and SSSP's lengths hash differently;
        ``tol``/``max_rounds`` are the solver's effective values.
        """
        ns = solver_namespace(
            graph, problem, row_update_q, q_template,
            n_workers, partition_method, min_chunk, tol, max_rounds,
        )
        meta = {
            "env": env_fingerprint(),
            "graph": graph.name,
            "graph_fingerprint": graph_fingerprint(graph)[:16],
            "problem": problem.name,
            "problem_fingerprint": problem_fingerprint(
                problem, row_update_q, problem.semiring, q_template
            )[:16],
            "n_workers": int(n_workers),
            "partition_method": partition_method,
            "min_chunk": int(min_chunk),
            "tol": float(tol),
            "max_rounds": int(max_rounds),
        }
        return cls(root, ns, meta)

    # ------------------------------------------------------------------ #
    # stripe schedules
    # ------------------------------------------------------------------ #
    def _sched_path(self, delta: int) -> Path:
        return self.dir / f"sched_d{int(delta)}.npz"

    def save_schedule(self, sched: DeviceSchedule) -> None:
        _save_npz(self._sched_path(sched.delta), sched.to_host_arrays())

    def load_schedule(self, delta: int) -> DeviceSchedule | None:
        path = self._sched_path(delta)
        try:
            _read_fault(path)
            with np.load(path, allow_pickle=False) as arrays:
                sched = DeviceSchedule.from_host_arrays(arrays)
            if sched.delta != int(delta):
                return None
            return sched
        except Exception:
            return None

    # ------------------------------------------------------------------ #
    # shared per-worker stripes (content-addressed, cross-namespace)
    # ------------------------------------------------------------------ #
    # Stripes live OUTSIDE the namespace on purpose: a graph mutation changes
    # the namespace (it hashes the whole graph), so per-namespace stripe
    # storage would never be warm after an update.  The digest alone proves
    # reusability (it hashes the block's own edge content + env), making the
    # shared directory safe across graphs, problems, and solvers.

    def _stripe_path(self, digest: str) -> Path:
        return self.root / f"v{CACHE_FORMAT}" / "stripes" / f"{digest[:24]}.npz"

    def save_stripe(self, digest: str, stripe: dict) -> None:
        """Persist one worker stripe under its content digest (atomic)."""
        path = self._stripe_path(digest)
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
        except OSError:  # pragma: no cover - best-effort persistence
            return
        _save_npz(path, stripe)

    def load_stripe(self, digest: str) -> dict | None:
        """The stripe dict for ``digest`` or ``None`` (corruption ⇒ miss)."""
        try:
            _read_fault(self._stripe_path(digest))
            with np.load(self._stripe_path(digest), allow_pickle=False) as arrays:
                out = {k: np.asarray(arrays[k]) for k in arrays.files}
            if not {"src", "val", "dst_local", "rows"} <= out.keys():
                return None
            return out
        except Exception:
            return None

    # ------------------------------------------------------------------ #
    # shared frontier-plan shard pieces (content-addressed, cross-namespace)
    # ------------------------------------------------------------------ #
    def _plan_shard_path(self, digest: str) -> Path:
        return self.root / f"v{CACHE_FORMAT}" / "planshards" / f"{digest[:24]}.npz"

    def save_plan_shard(self, digest: str, piece: dict) -> None:
        path = self._plan_shard_path(digest)
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
        except OSError:  # pragma: no cover - best-effort persistence
            return
        _save_npz(path, piece)

    def load_plan_shard(self, digest: str) -> dict | None:
        try:
            _read_fault(self._plan_shard_path(digest))
            with np.load(self._plan_shard_path(digest), allow_pickle=False) as arrays:
                out = {k: np.asarray(arrays[k]) for k in arrays.files}
            if not {"halo", "src_loc", "rows_loc"} <= out.keys():
                return None
            return out
        except Exception:
            return None

    # ------------------------------------------------------------------ #
    # frontier halo plans
    # ------------------------------------------------------------------ #
    def _plan_path(self, delta: int, D: int) -> Path:
        return self.dir / f"plan_d{int(delta)}_D{int(D)}.npz"

    def save_plan(self, plan) -> None:
        _save_npz(self._plan_path(plan.delta, plan.D), plan.to_host_arrays())

    def load_plan(self, delta: int, D: int):
        from repro.dist.engine_sharded import FrontierPlan

        try:
            _read_fault(self._plan_path(delta, D))
            with np.load(self._plan_path(delta, D), allow_pickle=False) as arrays:
                plan = FrontierPlan.from_host_arrays(arrays)
            if plan.delta != int(delta) or plan.D != int(D):
                return None
            return plan
        except Exception:
            return None

    # ------------------------------------------------------------------ #
    # compiled round / loop executables (jax.export blobs)
    # ------------------------------------------------------------------ #
    @staticmethod
    def _exec_digest(key: tuple, args) -> str:
        h = hashlib.sha256(repr(key).encode())
        for leaf in jax.tree_util.tree_leaves(tuple(args)):
            # .dtype directly: np.asarray would copy device buffers to host
            # just to read a dtype string
            dt = leaf.dtype if hasattr(leaf, "dtype") else np.asarray(leaf).dtype
            h.update(f"{np.shape(leaf)}:{dt};".encode())
        return h.hexdigest()[:24]

    def _exec_path(self, key: tuple, args) -> Path:
        return self.dir / f"exec_{self._exec_digest(key, args)}.bin"

    def save_executable(self, key: tuple, fn, args) -> bool:
        """Export + persist ``fn`` for ``args``' shapes; False if not portable."""
        blob = export_serialize(fn, args)
        if blob is None:
            return False
        try:
            _atomic_write_bytes(self._exec_path(key, args), blob)
            return True
        except OSError:  # pragma: no cover - best-effort persistence
            return False

    def load_executable(self, key: tuple, args):
        """The deserialized jit-able callable for ``(key, args)``, or ``None``.

        The callable replays the exported StableHLO — compiling it never
        re-traces the Python that originally built the round, which is what
        keeps a warm process at zero retraces.
        """
        path = self._exec_path(key, args)
        try:
            _read_fault(path)
            blob = path.read_bytes()
        except OSError:
            return None
        return export_deserialize(blob)

    # ------------------------------------------------------------------ #
    # δ-model + production observations
    # ------------------------------------------------------------------ #
    def save_delta_model(
        self, model: DeltaModel, best_delta: int, regime: str = "cold"
    ) -> None:
        """Persist one regime's model, preserving the other regime's section.

        The cold regime keeps the legacy top-level keys (old caches stay
        readable); any other regime writes ``<regime>_model`` /
        ``<regime>_best_delta`` alongside.
        """
        path = self.dir / "delta_model.json"
        try:
            payload = json.loads(path.read_text())
        except Exception:
            payload = {}
        if regime == "cold":
            payload["best_delta"] = int(best_delta)
            payload["model"] = model.to_dict()
        else:
            payload[f"{regime}_best_delta"] = int(best_delta)
            payload[f"{regime}_model"] = model.to_dict()
        try:
            _atomic_write_bytes(path, json.dumps(payload, indent=1).encode())
        except OSError:  # pragma: no cover - best-effort persistence
            pass

    def load_delta_model(
        self, regime: str = "cold"
    ) -> tuple[DeltaModel, int] | None:
        """``(model, best_delta)`` for ``regime`` as last fitted, or ``None``."""
        try:
            _read_fault(self.dir / "delta_model.json")
            payload = json.loads((self.dir / "delta_model.json").read_text())
            if regime == "cold":
                model, best = payload["model"], payload["best_delta"]
            else:
                model = payload[f"{regime}_model"]
                best = payload[f"{regime}_best_delta"]
            return DeltaModel.from_dict(model), int(best)
        except Exception:
            return None

    # Compact the observation log once it exceeds this, keeping the newest
    # rows — bounds both the directory and reprobe_delta's refit cost for
    # arbitrarily long-lived services.
    _OBS_MAX_BYTES = 1 << 20
    _OBS_KEEP_ROWS = 4096

    def record_observation(
        self,
        delta: int,
        rounds: int,
        total_time_s: float,
        backend: str,
        kind: str = "solve",
        regime: str = "cold",
    ) -> None:
        """Append one production ``(δ, rounds, time)`` datapoint (JSONL).

        ``regime`` separates cold solves from incremental warm restarts —
        incremental round counts are far lower for the same δ, so mixing the
        regimes in one fit would bias both curves.
        """
        row = {
            "delta": int(delta),
            "rounds": int(rounds),
            "total_time_s": float(total_time_s),
            "backend": backend,
            "kind": kind,
            "regime": regime,
        }
        path = self.dir / "observations.jsonl"
        try:
            # the check-compact-append sequence is not atomic; the lock keeps
            # two in-process writers from interleaving a compaction with an
            # append (cross-process appends remain safe: O_APPEND semantics)
            with _OBS_LOCK:
                if path.exists() and path.stat().st_size > self._OBS_MAX_BYTES:
                    tail = self.load_observations()[-self._OBS_KEEP_ROWS :]
                    _atomic_write_bytes(
                        path, "".join(json.dumps(r) + "\n" for r in tail).encode()
                    )
                with open(path, "a") as f:
                    f.write(json.dumps(row) + "\n")
        except OSError:  # pragma: no cover - best-effort persistence
            pass

    def load_observations(self) -> list[dict]:
        """All readable observation rows (a truncated tail line is skipped)."""
        out = []
        try:
            text = (self.dir / "observations.jsonl").read_text()
        except OSError:
            return out
        for line in text.splitlines():
            try:
                row = json.loads(line)
                out.append(
                    {
                        "delta": int(row["delta"]),
                        "rounds": int(row["rounds"]),
                        "total_time_s": float(row["total_time_s"]),
                        "backend": row.get("backend", "?"),
                        "kind": row.get("kind", "solve"),
                        "regime": row.get("regime", "cold"),
                    }
                )
            except (ValueError, KeyError, TypeError):
                continue  # partial write from a killed process: skip the line
        return out
