"""ShapeDtypeStruct input stand-ins + shardings for every (arch × shape) cell.

``input_specs`` returns weak-type-correct, shardable stand-ins — no device
allocation — for the function each shape kind lowers:

* train  → ``train_step(state, batch)``
* prefill→ ``prefill_step(params, batch)``
* decode → ``decode_step(params, cache, tokens)``
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig
from repro.models.lm import init_cache_specs

F32 = jnp.float32
SDS = jax.ShapeDtypeStruct


def _sds_tree(tree):
    return jax.tree.map(
        lambda x: SDS(x.shape, x.dtype), tree, is_leaf=lambda x: hasattr(x, "shape")
    )


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, with_labels: bool):
    """Batch ShapeDtypeStructs (+ PartitionSpecs) for train/prefill."""
    B, S = shape.global_batch, shape.seq_len
    dtype = jnp.dtype(cfg.dtype)
    specs, shards = {}, {}
    if cfg.family == "vlm":
        specs["embeds"] = SDS((B, S, cfg.d_model), dtype)
        shards["embeds"] = P(("pod", "data"), None, None)
        specs["positions"] = SDS((B, 3, S), jnp.int32)
        shards["positions"] = P(("pod", "data"), None, None)
    else:
        specs["tokens"] = SDS((B, S), jnp.int32)
        shards["tokens"] = P(("pod", "data"), None)
    if cfg.family == "encdec":
        specs["frames"] = SDS((B, cfg.enc_seq, cfg.d_model), dtype)
        shards["frames"] = P(("pod", "data"), None, None)
    if with_labels:
        specs["labels"] = SDS((B, S), jnp.int32)
        shards["labels"] = P(("pod", "data"), None)
    return specs, shards


def cache_shardings(cfg: ModelConfig, cache_specs, rules) -> dict:
    """PartitionSpecs mirroring the cache pytree."""
    batch_ax = rules.mapping.get("cache_batch")
    kv_ax = rules.mapping.get("kv_heads")
    seq_ax = rules.mapping.get("cache_seq")

    def spec_for(kind, leaf_shape):
        if kind == "attn":  # (B, S, Hkv, hd) kv, or (B, S, Hkv) int8 scales
            if len(leaf_shape) == 3:
                return P(batch_ax, seq_ax, kv_ax)
            return P(batch_ax, seq_ax, kv_ax, None)
        if kind == "ssm":
            if len(leaf_shape) == 4:  # (B, H, P, N)
                return P(batch_ax, kv_ax, None, None)
            return P(batch_ax, None, None)  # conv (B, cw-1, C)
        if kind == "rglru":
            if len(leaf_shape) == 2:  # (B, W)
                return P(batch_ax, kv_ax)
            return P(batch_ax, None, None)
        return P(*([None] * len(leaf_shape)))

    layers = []
    for kind, lc in zip(cfg.layer_kinds, cache_specs["layers"]):
        layers.append(
            jax.tree.map(
                lambda leaf: spec_for(kind, leaf.shape),
                lc,
                is_leaf=lambda x: isinstance(x, SDS),
            )
        )
    out = {"layers": layers, "cur_len": P(batch_ax)}
    if "enc" in cache_specs:
        out["enc"] = P(batch_ax, None, None)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeSpec, rules):
    """(kind, arg-specs tuple, arg-shardings tuple) for the lowered function."""
    if shape.kind == "train":
        specs, shards = batch_specs(cfg, shape, with_labels=True)
        return "train", (specs,), (shards,)
    if shape.kind == "prefill":
        specs, shards = batch_specs(cfg, shape, with_labels=False)
        return "prefill", (specs,), (shards,)
    if shape.kind == "decode":
        B = shape.global_batch
        dtype = jnp.dtype(cfg.dtype)
        cache = init_cache_specs(cfg, B, shape.seq_len, dtype)
        cache_sh = cache_shardings(cfg, cache, rules)
        batch_ax = rules.mapping.get("cache_batch")
        if cfg.family == "vlm":
            tok = SDS((B, 1, cfg.d_model), dtype)
            tok_sh = P(batch_ax, None, None)
        else:
            tok = SDS((B, 1), jnp.int32)
            tok_sh = P(batch_ax, None)
        return "decode", (cache, tok), (cache_sh, tok_sh)
    raise ValueError(shape.kind)
