"""End-to-end training driver.

Examples::

    # tiny CPU run (reduced config), fault-tolerant loop
    PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \\
        --steps 50 --batch 8 --seq 128

    # delayed gradient commit (paper's technique at training scale)
    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --reduced \\
        --steps 50 --commit-delta 4 --n-pods 2
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.data.pipeline import SyntheticLM, make_encdec_batch, make_vlm_batch
from repro.dist.delayed_commit import (
    DelayedCommitConfig,
    init_delayed_state,
    make_delayed_commit_step,
)
from repro.ft.runner import FailureInjector, RunnerConfig, run_training
from repro.train.optimizer import AdamW, linear_warmup_cosine, wsd
from repro.train.train_step import init_train_state, make_train_step


def build_batch_fn(cfg, seq, batch, n_pods=0):
    data = SyntheticLM(vocab=cfg.vocab, seq_len=seq, global_batch=batch)

    def batch_fn(step):
        b = data.batch(step)
        if cfg.family == "vlm":
            b = make_vlm_batch(b, cfg.d_model)
        elif cfg.family == "encdec":
            b = make_encdec_batch(b, cfg.d_model, cfg.enc_seq)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        if n_pods:
            b = jax.tree.map(
                lambda x: x.reshape((n_pods, x.shape[0] // n_pods) + x.shape[1:]), b
            )
        return b

    return batch_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd"])
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--commit-delta", type=int, default=0,
                    help="δ for delayed gradient commit (0 = plain sync DP)")
    ap.add_argument("--n-pods", type=int, default=2)
    ap.add_argument("--compress", default="none", choices=["none", "int8", "topk"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    sched = (
        wsd(args.lr, warmup=args.steps // 10, stable=int(args.steps * 0.7),
            decay=max(args.steps // 5, 1))
        if args.schedule == "wsd"
        else linear_warmup_cosine(args.lr, warmup=args.steps // 10, total=args.steps)
    )
    opt = AdamW(schedule=sched)
    key = jax.random.PRNGKey(args.seed)

    if args.commit_delta > 0:
        if args.batch % args.n_pods:
            ap.error(
                f"--batch {args.batch} must be divisible by --n-pods {args.n_pods}"
            )
        cc = DelayedCommitConfig(
            n_pods=args.n_pods, delta=args.commit_delta, compress=args.compress
        )
        state = init_delayed_state(cfg, opt, cc, key)
        step_fn = jax.jit(make_delayed_commit_step(cfg, opt, cc))
        batch_fn = build_batch_fn(cfg, args.seq, args.batch, n_pods=args.n_pods)
    else:
        state = init_train_state(cfg, opt, key)
        step_fn = jax.jit(make_train_step(cfg, opt, accum_steps=args.accum))
        batch_fn = build_batch_fn(cfg, args.seq, args.batch)

    def on_metrics(step, metrics, dt):
        loss = float(metrics.get("total_loss", metrics.get("loss")))
        print(f"step {step:5d}  loss {loss:8.4f}  {dt*1e3:7.1f} ms/step", flush=True)

    rcfg = RunnerConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir
    )
    injector = FailureInjector(args.fail_at)
    t0 = time.time()
    state, hist = run_training(
        state, step_fn, batch_fn, rcfg, injector=injector, on_metrics=on_metrics
    )
    print(
        f"done: {args.steps} steps in {time.time()-t0:.1f}s — "
        f"loss {hist['loss'][0]:.4f} → {hist['loss'][-1]:.4f}, "
        f"restarts={hist['restarts']} stragglers={hist['stragglers']} "
        f"ckpts={hist['ckpts']}"
    )
    return hist


if __name__ == "__main__":
    main()
