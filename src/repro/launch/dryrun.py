"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all            # full sweep, resumable

Results (memory analysis, cost analysis, per-collective bytes) are written to
``results/dryrun/<arch>__<shape>__<mesh>.json`` — benchmarks/roofline.py reads
them.  Cells that already have a result are skipped (incremental resume).
"""

# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; jax locks
# the device count on first init, so this must precede every other import.
# setdefault, not assignment: callers (CI smoke-bench, tests) may have pinned
# a smaller device count already.
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import re
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ALIASES, all_arch_ids, get_config
from repro.configs.shapes import SHAPES, applicable_shapes
from repro.dist.compat import cost_analysis, set_mesh
from repro.dist.sharding import Rules, tree_param_specs, use_rules
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models import decode_step, init_params, prefill
from repro.models.config import ModelConfig
from repro.train.optimizer import AdamW, constant
from repro.train.train_step import TrainState, init_train_state, make_train_step

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8,
    "f32": 4,
    "f16": 2,
    "bf16": 2,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
    "s64": 8,
    "u64": 8,
    "s32": 4,
    "u32": 4,
    "s16": 2,
    "u16": 2,
    "s8": 1,
    "u8": 1,
    "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start)?\("
)


def _bytes_of_type(tstr: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(tstr):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the HLO."""
    per_kind = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        tstr, kind = m.group(1), m.group(2)
        b = _bytes_of_type(tstr)
        per_kind[kind]["count"] += 1
        per_kind[kind]["bytes"] += b
    total = sum(v["bytes"] for v in per_kind.values())
    return {"per_kind": per_kind, "total_bytes": total}


def rules_for(cfg: ModelConfig, mesh, kind: str = "train") -> Rules:
    axes = set(mesh.axis_names)
    model_ok = "model" in axes
    # shard kv cache over heads when they divide the model axis; else over seq
    model_size = mesh.shape["model"] if model_ok else 1
    shard_heads = cfg.n_kv > 0 and model_ok and cfg.n_kv % model_size == 0
    # sequence parallelism for train/prefill: residual-stream activations are
    # sharded over "model" between blocks (Megatron-SP); decode has seq = 1.
    seq_axis = "model" if kind in ("train", "prefill") else None
    return Rules.default(shard_cache_heads=shard_heads, seq_axis=seq_axis)


def _filter_spec(spec, axes: set):
    def f(a):
        if a is None:
            return None
        if isinstance(a, (tuple, list)):
            t = tuple(x for x in a if x in axes)
            return t if t else None
        return a if a in axes else None

    return P(*(f(a) for a in spec))


def named(mesh, spec_tree, sds_tree=None):
    """NamedShardings for ``spec_tree``; unknown axes dropped.

    With ``sds_tree`` given, axes that do not divide the dim size are dropped
    too (jit argument shardings demand exact divisibility — batch=1 decode
    cells, odd vocab sizes, ragged stacks).
    """
    axes = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(s, sds=None):
        spec = _filter_spec(s, axes)
        if sds is not None:
            out = []
            for dim, a in zip(sds.shape, spec):
                total = 1
                for ax in (a if isinstance(a, tuple) else (a,)) if a else ():
                    total *= sizes.get(ax, 1)
                out.append(a if (a is None or dim % total == 0) else None)
            spec = P(*out)
        return NamedSharding(mesh, spec)

    if sds_tree is None:
        return jax.tree.map(one, spec_tree, is_leaf=lambda x: isinstance(x, P))
    flat_s, tdef = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, P)
    )
    flat_d = jax.tree_util.tree_flatten(
        sds_tree, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
    )[0]
    return tdef.unflatten([one(s, d) for s, d in zip(flat_s, flat_d)])


def build_cell(arch: str, shape_name: str, multi_pod: bool, kv_quant: bool = False):
    """Lower + compile one cell; returns the result record."""
    import dataclasses

    cfg = get_config(arch)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant_int8=True)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, mesh, shape.kind)
    key = jax.random.PRNGKey(0)

    kind, arg_specs, arg_shard_specs = input_specs(cfg, shape, rules)
    arg_sh = tuple(named(mesh, s, d) for s, d in zip(arg_shard_specs, arg_specs))

    t0 = time.time()
    with use_rules(rules), set_mesh(mesh):
        if kind == "train":
            from repro.train.optimizer import MixedPrecision

            opt = MixedPrecision(AdamW(schedule=constant(3e-4)))
            state_sds = jax.eval_shape(partial(init_train_state, cfg, opt), key)
            pspecs = tree_param_specs(state_sds.params, rules, mesh)
            state_spec = TrainState(
                params=pspecs,
                opt_state={
                    "master": pspecs,
                    "inner": {"m": pspecs, "v": pspecs, "step": P()},
                },
                step=P(),
            )
            state_sh = named(mesh, state_spec)
            # microbatching keeps big-model activations inside HBM
            n_par = cfg.param_count()
            accum = 8 if n_par > 60e9 else (2 if n_par > 9e9 else 1)
            step_fn = make_train_step(cfg, opt, accum_steps=accum, param_specs=pspecs)
            jitted = jax.jit(
                step_fn,
                in_shardings=(state_sh,) + arg_sh,
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_sds, *arg_specs)
        else:
            params_sds = jax.eval_shape(partial(init_params, cfg), key)
            pspecs = tree_param_specs(params_sds, rules, mesh)
            params_sh = named(mesh, pspecs)
            if kind == "prefill":
                jitted = jax.jit(
                    lambda params, batch: prefill(params, cfg, batch),
                    in_shardings=(params_sh,) + arg_sh,
                )
                lowered = jitted.lower(params_sds, *arg_specs)
            else:  # decode
                cache_sds, tok_sds = arg_specs
                jitted = jax.jit(
                    lambda params, cache, tok: decode_step(params, cfg, cache, tok),
                    in_shardings=(params_sh,) + arg_sh,
                    donate_argnums=(1,),
                )
                lowered = jitted.lower(params_sds, cache_sds, tok_sds)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = cost_analysis(compiled)
    hlo = compiled.as_text()
    coll = collective_stats(hlo)

    mem_rec = {
        k: int(getattr(mem, k, 0) or 0)
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
        )
    }
    cost_rec = {
        k: float(cost.get(k, 0.0))
        for k in ("flops", "bytes accessed", "transcendentals")
        if cost
    }
    n_dev = int(np.prod(list(mesh.shape.values())))
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev,
        "kind": kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_rec,
        "bytes_per_device": mem_rec["argument_size_in_bytes"]
        + mem_rec["temp_size_in_bytes"],
        "cost": cost_rec,
        "collectives": coll,
        "params": int(cfg.param_count()),
        "active_params": int(cfg.active_param_count()),
    }
    return rec


def run_cell(arch: str, shape_name: str, mesh_name: str, force=False, kv_quant=False):
    RESULTS.mkdir(parents=True, exist_ok=True)
    suffix = "__kvq" if kv_quant else ""
    out = RESULTS / f"{ALIASES[arch]}__{shape_name}__{mesh_name}{suffix}.json"
    if out.exists() and not force:
        print(f"[skip] {out.name}")
        return json.loads(out.read_text())
    try:
        rec = build_cell(
            arch, shape_name, multi_pod=(mesh_name == "multi"), kv_quant=kv_quant
        )
        out.write_text(json.dumps(rec, indent=1))
        print(
            f"[ok]   {out.name}: compile={rec['compile_s']}s "
            f"bytes/dev={rec['bytes_per_device']/2**30:.2f}GiB "
            f"flops={rec['cost'].get('flops', 0):.3g} "
            f"coll={rec['collectives']['total_bytes']/2**30:.2f}GiB"
        )
        return rec
    except Exception as e:  # noqa: BLE001 — sweep must record failures and continue
        err = {
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_name,
            "error": f"{type(e).__name__}: {e}",
            "trace": traceback.format_exc()[-2000:],
        }
        fail = RESULTS / f"FAILED__{ALIASES[arch]}__{shape_name}__{mesh_name}.json"
        fail.write_text(json.dumps(err, indent=1))
        print(f"[FAIL] {arch} {shape_name} {mesh_name}: {err['error']}")
        return err


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--kv-quant", action="store_true", help="int8 KV cache (§Perf)")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        for arch in all_arch_ids():
            cfg = get_config(arch)
            for shape_name in applicable_shapes(cfg.family):
                for m in meshes:
                    run_cell(arch, shape_name, m, force=args.force)
    else:
        assert args.arch and args.shape
        for m in meshes:
            run_cell(args.arch, args.shape, m, force=args.force, kv_quant=args.kv_quant)


if __name__ == "__main__":
    main()
