# repro.launch: entry points (serve_graph, dryrun, train) plus the serving
# tier's typed API.  Only the dependency-light wire types import eagerly —
# GraphService and ContinuousScheduler resolve lazily so `import repro.launch`
# stays cheap and cycle-free (repro.solve re-exports these same types).
from repro.launch.service.types import (
    Admission,
    ClassPolicy,
    QueryRequest,
    QueryResult,
)

__all__ = [
    "Admission",
    "ClassPolicy",
    "ContinuousScheduler",
    "GraphService",
    "QueryRequest",
    "QueryResult",
]

_LAZY = {
    "GraphService": ("repro.launch.serve_graph", "GraphService"),
    "ContinuousScheduler": ("repro.launch.service.scheduler", "ContinuousScheduler"),
}


def __getattr__(name):
    entry = _LAZY.get(name)
    if entry is None:
        raise AttributeError(f"module 'repro.launch' has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(entry[0]), entry[1])
