"""Production mesh definitions.

Axis conventions (DESIGN.md §7):

* ``pod``   — outer data-parallel over DCN (2 pods in the assigned target);
  also the commit axis for delayed gradient commit, and re-bindable to
  pipeline stages (knob left for >2-pod deployments).
* ``data``  — within-pod data parallel + FSDP (ZeRO-3 parameter sharding).
* ``model`` — tensor/expert parallel.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""

from __future__ import annotations

import jax

from repro.dist.compat import AxisType, make_mesh

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return make_mesh(
        (data, model),
        ("data", "model"),
        axis_types=(AxisType.Auto,) * 2,
    )
