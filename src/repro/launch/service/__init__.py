# The continuous-batching serving tier: typed request/response API
# (types.py), admission queue + lanes over BatchStepper (scheduler.py), and
# the open-loop Poisson load generator / trace replay harness (loadgen.py).
# GraphService (repro.launch.serve_graph) is the per-graph facade; a
# ContinuousScheduler serves several of them in one process.
from repro.launch.service.types import (
    DEFAULT_CLASSES,
    Admission,
    ClassPolicy,
    QueryFailure,
    QueryRequest,
    QueryResult,
    UpdateRequest,
    UpdateResult,
    default_class_for,
)
from repro.launch.service.scheduler import AdmissionQueue, ContinuousScheduler
from repro.launch.service.loadgen import (
    Trace,
    TraceEvent,
    load_traces,
    poisson_trace,
    replay_continuous,
    replay_fixed,
    save_traces,
    summarize,
)

__all__ = [
    "Admission",
    "AdmissionQueue",
    "ClassPolicy",
    "ContinuousScheduler",
    "DEFAULT_CLASSES",
    "QueryFailure",
    "QueryRequest",
    "QueryResult",
    "Trace",
    "TraceEvent",
    "UpdateRequest",
    "UpdateResult",
    "default_class_for",
    "load_traces",
    "poisson_trace",
    "replay_continuous",
    "replay_fixed",
    "save_traces",
    "summarize",
]
