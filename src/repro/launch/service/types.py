"""Typed request/response surface of the continuous-batching serving tier.

Nothing here imports the solver stack — these are the wire types a client
holds: a :class:`QueryRequest` goes in, an :class:`Admission` comes back
immediately (accepted with an id, or rejected with a reason — that is the
backpressure contract), and a :class:`QueryResult` comes out of
``drain()``/``pump()`` when the query retires from its batch.

Request *classes* decouple scheduling policy from the algorithm: a
:class:`ClassPolicy` names the δ / backend / frontier the class's lane
solves with and the scheduling quantum (``slot_rounds``) at which its lane
retires finished queries and slots in waiting ones.  ``"auto"`` routes
cheap point-lookups (PPR) to the ``"cheap"`` class and whole-graph traversals
(SSSP) to ``"deep"``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "Admission",
    "ClassPolicy",
    "DEFAULT_CLASSES",
    "QueryFailure",
    "QueryRequest",
    "QueryResult",
    "UpdateRequest",
    "UpdateResult",
    "default_class_for",
]


@dataclasses.dataclass(frozen=True)
class QueryRequest:
    """One serving query: which algorithm, on which resident graph, from where.

    * ``algo``          — ``"sssp"`` (payload = source vertex), ``"ppr"``
      (payload = seed vertex), or the matrix-frontier algorithms ``"rwr"`` /
      ``"labelprop"`` (payload = the first landmark/anchor vertex; the
      service derives the remaining ``feature_dim - 1`` evenly spaced ones).
    * ``payload``       — the vertex id the query is parameterized by.
    * ``request_class`` — scheduling class name, or ``"auto"`` to route by
      algorithm (PPR → ``"cheap"``, SSSP → ``"deep"``).
    * ``graph``         — tenant name; the scheduler owns several resident
      :class:`~repro.launch.serve_graph.GraphService` solvers in one process.
    * ``deadline_rounds`` — optional round-clock budget: if the query is
      still waiting (queued or in retry backoff) this many rounds after
      submit, it retires as a ``"deadline_exceeded"`` :class:`QueryFailure`
      instead of consuming a slot.  ``None`` = no deadline.
    """

    algo: str
    payload: int
    request_class: str = "auto"
    graph: str = "default"
    deadline_rounds: int | None = None


@dataclasses.dataclass(frozen=True)
class Admission:
    """Immediate answer to ``submit()`` — the backpressure contract.

    ``accepted=False`` always carries a ``reason`` (``"queue_full"``,
    ``"unknown_graph"``, ``"unsupported_algo"``, ``"unknown_class"``,
    ``"payload_out_of_range"``, ``"quota_exceeded"``, ``"lane_open"`` —
    the lane's circuit breaker is cooling down after repeated faults);
    rejection is deterministic in the submit sequence, never a timing
    accident.
    """

    accepted: bool
    request_id: str | None = None
    reason: str | None = None
    queue_depth: int = 0


@dataclasses.dataclass
class QueryResult:
    """One retired query: the answer plus its scheduling history.

    Clock fields are in *rounds* (the scheduler's deterministic virtual
    time); ``latency_s`` is the wall-clock from submit to retirement.
    ``converged=False`` means the round budget ran out — the state is the
    best iterate, flagged, never silently wrong.
    """

    request_id: str
    algo: str
    graph: str
    request_class: str
    payload: int  # the vertex the query was parameterized by
    x: np.ndarray  # (n,) — or (n, F) for matrix algos — frozen at convergence
    rounds: int  # rounds to first convergence (this query alone)
    converged: bool
    residual: float
    delta: int  # δ its lane solved with (class policy applied)
    backend: str
    admit_seq: int  # global admission order (FIFO audit)
    submitted_clock: int  # scheduler clock (rounds) at submit
    admitted_clock: int  # ... at slot-in
    finished_clock: int  # ... at retirement
    latency_s: float = 0.0

    @property
    def queue_rounds(self) -> int:
        """Rounds spent waiting in the admission queue."""
        return self.admitted_clock - self.submitted_clock

    @property
    def service_rounds(self) -> int:
        """Rounds from slot-in to retirement (includes quantum granularity)."""
        return self.finished_clock - self.admitted_clock


@dataclasses.dataclass
class QueryFailure:
    """One admitted query that could **not** be answered — a typed tombstone.

    The no-silent-loss contract: every accepted request retires as exactly
    one :class:`QueryResult` or one :class:`QueryFailure` (collected via
    ``ContinuousScheduler.take_failures()``).  ``reason`` is
    ``"deadline_exceeded"`` (the request's round-clock deadline passed while
    it waited) or ``"retries_exhausted"`` (its lane faulted more than the
    class policy's ``max_retries`` while it was slotted in).
    """

    request_id: str
    algo: str
    graph: str
    request_class: str
    payload: int
    reason: str
    attempts: int  # faulted lane quanta this request was slotted into
    submitted_clock: int  # scheduler clock (rounds) at submit
    failed_clock: int  # ... at retirement-as-failure
    latency_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class UpdateRequest:
    """One edge-update batch against a resident graph.

    ``batch`` is an :class:`repro.graphs.updates.EdgeBatch` (typed loosely
    here so the wire types stay import-light).  Updates share the admission
    contract with queries — ``submit_update()`` answers immediately with an
    :class:`Admission` (``"unknown_graph"``, ``"payload_out_of_range"``,
    ``"quota_exceeded"`` are the typed rejections) — but travel a separate
    per-graph queue and apply only at a round boundary where the graph's
    lanes are quiescent, so every in-flight query retires against the
    snapshot it was admitted on.
    """

    batch: object
    graph: str = "default"


@dataclasses.dataclass
class UpdateResult:
    """One applied update batch: what changed and when (round clock).

    ``barrier_rounds`` is the deterministic wait between submission and
    application — the rounds the scheduler spent retiring in-flight queries
    on the pre-update snapshot before the graph quiesced.
    """

    request_id: str
    graph: str
    inserted: int
    deleted: int
    reweighted: int
    affected_rows: int  # destination rows whose in-edge lists changed
    submitted_clock: int  # scheduler clock (rounds) at submit_update()
    applied_clock: int  # ... at application (round boundary, lanes quiesced)
    latency_s: float = 0.0

    @property
    def barrier_rounds(self) -> int:
        """Rounds spent waiting for the graph's lanes to quiesce."""
        return self.applied_clock - self.submitted_clock


@dataclasses.dataclass(frozen=True)
class ClassPolicy:
    """How one request class is solved and scheduled.

    ``delta`` / ``backend`` / ``frontier`` default to the owning service's
    construction values (``None`` = inherit); ``slot_rounds`` is the lane's
    scheduling quantum — how many rounds run between retire/slot-in
    boundaries.  Small quanta give admission latency and fast retirement at
    the cost of more host sync points; large quanta amortize.

    Fault handling (see the scheduler's retry loop): a lane quantum that
    raises evicts the lane's riders back to the queue head; each rider
    retries up to ``max_retries`` times, waiting
    ``backoff_rounds * 2**(attempt-1)`` rounds of virtual time before
    re-admission, then fails typed (``"retries_exhausted"``).
    ``breaker_threshold`` *consecutive* faulted quanta open the lane's
    circuit breaker: new submits are rejected (``"lane_open"``) for
    ``breaker_cooldown_rounds``, after which the lane half-opens and one
    successful quantum closes it again.
    """

    name: str
    delta: object = None
    backend: str | None = None
    frontier: str | None = None
    slot_rounds: int = 4
    max_rounds: int | None = None
    max_retries: int = 2
    backoff_rounds: int = 2
    breaker_threshold: int = 3
    breaker_cooldown_rounds: int = 32

    def __post_init__(self):
        if self.slot_rounds < 1:
            raise ValueError(f"slot_rounds must be >= 1, got {self.slot_rounds}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_rounds < 0:
            raise ValueError(f"backoff_rounds must be >= 0, got {self.backoff_rounds}")
        if self.breaker_threshold < 1:
            raise ValueError(
                f"breaker_threshold must be >= 1, got {self.breaker_threshold}"
            )
        if self.breaker_cooldown_rounds < 0:
            raise ValueError(
                "breaker_cooldown_rounds must be >= 0, "
                f"got {self.breaker_cooldown_rounds}"
            )


#: Default classes: interactive point lookups vs whole-graph traversals.
#: Both inherit the service's δ/backend; they differ in scheduling quantum —
#: the cheap lane retires (and admits) twice as often as the deep lane.
DEFAULT_CLASSES: dict[str, ClassPolicy] = {
    "cheap": ClassPolicy(name="cheap", slot_rounds=2),
    "deep": ClassPolicy(name="deep", slot_rounds=8),
}

_AUTO_CLASS = {"ppr": "cheap", "rwr": "cheap", "sssp": "deep", "labelprop": "deep"}


def default_class_for(algo: str) -> str:
    """The class ``request_class="auto"`` resolves to for ``algo``."""
    return _AUTO_CLASS.get(algo, "deep")
