"""Open-loop Poisson load generation and deterministic trace replay.

*Open loop* means arrival times are fixed in advance (a Poisson process at
the offered rate), independent of how the service keeps up — the honest way
to measure a serving tier, since closed-loop generators self-throttle and
hide saturation.  Time is the scheduler's round clock: one unit = one engine
round executed on the device, so a replay is **bit-deterministic** for a
given trace — queue waits, retirement order, rejections, and latency
percentiles can be committed as CI baselines (wall-clock fields ride along
under ``*_s`` names, which the regression guard skips).

Two replay disciplines give the continuous-batching comparison:

* :func:`replay_continuous` — drives a
  :class:`~repro.launch.service.scheduler.ContinuousScheduler`: arrivals
  slot into in-flight batches at quantum boundaries and leave when *they*
  converge.
* :func:`replay_fixed` — the pre-serving-tier discipline: arrivals wait for
  a full fixed-shape padded batch, which runs to *collective* convergence
  before anyone is answered or admitted (one fused ``solve_batch`` call,
  exactly what ``GraphService.sssp()/.ppr()`` did before this tier).
"""

from __future__ import annotations

import dataclasses
import json
import math
import time
from collections import deque
from pathlib import Path

import numpy as np

from repro.launch.service.types import QueryRequest
from repro.solve.batch import solve_batch
from repro.solve.problem import multi_source_x0, ppr_teleport

__all__ = [
    "Trace",
    "TraceEvent",
    "load_traces",
    "poisson_trace",
    "replay_continuous",
    "replay_fixed",
    "save_traces",
    "summarize",
]


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One arrival: at round-clock ``t``, a query for ``algo`` on ``graph``."""

    t: float
    algo: str
    payload: int
    request_class: str = "auto"
    graph: str = "default"


@dataclasses.dataclass(frozen=True)
class Trace:
    """A reproducible arrival sequence at one offered load."""

    rate: float  # offered load, queries per round
    duration: float  # arrival window, rounds
    seed: int
    events: tuple[TraceEvent, ...]

    def to_dict(self) -> dict:
        return {
            "rate": self.rate,
            "duration": self.duration,
            "seed": self.seed,
            "events": [dataclasses.asdict(e) for e in self.events],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Trace":
        return cls(
            rate=d["rate"],
            duration=d["duration"],
            seed=d["seed"],
            events=tuple(TraceEvent(**e) for e in d["events"]),
        )


def poisson_trace(
    rate: float,
    duration: float,
    n_vertices,
    *,
    seed: int = 0,
    mix=(("ppr", 0.75), ("sssp", 0.25)),
    graphs=("default",),
    graph_for: dict | None = None,
) -> Trace:
    """Open-loop Poisson arrivals: exp(1/rate) gaps over ``duration`` rounds.

    ``mix`` weights the algorithm of each arrival; each arrival then draws
    its tenant uniformly (seeded) from ``graph_for[algo]`` if given, else
    from ``graphs`` — ``graph_for`` routes algos to the tenants that serve
    them (SSSP needs length-valued edges, PPR needs pagerank-valued ones).
    ``n_vertices`` is an int (shared by all tenants) or a ``{tenant: n}``
    mapping; payload vertices are drawn uniformly per tenant.  Same seed →
    identical trace, always.
    """
    rng = np.random.default_rng(seed)
    algos = [a for a, _ in mix]
    weights = np.asarray([w for _, w in mix], np.float64)
    weights = weights / weights.sum()
    all_graphs = tuple(graphs)
    if graph_for:
        all_graphs = tuple(dict.fromkeys(g for gs in graph_for.values() for g in gs))
    if not isinstance(n_vertices, dict):
        n_vertices = {g: int(n_vertices) for g in all_graphs}
    events = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate)
        if t > duration:
            break
        algo = algos[int(rng.choice(len(algos), p=weights))]
        pool = tuple(graph_for[algo]) if graph_for else tuple(graphs)
        graph = pool[int(rng.integers(len(pool)))]
        payload = int(rng.integers(n_vertices[graph]))
        events.append(TraceEvent(t=float(t), algo=algo, payload=payload, graph=graph))
    return Trace(rate=rate, duration=duration, seed=seed, events=tuple(events))


def save_traces(path, traces: list[Trace]) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(
        json.dumps({"version": 1, "traces": [t.to_dict() for t in traces]}, indent=1)
    )
    return path


def load_traces(path) -> list[Trace]:
    d = json.loads(Path(path).read_text())
    return [Trace.from_dict(t) for t in d["traces"]]


def summarize(latencies_rounds, *, clock_rounds: int, wall_s: float) -> dict:
    """Aggregate one replay's per-request latencies (round-clock units)."""
    lat = np.asarray(latencies_rounds, np.float64)
    if lat.size == 0:
        p50 = p99 = mean = worst = 0.0
    else:
        p50 = float(np.percentile(lat, 50))
        p99 = float(np.percentile(lat, 99))
        mean = float(lat.mean())
        worst = float(lat.max())
    return {
        "completed": int(lat.size),
        "clock_rounds": int(clock_rounds),
        "p50_rounds": round(p50, 3),
        "p99_rounds": round(p99, 3),
        "mean_rounds": round(mean, 3),
        "worst_rounds": round(worst, 3),
        # queries per 1000 rounds of device work — the deterministic
        # throughput number (wall-clock throughput is runner-dependent)
        "completed_per_kround": (
            round(lat.size / clock_rounds * 1000, 3) if clock_rounds else 0.0
        ),
        "wall_s": wall_s,  # skipped by the regression guard, by name
    }


def replay_continuous(scheduler, trace: Trace) -> dict:
    """Drive ``scheduler`` through ``trace`` in open loop; report the replay.

    Arrivals are submitted the moment the round clock passes their ``t``
    (rejections — queue full — happen then, deterministically); the
    scheduler pumps whenever work is pending, and the clock fast-forwards
    across idle gaps.  Latency of a request = retirement clock − arrival
    ``t``.
    """
    events = sorted(trace.events, key=lambda e: e.t)
    arrival: dict[str, float] = {}
    results = []
    rejected: dict[str, int] = {}
    i = 0
    wall0 = time.perf_counter()
    while i < len(events) or not scheduler.idle:
        while i < len(events) and events[i].t <= scheduler.clock_rounds:
            ev = events[i]
            i += 1
            adm = scheduler.submit(
                QueryRequest(
                    algo=ev.algo,
                    payload=ev.payload,
                    request_class=ev.request_class,
                    graph=ev.graph,
                )
            )
            if adm.accepted:
                arrival[adm.request_id] = ev.t
            else:
                rejected[adm.reason] = rejected.get(adm.reason, 0) + 1
        if scheduler.idle:
            if i < len(events):  # idle gap: fast-forward to the next arrival
                scheduler.advance_clock(math.ceil(events[i].t))
            continue
        results.extend(scheduler.pump())
    wall_s = time.perf_counter() - wall0
    latencies = [r.finished_clock - arrival[r.request_id] for r in results]
    report = summarize(latencies, clock_rounds=scheduler.clock_rounds, wall_s=wall_s)
    report["offered"] = len(events)
    report["rejected"] = int(sum(rejected.values()))
    report["rejected_by_reason"] = dict(sorted(rejected.items()))
    report["unconverged"] = int(sum(not r.converged for r in results))
    return {"report": report, "results": results, "arrival": arrival}


def replay_fixed(
    services,
    trace: Trace,
    *,
    batch_size: int,
    queue_capacity: int = 64,
) -> dict:
    """The fixed-batch counterfactual: same trace, pre-serving-tier rules.

    Arrivals queue (bounded, same capacity as the scheduler's) until the
    device is free, then the head-of-queue's ``(graph, algo)`` group is
    padded to the fixed batch shape and solved with one fused
    ``solve_batch`` call; **nobody** in the batch is answered — and nobody
    new is admitted to the device — until the whole batch converges.  Clock
    advances by the fused loop's round count (max over the batch).
    """
    if not isinstance(services, dict):
        services = {"default": services}
    events = sorted(trace.events, key=lambda e: e.t)
    queue: deque[TraceEvent] = deque()
    latencies: list[float] = []
    rejected: dict[str, int] = {}
    clock = 0
    i = 0
    wall0 = time.perf_counter()
    while i < len(events) or queue:
        while i < len(events) and events[i].t <= clock:
            ev = events[i]
            i += 1
            if len(queue) >= queue_capacity:
                rejected["queue_full"] = rejected.get("queue_full", 0) + 1
            else:
                queue.append(ev)
        if not queue:
            clock = max(clock, math.ceil(events[i].t))
            continue
        head = queue[0]
        taken: list[TraceEvent] = []
        kept: deque[TraceEvent] = deque()
        while queue:
            ev = queue.popleft()
            same = ev.graph == head.graph and ev.algo == head.algo
            if same and len(taken) < batch_size:
                taken.append(ev)
            else:
                kept.append(ev)
        queue = kept
        service = services[head.graph]
        solver = service.solver(head.algo)
        g = service.graph
        payloads = [ev.payload for ev in taken]
        pad = payloads + [payloads[-1]] * (batch_size - len(payloads))
        if head.algo == "sssp":
            res = solve_batch(solver, multi_source_x0(g, pad))
        else:
            x0 = np.full((batch_size, g.n), 1.0 / g.n, np.float32)
            res = solve_batch(solver, x0, q=ppr_teleport(g, pad, service.damping))
        clock += res.rounds
        latencies.extend(clock - ev.t for ev in taken)
    wall_s = time.perf_counter() - wall0
    report = summarize(latencies, clock_rounds=clock, wall_s=wall_s)
    report["offered"] = len(events)
    report["rejected"] = int(sum(rejected.values()))
    report["rejected_by_reason"] = dict(sorted(rejected.items()))
    report["unconverged"] = 0
    return {"report": report}
