"""Continuous-batching scheduler: admission queue → lanes → retirement.

The serving loop the LM-inference playbook prescribes, applied to graph
queries: requests are admitted into a bounded FIFO queue, slotted into
fixed-capacity in-flight batches (*lanes*) as converged queries retire at
scheduling-quantum boundaries, and returned the moment **they** converge —
no barrier on batch boundaries, no slow query stalling the rest (the
non-blocking-PageRank / Maiter insight at the scheduling level).

One :class:`ContinuousScheduler` serves several resident
:class:`~repro.launch.serve_graph.GraphService` solvers (multi-graph
tenancy: ``QueryRequest.graph`` routes), and one *lane* exists per
``(graph, algo, class)`` — a :class:`repro.solve.batch.BatchStepper` whose
δ / backend / frontier / quantum come from the class's
:class:`~repro.launch.service.types.ClassPolicy`, so cheap PPR lookups and
deep SSSP traversals schedule independently while sharing the process.

Time is counted in *rounds* (``clock_rounds``): every quantum advances the
clock by the rounds it actually executed, which makes scheduling behavior —
queue waits, retirement order, backpressure — deterministic and assertable
in CI, independent of wall clock.  Wall-clock latency rides along in
``QueryResult.latency_s``.
"""

from __future__ import annotations

import time
from collections import deque

import numpy as np

from repro.ft.inject import fire
from repro.launch.service.types import (
    DEFAULT_CLASSES,
    Admission,
    ClassPolicy,
    QueryFailure,
    QueryRequest,
    QueryResult,
    UpdateRequest,
    UpdateResult,
    default_class_for,
)
from repro.solve.batch import BatchStepper
from repro.solve.problem import (
    labelprop_anchors,
    multi_source_x0,
    ppr_teleport,
    rwr_restart,
)

__all__ = ["AdmissionQueue", "ContinuousScheduler"]


class AdmissionQueue:
    """Bounded FIFO of ``(request_id, QueryRequest)`` — the backpressure valve.

    One global queue, popped per lane in scan order, preserves FIFO within
    every class; ``push`` on a full queue fails deterministically (the
    caller turns that into a ``"queue_full"`` rejection).
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"queue capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._q: deque[tuple[str, QueryRequest]] = deque()

    def __len__(self) -> int:
        return len(self._q)

    @property
    def full(self) -> bool:
        return len(self._q) >= self.capacity

    def push(self, request_id: str, req: QueryRequest) -> bool:
        if self.full:
            return False
        self._q.append((request_id, req))
        return True

    def push_front(self, items) -> None:
        """Requeue already-admitted entries at the head, preserving order.

        Used by fault recovery: evicted in-flight riders go back *ahead* of
        everything queued (they were admitted first).  Deliberately ignores
        ``capacity`` — these entries were already accepted, and dropping them
        would violate the no-silent-loss contract; the overshoot is transient
        (they re-admit before anything behind them).
        """
        self._q.extendleft(reversed(list(items)))

    def items(self) -> tuple[tuple[str, QueryRequest], ...]:
        """FIFO snapshot (for lane materialization / introspection)."""
        return tuple(self._q)

    def pop_where(self, pred, k: int) -> list[tuple[str, QueryRequest]]:
        """Pop up to ``k`` entries matching ``pred(req)``, preserving FIFO."""
        return self.pop_items_where(lambda item: pred(item[1]), k)

    def pop_items_where(
        self, pred, k: int | None = None
    ) -> list[tuple[str, QueryRequest]]:
        """Pop up to ``k`` entries matching ``pred((request_id, req))``."""
        if k is None:
            k = len(self._q)
        taken: list[tuple[str, QueryRequest]] = []
        kept: deque[tuple[str, QueryRequest]] = deque()
        while self._q:
            item = self._q.popleft()
            if len(taken) < k and pred(item):
                taken.append(item)
            else:
                kept.append(item)
        self._q = kept
        return taken


class _PendingUpdate:
    """Book-keeping for one accepted update batch while its graph quiesces."""

    __slots__ = ("req", "submitted_clock", "submit_wall")

    def __init__(self, req: UpdateRequest, clock: int, wall: float):
        self.req = req
        self.submitted_clock = clock
        self.submit_wall = wall


class _Pending:
    """Book-keeping for one accepted request while it waits / runs."""

    __slots__ = (
        "req",
        "submitted_clock",
        "submit_wall",
        "admitted_clock",
        "admit_seq",
        "attempts",
        "retry_at_clock",
    )

    def __init__(self, req: QueryRequest, clock: int, wall: float):
        self.req = req
        self.submitted_clock = clock
        self.submit_wall = wall
        self.admitted_clock = -1
        self.admit_seq = -1
        self.attempts = 0  # faulted lane quanta this request rode
        self.retry_at_clock = 0  # earliest clock it may re-admit (backoff)


class _Breaker:
    """Per-lane circuit breaker: consecutive faults open it for a cooldown."""

    __slots__ = ("consecutive", "open_until")

    def __init__(self):
        self.consecutive = 0
        self.open_until = 0


class _Lane:
    """One in-flight open batch: ``(graph, algo, class)`` → BatchStepper."""

    def __init__(self, service, algo: str, policy: ClassPolicy):
        self.service = service
        self.algo = algo
        self.policy = policy
        self.stepper = BatchStepper(
            service.solver(algo),
            capacity=service.batch_size,
            delta=policy.delta,
            backend=policy.backend,
            frontier=policy.frontier,
            max_rounds=policy.max_rounds,
        )

    def admit(self, request_id: str, req: QueryRequest):
        g = self.service.graph
        if req.algo == "sssp":
            self.stepper.admit(multi_source_x0(g, [req.payload])[0], tag=request_id)
        elif req.algo == "ppr":
            x0 = np.full(g.n, 1.0 / g.n, np.float32)
            q = ppr_teleport(g, [req.payload], self.service.damping)[0]
            self.stepper.admit(x0, q=q, tag=request_id)
        elif req.algo in ("rwr", "labelprop"):
            # matrix-frontier algos: the payload vertex anchors column 0 and
            # the remaining F-1 landmarks are spread evenly around the id
            # space, so one int payload parameterizes an (n, F) query
            F = self.service.solver(req.algo).problem.feature_dim
            seeds = (req.payload + (np.arange(F, dtype=np.int64) * g.n) // F) % g.n
            if req.algo == "rwr":
                x0 = np.full((g.n, F), 1.0 / g.n, np.float32)
                q = rwr_restart(g, seeds, self.service.damping)
            else:
                x0 = np.full((g.n, F), 1.0 / F, np.float32)
                q = labelprop_anchors(g, seeds)
            self.stepper.admit(x0, q=q, tag=request_id)
        else:  # pre-validated in submit(); defensive for direct callers
            raise ValueError(f"unsupported algo {req.algo!r}")

    def run_quantum(self):
        return self.stepper.run(self.policy.slot_rounds)


class ContinuousScheduler:
    """Admission queue + continuous batching over resident graph services.

    * ``services`` — one :class:`GraphService` or a ``{tenant: service}``
      mapping (multi-graph tenancy; requests route by ``req.graph``).
    * ``classes``  — request-class policies, overlaid on
      :data:`~repro.launch.service.types.DEFAULT_CLASSES`.
    * ``queue_capacity`` — bound on queued (not yet slotted-in) requests;
      beyond it :meth:`submit` rejects with ``"queue_full"``.
    * ``per_graph_quota`` — per-tenant admission bound: queued queries plus
      pending update batches for one graph; beyond it :meth:`submit` /
      :meth:`submit_update` reject with ``"quota_exceeded"`` (checked before
      the global ``queue_full``, so one tenant can't starve the rest).

    Edge-update batches travel :meth:`submit_update` →
    :meth:`take_update_results`: accepted :class:`UpdateRequest`\\ s queue
    per graph and apply inside :meth:`pump` only when that graph's lanes are
    quiescent — queries admitted before the update retire on the old
    snapshot, queries submitted after it stay queued until it applies.

    ``submit()`` answers immediately with an :class:`Admission`;
    :meth:`pump` executes one scheduling quantum across all lanes (slot in
    from the queue, run, retire); :meth:`drain` pumps until idle and returns
    every completed :class:`QueryResult`.  All scheduling state advances in
    deterministic round-clock time.
    """

    def __init__(
        self,
        services,
        *,
        classes: dict[str, ClassPolicy] | None = None,
        queue_capacity: int = 64,
        per_graph_quota: int | None = None,
    ):
        if not isinstance(services, dict):
            services = {"default": services}
        if not services:
            raise ValueError("at least one resident GraphService is required")
        if per_graph_quota is not None and per_graph_quota < 1:
            raise ValueError(f"per_graph_quota must be >= 1, got {per_graph_quota}")
        self.services = dict(services)
        self.classes = dict(DEFAULT_CLASSES)
        if classes:
            self.classes.update(classes)
        self.queue = AdmissionQueue(queue_capacity)
        self.per_graph_quota = per_graph_quota
        self._lanes: dict[tuple[str, str, str], _Lane] = {}
        self._pending: dict[str, _Pending] = {}
        self._pending_updates: dict[str, deque[tuple[str, _PendingUpdate]]] = {}
        self._update_results: list[UpdateResult] = []
        self._breakers: dict[tuple[str, str, str], _Breaker] = {}
        self._failures: list[QueryFailure] = []
        self._next_id = 0
        self._next_admit_seq = 0
        self.clock_rounds = 0
        self.counters = {
            "submitted": 0,
            "accepted": 0,
            "rejected": 0,
            "completed": 0,
            "unconverged": 0,
            "failed": 0,
            "lane_faults": 0,
            "retries": 0,
            "pumps": 0,
            "updates_submitted": 0,
            "updates_applied": 0,
        }
        self.rejections: dict[str, int] = {}

    # ------------------------------------------------------------ submit #
    def _reject(self, reason: str) -> Admission:
        self.counters["rejected"] += 1
        self.rejections[reason] = self.rejections.get(reason, 0) + 1
        return Admission(accepted=False, reason=reason, queue_depth=len(self.queue))

    def resolve_class(self, req: QueryRequest) -> str:
        cls = req.request_class
        return default_class_for(req.algo) if cls == "auto" else cls

    def _graph_load(self, graph: str) -> int:
        """Admitted-but-unapplied work for one tenant (the quota metric):
        queued queries plus pending update batches."""
        queued = sum(1 for _, r in self.queue.items() if r.graph == graph)
        return queued + len(self._pending_updates.get(graph, ()))

    def submit(self, req: QueryRequest) -> Admission:
        """Admit or reject one request — constant-time, never blocks."""
        self.counters["submitted"] += 1
        service = self.services.get(req.graph)
        if service is None:
            return self._reject("unknown_graph")
        if req.algo not in getattr(service, "algos", ("sssp", "ppr")):
            return self._reject("unsupported_algo")
        cls = self.resolve_class(req)
        if cls not in self.classes:
            return self._reject("unknown_class")
        breaker = self._breakers.get((req.graph, req.algo, cls))
        if breaker is not None and self.clock_rounds < breaker.open_until:
            return self._reject("lane_open")
        payload = int(req.payload)
        if not 0 <= payload < service.graph.n:
            return self._reject("payload_out_of_range")
        if (
            self.per_graph_quota is not None
            and self._graph_load(req.graph) >= self.per_graph_quota
        ):
            return self._reject("quota_exceeded")
        if self.queue.full:
            return self._reject("queue_full")
        request_id = f"q{self._next_id:06d}"
        self._next_id += 1
        self._pending[request_id] = _Pending(
            req, self.clock_rounds, time.perf_counter()
        )
        self.queue.push(request_id, req)
        self.counters["accepted"] += 1
        return Admission(
            accepted=True, request_id=request_id, queue_depth=len(self.queue)
        )

    # ----------------------------------------------------------- updates #
    def submit_update(self, req: UpdateRequest) -> Admission:
        """Admit one edge-update batch (or reject with a reason).

        Accepted batches join their graph's FIFO update queue and apply at
        the next :meth:`pump` boundary where that graph's lanes are
        quiescent; queries submitted *after* an update stay queued until it
        applies (the snapshot barrier), so results never mix graph versions.
        """
        self.counters["updates_submitted"] += 1
        service = self.services.get(req.graph)
        if service is None:
            return self._reject("unknown_graph")
        verts = req.batch.all_vertices()
        if verts.size and (verts.min() < 0 or verts.max() >= service.graph.n):
            return self._reject("payload_out_of_range")
        if (
            self.per_graph_quota is not None
            and self._graph_load(req.graph) >= self.per_graph_quota
        ):
            return self._reject("quota_exceeded")
        request_id = f"u{self._next_id:06d}"
        self._next_id += 1
        self._pending_updates.setdefault(req.graph, deque()).append(
            (request_id, _PendingUpdate(req, self.clock_rounds, time.perf_counter()))
        )
        self.counters["accepted"] += 1
        return Admission(
            accepted=True, request_id=request_id, queue_depth=len(self.queue)
        )

    def _apply_ready_updates(self):
        """Apply queued update batches whose graph's lanes are all quiescent.

        Runs at the top of every :meth:`pump` — a deterministic round
        boundary: every in-flight query has either retired or sits frozen at
        a quantum edge *on the pre-update snapshot's lanes*, which are
        dropped and lazily rebuilt against the mutated solver only once
        occupancy reaches zero.
        """
        for graph in list(self._pending_updates):
            busy = any(
                lane.stepper.occupancy > 0
                for key, lane in self._lanes.items()
                if key[0] == graph
            )
            if busy:
                continue
            service = self.services[graph]
            queued = self._pending_updates.pop(graph)
            for key in [k for k in self._lanes if k[0] == graph]:
                del self._lanes[key]
            for request_id, pend in queued:
                report = service.apply_updates(pend.req.batch)
                self.counters["updates_applied"] += 1
                self._update_results.append(
                    UpdateResult(
                        request_id=request_id,
                        graph=graph,
                        inserted=int(report.inserted),
                        deleted=int(report.deleted),
                        reweighted=int(report.reweighted),
                        affected_rows=int(report.affected_rows.size),
                        submitted_clock=pend.submitted_clock,
                        applied_clock=self.clock_rounds,
                        latency_s=time.perf_counter() - pend.submit_wall,
                    )
                )

    def take_update_results(self) -> list[UpdateResult]:
        """Applied-update lifecycle records (cleared on read)."""
        out = self._update_results
        self._update_results = []
        return out

    # -------------------------------------------------------------- pump #
    def _lane_for(self, req: QueryRequest) -> _Lane:
        key = (req.graph, req.algo, self.resolve_class(req))
        lane = self._lanes.get(key)
        if lane is None:
            lane = _Lane(self.services[req.graph], req.algo, self.classes[key[2]])
            self._lanes[key] = lane
        return lane

    def _admit_from_queue(self):
        """Slot queued requests into free lane slots, FIFO within class.

        Graphs with pending updates are barriered: their queued queries stay
        in the queue (and no new lanes materialize for them) until the
        update applies, so every admitted query runs on one graph version.
        """
        # Materialize lanes for whatever is queued (deterministic creation
        # order: queue scan order), then fill each lane's free slots.
        for _, req in self.queue.items():
            if req.graph not in self._pending_updates:
                self._lane_for(req)
        for key, lane in self._lanes.items():
            free = lane.stepper.free_slots
            if free == 0:
                continue
            graph, algo, cls = key
            if graph in self._pending_updates:
                continue

            def match(item, g=graph, a=algo, c=cls):
                request_id, r = item
                if r.graph != g or r.algo != a or self.resolve_class(r) != c:
                    return False
                # exponential-backoff wait after a lane fault: stay queued
                # until the retry clock passes
                return self._pending[request_id].retry_at_clock <= self.clock_rounds

            for request_id, req in self.queue.pop_items_where(match, free):
                lane.admit(request_id, req)
                pend = self._pending[request_id]
                pend.admitted_clock = self.clock_rounds
                pend.admit_seq = self._next_admit_seq
                self._next_admit_seq += 1

    def _fail(self, request_id: str, pend: _Pending, reason: str):
        """Retire one admitted request as a typed :class:`QueryFailure`."""
        self._pending.pop(request_id, None)
        self.counters["failed"] += 1
        self._failures.append(
            QueryFailure(
                request_id=request_id,
                algo=pend.req.algo,
                graph=pend.req.graph,
                request_class=self.resolve_class(pend.req),
                payload=int(pend.req.payload),
                reason=reason,
                attempts=pend.attempts,
                submitted_clock=pend.submitted_clock,
                failed_clock=self.clock_rounds,
                latency_s=time.perf_counter() - pend.submit_wall,
            )
        )

    def _expire_deadlines(self):
        """Fail queued requests whose round-clock deadline has passed.

        Deadlines bound *waiting* (queue + retry backoff): once a query is
        slotted in it runs to retirement — its answer exists, delivering it
        is strictly better than discarding work.
        """
        now = self.clock_rounds

        def expired(item):
            request_id, req = item
            if req.deadline_rounds is None:
                return False
            pend = self._pending[request_id]
            return now - pend.submitted_clock > req.deadline_rounds

        for request_id, _ in self.queue.pop_items_where(expired):
            self._fail(request_id, self._pending[request_id], "deadline_exceeded")

    def _on_lane_fault(self, key: tuple[str, str, str], lane: _Lane):
        """Recover from one faulted lane quantum — no admitted query is lost.

        The lane's riders are evicted and requeued at the *head* of the
        admission queue (they were admitted first) with exponential backoff;
        riders whose retry budget is spent fail typed instead.  The lane
        itself is dropped (its batch state is suspect) and will lazily
        rebuild from the solver's still-warm caches; its circuit breaker
        opens after ``breaker_threshold`` consecutive faults.
        """
        self.counters["lane_faults"] += 1
        policy = lane.policy
        requeue = []
        for tag in lane.stepper.evict_all():
            pend = self._pending.get(tag)
            if pend is None:  # defensive: unknown rider, nothing to requeue
                continue
            pend.attempts += 1
            pend.admitted_clock = -1
            if pend.attempts > policy.max_retries:
                self._fail(tag, pend, "retries_exhausted")
                continue
            self.counters["retries"] += 1
            pend.retry_at_clock = self.clock_rounds + policy.backoff_rounds * (
                2 ** (pend.attempts - 1)
            )
            requeue.append((tag, pend.req))
        self.queue.push_front(requeue)
        del self._lanes[key]
        breaker = self._breakers.setdefault(key, _Breaker())
        breaker.consecutive += 1
        if breaker.consecutive >= policy.breaker_threshold:
            breaker.open_until = self.clock_rounds + policy.breaker_cooldown_rounds

    def pump(self) -> list[QueryResult]:
        """One scheduling quantum: apply ready updates, slot in, run, retire.

        A lane quantum that raises (kernel fault, injected chaos) is a
        recoverable event, not a scheduler crash: see :meth:`_on_lane_fault`.
        The faulted quantum still advances the round clock by its
        ``slot_rounds`` — burned device time is burned — which also makes
        retry backoff and breaker cooldowns progress deterministically.
        """
        self.counters["pumps"] += 1
        self._apply_ready_updates()
        self._expire_deadlines()
        self._admit_from_queue()
        results: list[QueryResult] = []
        ran = 0
        for key, lane in list(self._lanes.items()):
            if lane.stepper.occupancy == 0:
                continue
            before = lane.stepper.rounds_executed
            try:
                fire("scheduler.lane", graph=key[0], algo=key[1], request_class=key[2])
                retired = lane.run_quantum()
            except (ValueError, TypeError):
                raise  # caller/config errors — not a fault to retry
            except Exception:
                self.clock_rounds += lane.policy.slot_rounds
                ran += lane.policy.slot_rounds
                self._on_lane_fault(key, lane)
                continue
            breaker = self._breakers.get(key)
            if breaker is not None:
                breaker.consecutive = 0  # a clean quantum closes the breaker
            executed = lane.stepper.rounds_executed - before
            self.clock_rounds += executed
            ran += executed
            for row in retired:
                pend = self._pending.pop(row.tag)
                self.counters["completed"] += 1
                if not row.converged:
                    self.counters["unconverged"] += 1
                results.append(
                    QueryResult(
                        request_id=row.tag,
                        algo=pend.req.algo,
                        graph=pend.req.graph,
                        request_class=self.resolve_class(pend.req),
                        payload=int(pend.req.payload),
                        x=row.x,
                        rounds=row.rounds,
                        converged=row.converged,
                        residual=row.residual,
                        delta=lane.stepper.sched.delta,
                        backend=lane.stepper.backend,
                        admit_seq=pend.admit_seq,
                        submitted_clock=pend.submitted_clock,
                        admitted_clock=pend.admitted_clock,
                        finished_clock=self.clock_rounds,
                        latency_s=time.perf_counter() - pend.submit_wall,
                    )
                )
        if ran == 0 and self.in_flight == 0 and len(self.queue):
            # nothing could run: every queued request is waiting out a retry
            # backoff — fast-forward virtual time to the earliest retry so
            # drain() makes progress instead of spinning
            waits = [
                self._pending[request_id].retry_at_clock
                for request_id, _ in self.queue.items()
            ]
            future = [w for w in waits if w > self.clock_rounds]
            if future:
                self.clock_rounds = min(future)
        return results

    def take_failures(self) -> list[QueryFailure]:
        """Typed tombstones of admitted-but-failed queries (cleared on read).

        Together with :meth:`pump`'s results this closes the accounting
        loop: ``accepted == completed + failed + still-pending`` at every
        quantum boundary — no admitted query is ever silently lost.
        """
        out = self._failures
        self._failures = []
        return out

    def advance_clock(self, to_rounds: int):
        """Fast-forward the round clock across an idle gap (load replay)."""
        self.clock_rounds = max(self.clock_rounds, int(to_rounds))

    # ------------------------------------------------------------- drain #
    @property
    def in_flight(self) -> int:
        return sum(lane.stepper.occupancy for lane in self._lanes.values())

    @property
    def pending_updates(self) -> int:
        return sum(len(q) for q in self._pending_updates.values())

    @property
    def idle(self) -> bool:
        return (
            len(self.queue) == 0 and self.in_flight == 0 and self.pending_updates == 0
        )

    def drain(self, max_pumps: int = 100_000) -> list[QueryResult]:
        """Pump until queue and lanes are empty; return everything retired."""
        results: list[QueryResult] = []
        pumps = 0
        while not self.idle:
            if pumps >= max_pumps:
                raise RuntimeError(
                    f"drain did not settle within {max_pumps} pumps "
                    f"(queue={len(self.queue)}, in_flight={self.in_flight})"
                )
            results.extend(self.pump())
            pumps += 1
        return results

    def stats(self) -> dict:
        return {
            "clock_rounds": self.clock_rounds,
            "queue_depth": len(self.queue),
            "in_flight": self.in_flight,
            "pending_updates": {
                g: len(q) for g, q in self._pending_updates.items() if q
            },
            "counters": dict(self.counters),
            "rejections": dict(self.rejections),
            "breakers": {
                "/".join(key): {
                    "consecutive": b.consecutive,
                    "open": self.clock_rounds < b.open_until,
                    "open_until": b.open_until,
                }
                for key, b in self._breakers.items()
                if b.consecutive or b.open_until
            },
            "lanes": {
                "/".join(key): {
                    "occupancy": lane.stepper.occupancy,
                    "capacity": lane.stepper.capacity,
                    "delta": lane.stepper.sched.delta,
                    "backend": lane.stepper.backend,
                    "rounds_executed": lane.stepper.rounds_executed,
                    "quanta": lane.stepper.quanta,
                }
                for key, lane in self._lanes.items()
            },
        }
