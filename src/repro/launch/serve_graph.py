"""Graph-query serving from a warm solver cache, behind a typed API.

The serving-scale scenario: one resident graph, many concurrent queries.
:class:`GraphService` keeps one warm :class:`repro.solve.Solver` per problem
family and serves queries through the continuous-batching tier
(:mod:`repro.launch.service`): requests are typed
:class:`~repro.launch.service.types.QueryRequest` objects, admitted into a
bounded queue and slotted into fixed-capacity in-flight batches as converged
queries retire — the first quantum pays schedule build + compile, every later
quantum pays neither, and nobody waits for a full batch to form.

Example::

    PYTHONPATH=src python -m repro.launch.serve_graph --graph twitter \\
        --scale 12 --algo both --queries 8 --repeats 3 --delta auto \\
        --backend sharded --frontier halo --compact-every 4
"""

from __future__ import annotations

import argparse
import dataclasses
import time
import warnings

import numpy as np

from repro.core.engine import MIN_CHUNK
from repro.graphs.formats import CSRGraph
from repro.graphs.generators import make_graph
from repro.launch.service.types import (
    DEFAULT_CLASSES,
    Admission,
    ClassPolicy,
    QueryRequest,
    QueryResult,
)
from repro.solve import (
    BACKEND_FRONTIERS,
    Solver,
    label_propagation_problem,
    ppr_problem,
    rwr_embedding_problem,
    sssp_problem,
)

__all__ = ["GraphService", "main"]


class GraphService:
    """Answers SSSP / PPR / RWR / label-propagation queries on one graph.

    Vector algorithms (``"sssp"``, ``"ppr"``) retire ``(n,)`` rows; matrix
    algorithms (``"rwr"`` — F random-walk-with-restart proximity columns,
    ``"labelprop"`` — F-class semi-supervised labels) retire ``(n, F)``
    matrices, with ``F = feature_dim``.  All four share the continuous-
    batching lanes; a matrix lane's compiled loop simply carries the extra
    trailing feature axis.

    The public surface is the typed request/response API: :meth:`submit` a
    :class:`QueryRequest` (constant-time admission or a reasoned rejection),
    then :meth:`drain` (or :meth:`pump` one quantum at a time) to collect
    :class:`QueryResult` rows as queries converge.  ``batch_size`` slots per
    ``(algo, class)`` lane are part of the compiled shape; free slots ride
    along pre-converged, so one compiled loop serves every occupancy.

    ``damping`` is a property of the *service*, not the request: it must
    match the damping baked into the graph's pagerank edge values
    (``d / outdeg``), so one value covers both the link-follow mass and the
    teleport mass of every PPR query.

    ``backend`` × ``frontier`` validity is owned by one table
    (``repro.solve.BACKEND_FRONTIERS``) — this service just passes both
    through.  ``backend="pallas"`` serves every batch through the fused
    one-kernel round (frontier VMEM-resident across all commit steps — the
    lowest frontier HBM traffic on a single device); ``frontier="halo"``
    keeps the frontier sharded with halo-exchange commits for graphs larger
    than one device, served via ``backend="sharded"`` (lanes are batched
    ``vmap`` loops, so the per-shard-fused ``pallas``+``halo`` path — the
    single-query fastest configuration, with optional quantized
    ``halo_dtype`` wire — lives in ``repro.solve.Solver``, not here).
    ``compact_every`` sets the scheduling quantum in rounds (how often
    converged queries retire and queued ones slot in) for every request
    class.

    ``cache_dir`` makes the warm state survive the *process*: each solver
    persists its stripe schedules, δ-model, and AOT-exported executables to
    the content-addressed store (:mod:`repro.persist`), so a restarted
    service pointed at the same directory serves its first quantum with zero
    stripe builds and zero retraces; ``reprobe_every=N`` keeps refitting the
    δ-model from the observations production solves log there, migrating
    ``delta="auto"`` services to the measured-best δ* as traffic accumulates.

    ``sssp(sources)`` / ``ppr(seeds)`` remain as deprecated sugar over
    submit/drain (any query count — longer lists split across queue slots).
    """

    def __init__(
        self,
        graph: CSRGraph,
        n_workers: int = 8,
        delta="auto",
        batch_size: int = 8,
        min_chunk: int = MIN_CHUNK,
        damping: float = 0.85,
        backend: str = "jit",
        frontier: str = "replicated",
        compact_every: int | None = None,
        cache_dir=None,
        reprobe_every: int | None = None,
        queue_capacity: int = 64,
        per_graph_quota: int | None = None,
        classes: dict[str, ClassPolicy] | None = None,
        algos: tuple[str, ...] = ("sssp", "ppr"),
        feature_dim: int = 4,
        degrade: bool = False,
    ):
        self.graph = graph
        self.n_workers = n_workers
        self.delta = delta
        self.batch_size = batch_size
        self.min_chunk = min_chunk
        self.damping = damping
        self.backend = backend
        self.frontier = frontier
        self.compact_every = compact_every
        self.cache_dir = cache_dir
        self.reprobe_every = reprobe_every
        self.queue_capacity = queue_capacity
        self.per_graph_quota = per_graph_quota
        self.classes = classes
        self.algos = tuple(algos)
        self.feature_dim = feature_dim  # F for the matrix algos (rwr/labelprop)
        # serving deployments usually want degrade=True: a kernel fault turns
        # into a slower bit-identical answer instead of a failed lane quantum
        self.degrade = degrade
        self._solvers: dict[str, Solver] = {}
        self._scheduler = None
        self._unclaimed: list[QueryResult] = []

    def solver(self, name: str) -> Solver:
        """The warm per-problem solver (built on first use, then cached)."""
        sv = self._solvers.get(name)
        if sv is None:
            problems = {
                "sssp": sssp_problem,
                "ppr": lambda: ppr_problem(damping=self.damping),
                "rwr": lambda: rwr_embedding_problem(
                    feature_dim=self.feature_dim, damping=self.damping
                ),
                "labelprop": lambda: label_propagation_problem(
                    feature_dim=self.feature_dim
                ),
            }
            sv = Solver(
                self.graph,
                problems[name](),
                n_workers=self.n_workers,
                delta=self.delta,
                backend=self.backend,
                frontier=self.frontier,
                min_chunk=self.min_chunk,
                cache_dir=self.cache_dir,
                reprobe_every=self.reprobe_every,
                degrade=self.degrade,
            )
            self._solvers[name] = sv
        return sv

    # ------------------------------------------------------ typed surface #
    @property
    def scheduler(self):
        """The service's own single-tenant :class:`ContinuousScheduler`."""
        if self._scheduler is None:
            from repro.launch.service.scheduler import ContinuousScheduler

            classes = self.classes
            if classes is None and self.compact_every is not None:
                # legacy knob: one quantum length for every request class
                classes = {
                    name: dataclasses.replace(p, slot_rounds=self.compact_every)
                    for name, p in DEFAULT_CLASSES.items()
                }
            self._scheduler = ContinuousScheduler(
                {"default": self},
                classes=classes,
                queue_capacity=self.queue_capacity,
                per_graph_quota=self.per_graph_quota,
            )
        return self._scheduler

    def submit(self, req: QueryRequest) -> Admission:
        """Admit one request (or reject with a reason) — never blocks."""
        return self.scheduler.submit(req)

    def submit_update(self, req) -> Admission:
        """Admit one edge-update batch; it applies at a quiesced round
        boundary (see :meth:`ContinuousScheduler.submit_update`)."""
        return self.scheduler.submit_update(req)

    def take_update_results(self) -> list:
        """Applied-update lifecycle records (cleared on read)."""
        return self.scheduler.take_update_results()

    def take_failures(self) -> list:
        """Typed :class:`QueryFailure` tombstones (cleared on read)."""
        return self.scheduler.take_failures()

    def apply_updates(self, batch):
        """Mutate the resident graph in place (synchronous path).

        Every warm solver re-solves incrementally from here on
        (``Solver.resolve`` semantics); schedules are patched stripe-wise
        rather than rebuilt.  The serving tier calls this from the
        scheduler's quiesced round boundary — direct callers must ensure no
        queries are in flight.  Returns the
        :class:`~repro.graphs.updates.UpdateReport` of the applied batch.
        """
        report = None
        for sv in self._solvers.values():
            report = sv.apply_updates(batch)
        if self._solvers:
            self.graph = next(iter(self._solvers.values())).graph
        else:
            self.graph, report = self.graph.apply_updates(batch)
        return report

    def pump(self) -> list[QueryResult]:
        """Run one scheduling quantum; return the queries that retired."""
        results = self._unclaimed + self.scheduler.pump()
        self._unclaimed = []
        return results

    def drain(self) -> list[QueryResult]:
        """Pump until queue and lanes are empty; return everything retired."""
        results = self._unclaimed + self.scheduler.drain()
        self._unclaimed = []
        return results

    # ------------------------------------------------- deprecated surface #
    def _legacy_query(self, algo: str, ids) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        if ids.ndim != 1:
            raise ValueError(f"expected a 1-D query list, got shape {ids.shape}")
        if ids.size == 0:
            raise ValueError("empty query list")
        wanted: list[str] = []
        collected: dict[str, QueryResult] = {}

        def take(results):
            for r in results:
                if r.request_id in taken_ids:
                    collected[r.request_id] = r
                else:  # a typed-API caller's request — hold for their drain()
                    self._unclaimed.append(r)

        taken_ids: set[str] = set()
        for v in ids:
            while True:
                adm = self.scheduler.submit(QueryRequest(algo=algo, payload=int(v)))
                if adm.accepted:
                    wanted.append(adm.request_id)
                    taken_ids.add(adm.request_id)
                    break
                if adm.reason != "queue_full":
                    raise ValueError(f"query rejected: {adm.reason}")
                take(self.scheduler.pump())  # free queue slots, then retry
        while len(collected) < len(wanted):
            take(self.scheduler.pump())
        return np.stack([collected[rid].x for rid in wanted])

    def sssp(self, sources) -> np.ndarray:
        """(k, n) int32 distance rows, one per source.

        .. deprecated:: use ``submit(QueryRequest(algo="sssp", payload=s))``
           + ``drain()``.
        """
        warnings.warn(
            "GraphService.sssp() is deprecated; use "
            "submit(QueryRequest(algo='sssp', payload=...)) + drain()",
            DeprecationWarning,
            stacklevel=2,
        )
        return self._legacy_query("sssp", sources)

    def ppr(self, seeds) -> np.ndarray:
        """(k, n) float32 personalized-PageRank rows, one per seed.

        .. deprecated:: use ``submit(QueryRequest(algo="ppr", payload=s))``
           + ``drain()``.
        """
        warnings.warn(
            "GraphService.ppr() is deprecated; use "
            "submit(QueryRequest(algo='ppr', payload=...)) + drain()",
            DeprecationWarning,
            stacklevel=2,
        )
        return self._legacy_query("ppr", seeds)

    def stats(self) -> dict:
        return {name: dict(sv.stats) for name, sv in self._solvers.items()}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--graph", default="twitter")
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--efactor", type=int, default=8)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--delta", default="auto", help="'auto', 'sync', 'async', or int")
    ap.add_argument(
        "--algo",
        choices=["sssp", "ppr", "rwr", "labelprop", "both", "all"],
        default="both",
        help="'both' = sssp+ppr (vector algos); 'all' adds the matrix algos",
    )
    ap.add_argument("--queries", type=int, default=8, help="batch capacity Q")
    ap.add_argument(
        "--feature-dim",
        type=int,
        default=4,
        help="F for the matrix-frontier algos (rwr/labelprop)",
    )
    ap.add_argument("--repeats", type=int, default=3, help="waves per algo")
    ap.add_argument("--min-chunk", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    # valid combinations come from the repro.solve.BACKEND_FRONTIERS table —
    # the Solver rejects an unsupported pair with an exact message, so the
    # CLI no longer hard-codes which backend a frontier belongs to
    ap.add_argument("--backend", choices=sorted(BACKEND_FRONTIERS), default="jit")
    ap.add_argument("--frontier", choices=["replicated", "halo"], default="replicated")
    ap.add_argument(
        "--compact-every",
        type=int,
        default=None,
        help="scheduling quantum in rounds (default: per-class policy)",
    )
    ap.add_argument(
        "--cache-dir",
        default=None,
        help="persistent warm-start cache directory (schedules, δ-model, "
        "AOT executables survive restarts)",
    )
    ap.add_argument(
        "--reprobe-every",
        type=int,
        default=None,
        help="refit the δ-model from logged observations every N solves "
        "(requires --cache-dir and --delta auto)",
    )
    ap.add_argument(
        "--assert-warm",
        action="store_true",
        help="fail (exit 1) unless every solver served from the cache: "
        "zero stripe builds and zero retraces (the CI warm-restart gate)",
    )
    args = ap.parse_args(argv)

    delta = args.delta if args.delta in ("auto", "sync", "async") else int(args.delta)
    # PPR/RWR queries need weighted pagerank edge values; SSSP needs lengths —
    # one service per edge-value kind, same topology.  (labelprop overrides
    # edge values with unit weights itself, so any kind works.)
    if args.algo == "both":
        algos = ["sssp", "ppr"]
    elif args.algo == "all":
        algos = ["sssp", "ppr", "rwr", "labelprop"]
    else:
        algos = [args.algo]
    rng = np.random.default_rng(args.seed)
    report: dict = {"latency_s": {}, "stats": {}}
    for algo in algos:
        kind = "sssp" if algo == "sssp" else "pagerank"
        g = make_graph(args.graph, scale=args.scale, efactor=args.efactor, kind=kind)
        service = GraphService(
            g,
            n_workers=args.workers,
            delta=delta,
            batch_size=args.queries,
            min_chunk=args.min_chunk,
            backend=args.backend,
            frontier=args.frontier,
            compact_every=args.compact_every,
            cache_dir=args.cache_dir,
            reprobe_every=args.reprobe_every,
            queue_capacity=max(64, args.queries),
            algos=(algo,),
            feature_dim=args.feature_dim,
        )
        lat = []
        for rep in range(args.repeats):
            qids = rng.integers(0, g.n, args.queries)
            t0 = time.perf_counter()
            for v in qids:
                adm = service.submit(QueryRequest(algo=algo, payload=int(v)))
                assert adm.accepted, adm.reason
            out = service.drain()
            lat.append(time.perf_counter() - t0)
            assert len(out) == args.queries
            want = (
                (g.n,)
                if algo in ("sssp", "ppr")
                else (g.n, args.feature_dim)
            )
            assert all(r.x.shape == want for r in out)
        sv = service.solver(algo)
        warm = f"{min(lat[1:]) * 1e3:.1f} ms" if len(lat) > 1 else "n/a (1 repeat)"
        print(
            f"{algo}: graph={g.name} n={g.n} δ={sv.resolve_delta():d} "
            f"Q={args.queries}  cold={lat[0] * 1e3:.1f} ms  warm={warm}  "
            f"(schedule builds={sv.stats['schedule_builds']}, "
            f"compiles={sv.stats['compiles']}, "
            f"cache loads={sv.stats['cache_loads']})"
        )
        report["latency_s"][algo] = lat
        report["stats"][algo] = service.stats()[algo]
    if args.assert_warm:
        cold = {
            algo: {
                k: stats[k]
                for k in ("schedule_builds", "plan_builds", "traces")
                if stats[k]
            }
            for algo, stats in report["stats"].items()
        }
        cold = {algo: c for algo, c in cold.items() if c}
        if cold:
            raise SystemExit(
                f"--assert-warm: cold work performed despite the cache: {cold} "
                f"(cache_dir={args.cache_dir!r})"
            )
        print(
            "warm restart verified: zero stripe builds, zero plan builds, "
            "zero retraces across all solvers"
        )
    return report


if __name__ == "__main__":
    main()
