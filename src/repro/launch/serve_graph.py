"""Batched graph-query serving from a warm solver cache.

The serving-scale scenario: one resident graph, many concurrent queries.
:class:`GraphService` keeps one warm :class:`repro.solve.Solver` per problem
family; every batch of queries reuses the cached stripe schedule and compiled
loop, so steady-state latency is pure device execution — the first batch pays
schedule build + compile, every later batch pays neither.  Queries are padded
to a fixed batch size so the compiled shape never changes.

Example::

    PYTHONPATH=src python -m repro.launch.serve_graph --graph twitter \\
        --scale 12 --algo both --queries 8 --repeats 3 --delta auto \\
        --backend sharded --frontier halo --compact-every 4
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.engine import MIN_CHUNK
from repro.graphs.formats import CSRGraph
from repro.graphs.generators import make_graph
from repro.solve import (
    Solver,
    multi_source_x0,
    ppr_problem,
    ppr_teleport,
    solve_batch,
    sssp_problem,
)

__all__ = ["GraphService", "main"]


class GraphService:
    """Answers batched SSSP / personalized-PageRank queries on one graph.

    ``batch_size`` is part of the compiled shape: shorter query lists are
    padded (by repeating the last query) and the padding is stripped from the
    reply, so a single compiled loop serves every request.

    ``damping`` is a property of the *service*, not the request: it must
    match the damping baked into the graph's pagerank edge values
    (``d / outdeg``), so one value covers both the link-follow mass and the
    teleport mass of every PPR query.

    ``backend="pallas"`` serves every batch through the fused one-kernel
    round (frontier VMEM-resident across all commit steps — the lowest
    frontier HBM traffic on a single device); ``backend="sharded"`` serves
    through the ``shard_map`` engine spanning the worker mesh
    (``frontier="halo"`` keeps the frontier sharded with halo-exchange
    commits — graphs larger than one device); ``compact_every`` shrinks each
    batch to its unconverged queries every that many rounds so one straggler
    query stops taxing the whole batch.

    ``cache_dir`` makes the warm state survive the *process*: each solver
    persists its stripe schedules, δ-model, and AOT-exported executables to
    the content-addressed store (:mod:`repro.persist`), so a restarted
    service pointed at the same directory serves its first batch with zero
    stripe builds and zero retraces; ``reprobe_every=N`` keeps refitting the
    δ-model from the observations production solves log there, migrating
    ``delta="auto"`` services to the measured-best δ* as traffic accumulates.
    """

    def __init__(
        self,
        graph: CSRGraph,
        n_workers: int = 8,
        delta="auto",
        batch_size: int = 8,
        min_chunk: int = MIN_CHUNK,
        damping: float = 0.85,
        backend: str = "jit",
        frontier: str = "replicated",
        compact_every: int | None = None,
        cache_dir=None,
        reprobe_every: int | None = None,
    ):
        self.graph = graph
        self.n_workers = n_workers
        self.delta = delta
        self.batch_size = batch_size
        self.min_chunk = min_chunk
        self.damping = damping
        self.backend = backend
        self.frontier = frontier
        self.compact_every = compact_every
        self.cache_dir = cache_dir
        self.reprobe_every = reprobe_every
        self._solvers: dict[str, Solver] = {}
        self._ppr_x0 = None  # constant (batch_size, n) uniform tile, built once

    def solver(self, name: str) -> Solver:
        """The warm per-problem solver (built on first use, then cached)."""
        sv = self._solvers.get(name)
        if sv is None:
            problems = {
                "sssp": sssp_problem,
                "ppr": lambda: ppr_problem(damping=self.damping),
            }
            sv = Solver(
                self.graph,
                problems[name](),
                n_workers=self.n_workers,
                delta=self.delta,
                backend=self.backend,
                frontier=self.frontier,
                min_chunk=self.min_chunk,
                cache_dir=self.cache_dir,
                reprobe_every=self.reprobe_every,
            )
            self._solvers[name] = sv
        return sv

    def _solve(self, name: str, x0_batch, q=None):
        return solve_batch(
            self.solver(name), x0_batch, q=q, compact_every=self.compact_every
        )

    def _pad(self, arr: np.ndarray) -> tuple[np.ndarray, int]:
        k = arr.shape[0]
        if k > self.batch_size:
            raise ValueError(f"{k} queries > batch_size {self.batch_size}")
        if k < self.batch_size:
            pad = np.repeat(arr[-1:], self.batch_size - k, axis=0)
            arr = np.concatenate([arr, pad], axis=0)
        return arr, k

    def sssp(self, sources) -> np.ndarray:
        """(k, n) int32 distance rows, one per source, in one lowering."""
        sources, k = self._pad(np.atleast_1d(np.asarray(sources, np.int64)))
        res = self._solve("sssp", multi_source_x0(self.graph, sources))
        return res.x[:k]

    def ppr(self, seeds) -> np.ndarray:
        """(k, n) float32 personalized-PageRank rows, one per seed."""
        seeds, k = self._pad(np.atleast_1d(np.asarray(seeds, np.int64)))
        if self._ppr_x0 is None:
            self._ppr_x0 = np.full(
                (self.batch_size, self.graph.n), 1.0 / self.graph.n, np.float32
            )
        res = self._solve(
            "ppr", self._ppr_x0, q=ppr_teleport(self.graph, seeds, self.damping)
        )
        return res.x[:k]

    def stats(self) -> dict:
        return {name: dict(sv.stats) for name, sv in self._solvers.items()}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--graph", default="twitter")
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--efactor", type=int, default=8)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--delta", default="auto", help="'auto', 'sync', 'async', or int")
    ap.add_argument("--algo", choices=["sssp", "ppr", "both"], default="both")
    ap.add_argument("--queries", type=int, default=8, help="batch size Q")
    ap.add_argument("--repeats", type=int, default=3, help="batches per algo")
    ap.add_argument("--min-chunk", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=["jit", "pallas", "sharded"], default="jit")
    ap.add_argument("--frontier", choices=["replicated", "halo"], default="replicated")
    ap.add_argument(
        "--compact-every",
        type=int,
        default=None,
        help="straggler compaction period in rounds (default: off)",
    )
    ap.add_argument(
        "--cache-dir",
        default=None,
        help="persistent warm-start cache directory (schedules, δ-model, "
        "AOT executables survive restarts)",
    )
    ap.add_argument(
        "--reprobe-every",
        type=int,
        default=None,
        help="refit the δ-model from logged observations every N solves "
        "(requires --cache-dir and --delta auto)",
    )
    ap.add_argument(
        "--assert-warm",
        action="store_true",
        help="fail (exit 1) unless every solver served from the cache: "
        "zero stripe builds and zero retraces (the CI warm-restart gate)",
    )
    args = ap.parse_args(argv)

    delta = args.delta if args.delta in ("auto", "sync", "async") else int(args.delta)
    # PPR queries need weighted pagerank edge values; SSSP needs lengths —
    # one service per edge-value kind, same topology.
    algos = ["sssp", "ppr"] if args.algo == "both" else [args.algo]
    rng = np.random.default_rng(args.seed)
    report: dict = {"latency_s": {}, "stats": {}}
    for algo in algos:
        kind = "sssp" if algo == "sssp" else "pagerank"
        g = make_graph(args.graph, scale=args.scale, efactor=args.efactor, kind=kind)
        service = GraphService(
            g,
            n_workers=args.workers,
            delta=delta,
            batch_size=args.queries,
            min_chunk=args.min_chunk,
            backend=args.backend,
            frontier=args.frontier,
            compact_every=args.compact_every,
            cache_dir=args.cache_dir,
            reprobe_every=args.reprobe_every,
        )
        lat = []
        for rep in range(args.repeats):
            qids = rng.integers(0, g.n, args.queries)
            t0 = time.perf_counter()
            out = getattr(service, algo)(qids)
            lat.append(time.perf_counter() - t0)
            assert out.shape == (args.queries, g.n)
        sv = service.solver(algo)
        warm = f"{min(lat[1:]) * 1e3:.1f} ms" if len(lat) > 1 else "n/a (1 repeat)"
        print(
            f"{algo}: graph={g.name} n={g.n} δ={sv.resolve_delta():d} "
            f"Q={args.queries}  cold={lat[0] * 1e3:.1f} ms  warm={warm}  "
            f"(schedule builds={sv.stats['schedule_builds']}, "
            f"compiles={sv.stats['compiles']}, "
            f"cache loads={sv.stats['cache_loads']})"
        )
        report["latency_s"][algo] = lat
        report["stats"][algo] = service.stats()[algo]
    if args.assert_warm:
        cold = {
            algo: {
                k: stats[k]
                for k in ("schedule_builds", "plan_builds", "traces")
                if stats[k]
            }
            for algo, stats in report["stats"].items()
        }
        cold = {algo: c for algo, c in cold.items() if c}
        if cold:
            raise SystemExit(
                f"--assert-warm: cold work performed despite the cache: {cold} "
                f"(cache_dir={args.cache_dir!r})"
            )
        print(
            "warm restart verified: zero stripe builds, zero plan builds, "
            "zero retraces across all solvers"
        )
    return report


if __name__ == "__main__":
    main()
