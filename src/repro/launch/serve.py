"""Serving driver: batched prefill + decode loop (greedy) with KV/state cache.

Example::

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --reduced \\
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import decode_step, init_params, prefill
from repro.models.lm import pad_cache


def generate(cfg, params, prompt_tokens, gen_len: int, frames=None):
    """Greedy generation; returns (B, gen_len) int32."""
    B, S = prompt_tokens.shape
    batch = {"tokens": jnp.asarray(prompt_tokens)}
    if cfg.family == "encdec":
        batch["frames"] = frames
    logits, cache = jax.jit(lambda b: prefill(params, cfg, b))(batch)
    cache = pad_cache(cfg, cache, S + gen_len)
    dstep = jax.jit(lambda c, t: decode_step(params, cfg, c, t))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [tok]
    for _ in range(gen_len - 1):
        logits, cache = dstep(cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    assert cfg.family != "vlm", "vlm serving needs precomputed embeds; use examples/"
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    frames = None
    if cfg.family == "encdec":
        frames = jnp.asarray(
            rng.standard_normal((args.batch, cfg.enc_seq, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.dtype),
        )
    t0 = time.time()
    toks = generate(cfg, params, prompts, args.gen, frames=frames)
    dt = time.time() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s incl. compile)")
    print("first sequence:", np.asarray(toks[0]))
    return toks


if __name__ == "__main__":
    main()
