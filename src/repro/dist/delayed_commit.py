"""Delayed gradient commit: the paper's δ-buffering at training scale.

Each of ``n_pods`` pods holds a *local* parameter view ``global + delta_p``
and runs ordinary optimizer steps against it, accumulating everything it has
not yet published into ``delta_p`` — the training analogue of the engine's
thread-local buffer.  Every ``delta`` steps the pods flush: per-pod deltas
are (optionally wire-compressed and) averaged across the pod axis — the one
DCN collective — added to the replicated global store, and each pod's buffer
keeps only its compression residual (error feedback; exactly zero when
``compress="none"``), so pods resynchronize to the fresh global view.

Correspondence with the graph engine (``repro.core.engine``): the engine's
commit step publishes δ rows per worker to the frontier; here a commit
publishes one averaged parameter delta per pod to the global params.  δ=1
with identical pod batches is bit-equivalent to the plain synchronous step
(``make_train_step``), mirroring how the engine's ``S == 1`` schedule
recovers Jacobi.

Local-update semantics: each pod applies its optimizer to its *local* params,
so δ=1 with different pod shards is mean-of-local-optimizer-steps, which
differs from optimizer-on-mean-gradients by the optimizer's nonlinearity.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

__all__ = [
    "DelayedCommitConfig",
    "DelayedCommitState",
    "init_delayed_state",
    "make_delayed_commit_step",
    "pod_prefix_specs",
    "reshard_delayed_state",
]

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class DelayedCommitConfig:
    """δ-commit hyperparameters.

    ``compress`` ∈ {"none", "int8", "topk"} is applied per pod to the flushed
    delta (wire compression over DCN); ``topk_frac`` is the kept fraction for
    "topk".  ``"int8"`` sums quantized codes across pods *in int8 on the
    wire* (shared per-leaf scale, per-pod clip to ±(127 // n_pods) so the
    sum is exact) and dequantizes after the reduction; the per-pod error
    feedback keeps whatever the codes could not represent.
    """

    n_pods: int = 2
    delta: int = 1
    compress: str = "none"
    topk_frac: float = 0.1


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DelayedCommitState:
    global_params: dict  # replicated committed store
    local_delta: dict  # (n_pods, *param) uncommitted per-pod buffers
    opt_state: dict  # per-pod optimizer state (pod-stacked leaves)
    step: jnp.ndarray


def _pod_stack(leaf, n_pods: int):
    if getattr(leaf, "ndim", 0) == 0:
        return leaf  # shared scalars (e.g. the optimizer step counter)
    return jnp.broadcast_to(leaf, (n_pods,) + leaf.shape)


def _pod_axes(tree):
    """vmap in/out axes for a pod-stacked state tree: 0 on arrays, None on
    shared scalars."""
    return jax.tree.map(lambda l: 0 if getattr(l, "ndim", 0) else None, tree)


def init_delayed_state(
    cfg: ModelConfig, optimizer, cc: DelayedCommitConfig, key
) -> DelayedCommitState:
    from repro.train.train_step import init_train_state  # avoid import cycle

    base = init_train_state(cfg, optimizer, key)
    return DelayedCommitState(
        global_params=base.params,
        local_delta=jax.tree.map(
            lambda p: jnp.zeros((cc.n_pods,) + p.shape, p.dtype), base.params
        ),
        opt_state=jax.tree.map(lambda l: _pod_stack(l, cc.n_pods), base.opt_state),
        step=jnp.zeros((), jnp.int32),
    )


def pod_prefix_specs(specs):
    """Prepend the ``pod`` mesh axis to every PartitionSpec in ``specs``."""
    return jax.tree.map(
        lambda s: P(*(("pod",) + tuple(s))),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def reshard_delayed_state(state: DelayedCommitState, n_pods: int) -> DelayedCommitState:
    """Re-partition a (restored) state onto ``n_pods`` pods, elastically.

    Same pod count → the state is returned untouched (bit-identical resume).
    A different count performs one flush-equivalent commit at the *old*
    width — the mean of the per-pod deltas folds into the global store, so
    no buffered progress is lost — then lays out fresh zero buffers at the
    new width and re-provisions per-pod optimizer state from the pod mean
    (shared scalars pass through).  The fixed point does not depend on the
    pod partition (delta-accumulative iteration restarts from any
    intermediate state — Maiter), so training resumes
    fixed-point-identical, with the δ staleness bound re-established at the
    new width.
    """
    n_pods = int(n_pods)
    delta_leaves = jax.tree.leaves(state.local_delta)
    old = int(delta_leaves[0].shape[0]) if delta_leaves else n_pods
    if old == n_pods:
        return state
    new_gp = jax.tree.map(
        lambda g, d: g + jnp.asarray(d).mean(axis=0).astype(jnp.asarray(g).dtype),
        state.global_params,
        state.local_delta,
    )
    new_dl = jax.tree.map(
        lambda g: jnp.zeros((n_pods,) + jnp.asarray(g).shape, jnp.asarray(g).dtype),
        new_gp,
    )

    def re_pod(leaf):
        leaf = jnp.asarray(leaf)
        if leaf.ndim == 0:
            return leaf  # shared scalar (e.g. the optimizer step counter)
        return jnp.broadcast_to(leaf.mean(axis=0), (n_pods,) + leaf.shape[1:]).astype(
            leaf.dtype
        )

    return DelayedCommitState(
        global_params=new_gp,
        local_delta=new_dl,
        opt_state=jax.tree.map(re_pod, state.opt_state),
        step=state.step,
    )


def _compress_pod_deltas(tree, cc: DelayedCommitConfig):
    """Per-pod wire compression of delta leaves shaped (n_pods, *param).

    Value-domain modes only ("none" sends f32 verbatim, "topk" sparsifies but
    still sends f32 survivors).  ``"int8"`` is *not* here: dequantizing per
    pod before the mean would put f32 back on the DCN wire, so the int8 path
    reduces in the integer domain inside ``commit`` itself.
    """
    if cc.compress == "none":
        return tree
    if cc.compress == "int8":
        raise ValueError("int8 deltas reduce in the wire domain — see commit()")
    if cc.compress == "topk":

        def topk(d):
            flat = d.reshape(d.shape[0], -1)
            k = max(1, int(round(flat.shape[1] * cc.topk_frac)))
            thresh = jax.lax.top_k(jnp.abs(flat), k)[0][:, -1:]
            return jnp.where(jnp.abs(flat) >= thresh, flat, 0.0).reshape(d.shape)

        return jax.tree.map(topk, tree)
    raise ValueError(f"unknown compress mode {cc.compress!r}")


def make_delayed_commit_step(
    cfg: ModelConfig,
    optimizer,
    cc: DelayedCommitConfig,
    phase: str | None = None,
    param_specs=None,
):
    """Returns jit-able ``(state, pod_batch) -> (state, metrics)``.

    ``pod_batch`` leaves carry a leading ``n_pods`` axis.  ``phase`` lowers a
    single phase for HLO analysis: "local" (buffered step, no flush) or
    "commit" (flush every step); ``None`` is the real schedule — flush when
    ``(step + 1) % delta == 0``.  ``param_specs`` pins the global store (and,
    pod-prefixed, the per-pod buffers) to the parameter sharding.
    """
    from repro.models import train_loss  # avoid import cycle
    from repro.train.train_step import cast_tree

    compute_dtype = jnp.dtype(cfg.dtype)

    def loss_fn(params, batch):
        return train_loss(cast_tree(params, compute_dtype), cfg, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    pod_specs = pod_prefix_specs(param_specs) if param_specs is not None else None

    def constrain(tree, specs):
        if specs is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, specs
        )

    def commit_int8(gp, dl):
        # True int8 wire: quantize each pod's delta against a shared per-leaf
        # scale, *sum the int8 codes across the pod axis* (the DCN collective
        # ships 1-byte elements), and dequantize only after the reduction.
        # Clipping each pod to ±(127 // n_pods) makes the int8 sum exact —
        # |Σ q_p| ≤ n_pods · qcap ≤ 127 can never overflow — and each pod
        # keeps what its own codes failed to represent as error feedback.
        qcap = max(1, 127 // cc.n_pods)

        def leaf(g, d):
            # no reshapes: flattening a sharded leaf would force XLA to
            # rematerialize (all-gather) the full delta in f32, defeating
            # the wire win; elementwise ops preserve the pod-prefixed
            # sharding so only the int8 codes cross the DCN.
            scale = jnp.maximum(jnp.abs(d).max(), 1e-12) / qcap
            q = jnp.clip(jnp.round(d / scale), -qcap, qcap).astype(jnp.int8)
            total = q.sum(axis=0, dtype=jnp.int8)  # the one cross-pod reduce
            avg = total.astype(F32) * scale / cc.n_pods
            residual = d - (q.astype(F32) * scale).astype(d.dtype)
            return g + avg.astype(g.dtype), residual.astype(d.dtype)

        pairs = jax.tree.map(leaf, gp, dl)
        is_pair = lambda x: isinstance(x, tuple)  # noqa: E731
        new_gp = jax.tree.map(lambda p: p[0], pairs, is_leaf=is_pair)
        residual = jax.tree.map(lambda p: p[1], pairs, is_leaf=is_pair)
        return new_gp, residual

    def commit(gp, dl):
        if cc.compress == "int8":
            return commit_int8(gp, dl)
        committed = _compress_pod_deltas(dl, cc)
        avg = jax.tree.map(lambda c: c.mean(axis=0), committed)
        new_gp = jax.tree.map(jnp.add, gp, avg)
        residual = jax.tree.map(jnp.subtract, dl, committed)
        return new_gp, residual

    def step(state: DelayedCommitState, pod_batch):
        gp = state.global_params

        def local_fn(delta_p, opt_p, batch_p):
            params_p = jax.tree.map(jnp.add, gp, delta_p)
            (loss, lmetrics), grads = grad_fn(params_p, batch_p)
            new_params, new_opt, ometrics = optimizer.update(grads, opt_p, params_p)
            new_delta = jax.tree.map(jnp.subtract, new_params, gp)
            return new_delta, new_opt, loss, dict(lmetrics, **ometrics)

        opt_axes = _pod_axes(state.opt_state)
        new_dl, new_opt, losses, pod_metrics = jax.vmap(
            local_fn,
            in_axes=(0, opt_axes, 0),
            out_axes=(0, opt_axes, 0, 0),
        )(state.local_delta, state.opt_state, pod_batch)
        new_dl = constrain(new_dl, pod_specs)

        if phase == "local":
            new_gp, committed = gp, jnp.zeros((), F32)
        elif phase == "commit":
            new_gp, new_dl = commit(gp, new_dl)
            committed = jnp.ones((), F32)
        else:
            pred = (state.step + 1) % cc.delta == 0
            new_gp, new_dl = jax.lax.cond(
                pred, commit, lambda g, d: (g, d), gp, new_dl
            )
            committed = pred.astype(F32)
        new_gp = constrain(new_gp, param_specs)

        metrics = jax.tree.map(lambda m: m.mean(axis=0), pod_metrics)
        metrics = dict(metrics, total_loss=losses.mean(), committed=committed)
        new_state = DelayedCommitState(
            global_params=new_gp,
            local_delta=new_dl,
            opt_state=new_opt,
            step=state.step + 1,
        )
        return new_state, metrics

    return step
