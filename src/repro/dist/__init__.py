"""Multi-pod distribution layer: logical-axis sharding rules, delayed
gradient commit (the paper's δ-buffering at training scale), and shard_map
execution of the graph engine."""

from repro.dist.delayed_commit import (
    DelayedCommitConfig,
    DelayedCommitState,
    init_delayed_state,
    make_delayed_commit_step,
    pod_prefix_specs,
)
from repro.dist.engine_sharded import (
    FrontierPlan,
    frontier_plan_args,
    frontier_round_ext_fn,
    frontier_sharded_round_fn,
    input_specs_for_engine,
    make_frontier_plan,
    sharded_round_fn,
    sharded_round_fn_q,
)
from repro.dist.sharding import Rules, logical, tree_param_specs, use_rules

__all__ = [
    "DelayedCommitConfig",
    "DelayedCommitState",
    "FrontierPlan",
    "Rules",
    "frontier_plan_args",
    "frontier_round_ext_fn",
    "frontier_sharded_round_fn",
    "init_delayed_state",
    "input_specs_for_engine",
    "logical",
    "make_delayed_commit_step",
    "make_frontier_plan",
    "pod_prefix_specs",
    "sharded_round_fn",
    "sharded_round_fn_q",
    "tree_param_specs",
    "use_rules",
]
