"""shard_map execution of the delayed-async engine over a worker mesh axis.

Two distribution disciplines, both bit-identical per round to the
single-device ``round_fn`` (same update list, same order, dump slot included
for the replicated path / owned frontier for the sharded one):

* **replicated frontier** (``sharded_round_fn`` / ``sharded_round_fn_q``) —
  every device holds the whole frontier ``x_ext``; each commit all-gathers
  every worker's chunk (O(P·δ) wire per commit) and publishes with exactly
  the reference scatter.  Exactness-first; bounded by one device's memory.

* **sharded frontier with halo exchange** (``frontier_sharded_round_fn``) —
  owner-computes: each device keeps only its owned vertex block plus halo
  copies of the remote vertices its workers read (:class:`FrontierPlan`,
  built on the cut/halo sets of :class:`repro.graphs.partition.Partition`).
  Each commit publishes locally and all-gathers only the *boundary* entries
  other shards need (O(D·H) wire per commit, H = max boundary rows per
  commit step).  The halo copy of a vertex always holds its owner's last
  committed value — exactly what the replicated round reads — so rounds stay
  bit-identical while the frontier spans devices.

* **fused sharded frontier** (``frontier_pallas_round_fn``) — the same
  owner-computes discipline with each shard's commit step fused into one
  Pallas kernel (:func:`repro.kernels.round_block.fused_halo_step_fn`):
  gather/⊗/segment-⊕/row-update/publish and the boundary-row selection all
  run with the shard's frontier slice pinned in VMEM; only the boundary
  all-gather runs in XLA between kernel invocations.  This is the paper's
  thread-local buffer applied at both levels of the hierarchy at once —
  VMEM within a chip, halo across chips.  ``halo_dtype ∈ {"f32","int8",
  "fp8"}`` additionally quantizes the shipped boundary rows with per-shard
  error-feedback residuals, so the gathered elements are genuinely 1-byte
  on the wire (f32 stays bit-identical to the XLA rounds; low-precision
  converges to the same fixed point within quantization tolerance).

The schedule arrays are function arguments (not closure constants) so the
worker axis can be sharded by ``shard_map`` in_specs and the whole round is
AOT-lowerable from ``input_specs_for_engine``.  The *plan* arrays are kept
shard-major (``(D, S, P_loc, ·)``, one block per shard) so plan assembly
never materializes full ``(S, P, M)`` stripe monoliths host-side and the
``shard_map`` in_specs slice them along the leading shard axis.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.engine import DeviceSchedule
from repro.core.semiring import Semiring
from repro.dist.compat import mesh_axis_sizes, shard_map
from repro.kernels.round_block import fused_halo_step_fn

__all__ = [
    "FrontierPlan",
    "HALO_DTYPES",
    "assemble_frontier_plan",
    "build_plan_shard",
    "frontier_ef_init",
    "frontier_pallas_round_ext_fn",
    "frontier_pallas_round_fn",
    "frontier_plan_args",
    "frontier_round_ext_fn",
    "frontier_sharded_round_fn",
    "input_specs_for_engine",
    "make_frontier_plan",
    "plan_shard_bounds",
    "resolve_halo_dtype",
    "sharded_round_fn",
    "sharded_round_fn_q",
]

#: Wire dtypes supported for the fused halo exchange.  ``"f32"`` ships the
#: committed boundary rows verbatim (bit-identical rounds); ``"int8"`` /
#: ``"fp8"`` quantize per (shard, commit) with an error-feedback residual so
#: each gathered element is one byte on the wire.
HALO_DTYPES = ("f32", "int8", "fp8")

_HALO_QUANT = {
    "int8": (jnp.int8, 127.0),
    "fp8": (jnp.float8_e4m3fn, 448.0),
}


def resolve_halo_dtype(halo_dtype: str, semiring: Semiring) -> str:
    """Validate ``halo_dtype`` against :data:`HALO_DTYPES` and the semiring.

    Low-precision halo exchange quantizes in f32, so it is only defined for
    floating-point semirings (min-plus runs on int32 where rounding a path
    length would silently corrupt exactness).
    """
    if halo_dtype not in HALO_DTYPES:
        raise ValueError(
            f"halo_dtype={halo_dtype!r} not supported; choose from {HALO_DTYPES}"
        )
    if halo_dtype != "f32" and not jnp.issubdtype(
        jnp.dtype(semiring.dtype), jnp.floating
    ):
        raise ValueError(
            f"halo_dtype={halo_dtype!r} requires a floating-point semiring, "
            f"got dtype={jnp.dtype(semiring.dtype).name}"
        )
    return halo_dtype


def sharded_round_fn_q(
    sched: DeviceSchedule,
    semiring: Semiring,
    row_update,
    mesh,
    axis: str = "data",
    feature_dims: int = 0,
) -> Callable:
    """Return jit-able ``(x_ext, src, val, dst_local, rows, q) -> x_ext``.

    One full round (``S`` commit steps) with the worker dimension of the
    schedule sharded over mesh ``axis``; ``x_ext`` and the per-query params
    ``q`` stay replicated.  ``row_update`` is the 4-arg query form
    ``(old, reduced, rows, q) -> new``.  Requires ``sched.P`` divisible by the
    axis size (workers per device is static).

    ``feature_dims`` is the number of trailing feature axes on ``x_ext`` —
    0 for the classic ``(n+1,)`` vector frontier, 1 for ``(n+1, F)`` matrix
    frontiers (the feature axis stays replicated; only the worker axis
    shards).
    """
    axis_size = mesh_axis_sizes(mesh)[axis]
    if sched.P % axis_size != 0:
        raise ValueError(f"P={sched.P} not divisible by |{axis}|={axis_size}")
    delta = sched.delta

    def body(x_ext, src, val, dst_local, rows, q):
        P_loc = src.shape[1]
        feat = x_ext.shape[1:]

        def commit_step(s, x):
            src_s = jax.lax.dynamic_index_in_dim(src, s, 0, keepdims=False)
            val_s = jax.lax.dynamic_index_in_dim(val, s, 0, keepdims=False)
            dst_s = jax.lax.dynamic_index_in_dim(dst_local, s, 0, keepdims=False)
            rows_s = jax.lax.dynamic_index_in_dim(rows, s, 0, keepdims=False)

            gathered = x[src_s]  # (P_loc, M) + feat — committed frontier reads
            val_b = val_s.reshape(val_s.shape + (1,) * len(feat))
            contrib = semiring.mul(gathered, val_b)
            seg = dst_s + (jnp.arange(P_loc, dtype=jnp.int32) * (delta + 1))[:, None]
            reduced = semiring.segment_reduce(
                contrib.reshape((-1,) + feat), seg.reshape(-1), P_loc * (delta + 1)
            ).reshape((P_loc, delta + 1) + feat)[:, :delta]
            old = x[rows_s]
            new = row_update(old, reduced, rows_s, q)
            # Flush: gather every worker's chunk, publish with the reference
            # engine's scatter (same updates, same order → bit-identical).
            new_full = jax.lax.all_gather(new, axis, axis=0, tiled=True)
            rows_full = jax.lax.all_gather(rows_s, axis, axis=0, tiled=True)
            return x.at[rows_full.reshape(-1)].set(
                new_full.reshape((-1,) + feat).astype(x.dtype),
                mode="drop",
                unique_indices=False,
            )

        return jax.lax.fori_loop(0, sched.S, commit_step, x_ext)

    sched_spec = P(None, axis, None)
    x_spec = P(*((None,) * (1 + feature_dims)))
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, sched_spec, sched_spec, sched_spec, sched_spec, P()),
        out_specs=x_spec,
        check_vma=False,
    )


def sharded_round_fn(
    sched: DeviceSchedule,
    semiring: Semiring,
    row_update,
    mesh,
    axis: str = "data",
    feature_dims: int = 0,
) -> Callable:
    """Query-free surface: ``(x_ext, src, val, dst_local, rows) -> x_ext``.

    ``row_update`` is the 3-arg form ``(old, reduced, rows) -> new``.
    """
    fn_q = sharded_round_fn_q(
        sched,
        semiring,
        lambda old, reduced, rows, q: row_update(old, reduced, rows),
        mesh,
        axis,
        feature_dims,
    )

    def fn(x_ext, src, val, dst_local, rows):
        return fn_q(x_ext, src, val, dst_local, rows, jnp.zeros((), jnp.int32))

    return fn


def input_specs_for_engine(sched: DeviceSchedule, semiring: Semiring) -> tuple:
    """ShapeDtypeStructs matching ``sharded_round_fn``'s signature (AOT path)."""
    SDS = jax.ShapeDtypeStruct
    return (
        SDS((sched.n_slots,), semiring.dtype),
        SDS(sched.src.shape, jnp.int32),
        SDS(sched.val.shape, sched.val.dtype),
        SDS(sched.dst_local.shape, jnp.int32),
        SDS(sched.rows.shape, jnp.int32),
    )


# --------------------------------------------------------------------------- #
# Frontier sharding: owner-computes layout + per-commit halo exchange
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class FrontierPlan:
    """Owner-computes layout + halo-exchange indices for one ``(sched, D)``.

    Shard ``d`` (one of ``D`` mesh slots, ``P_loc = P / D`` schedule workers)
    owns vertices ``[vertex_bounds[d], vertex_bounds[d+1])`` and keeps a local
    frontier of length ``L``: owned block, then halo copies of the remote
    vertices its workers read (sorted by global id), then a dump slot at
    ``L - 1`` (absorbing schedule padding, exactly like slot ``n`` of the
    replicated ``x_ext``).

    Per commit step ``s``, shard ``d`` publishes its chunk locally and ships
    the ``≤ H`` committed rows that appear in some other shard's halo
    (``send_idx``); every shard scatters the all-gathered ``(D·H,)`` buffer
    into its own halo slots (``recv_idx``; non-resident and padding entries
    land in the dump slot).
    """

    D: int
    P_loc: int
    L: int
    H: int
    S: int
    delta: int
    n: int
    vertex_bounds: np.ndarray  # (D + 1,) int64
    halo_sizes: np.ndarray  # (D,) int64 — |halo_in| per shard
    boundary_entries_per_round: int  # true (unpadded) halo rows shipped/round
    src_loc: jnp.ndarray  # (D, S, P_loc, M) int32 — shard-major local src indices
    rows_loc: jnp.ndarray  # (D, S, P_loc, delta) int32 — shard-major row slots
    send_idx: jnp.ndarray  # (S, D, H) int32 into the flat (P_loc·delta,) chunk
    recv_idx: jnp.ndarray  # (S, D, D·H) int32 into the local frontier
    gather_index: jnp.ndarray  # (D, L) int32 — global slot of each local slot
    owned_flat: jnp.ndarray  # (n,) int32 — flat (D·L) slot owning each vertex

    # ------------------------------------------------------------------ #
    # Wire accounting (the replicated column is the engine's flush_bytes)
    # ------------------------------------------------------------------ #
    def halo_bytes_per_round(self, bytes_per_elem: int = 4) -> int:
        """Bytes each shard receives per round from the halo all-gathers."""
        return self.S * self.D * self.H * bytes_per_elem

    def replicated_bytes_per_round(self, bytes_per_elem: int = 4) -> int:
        """Same-round wire of the replicated flush (S · P · δ elements)."""
        return self.S * self.D * self.P_loc * self.delta * bytes_per_elem

    def scatter_x(self, x_ext) -> jnp.ndarray:
        """Replicated ``(n + 1,)`` frontier → stacked ``(D, L)`` local view."""
        return jnp.asarray(x_ext)[self.gather_index]

    # ------------------------------------------------------------------ #
    # persistence (repro.persist stores plans as plain npz archives)
    # ------------------------------------------------------------------ #
    def to_host_arrays(self) -> dict:
        """Flat ``{name: ndarray}`` dict round-trippable through ``np.savez``."""
        out = {
            f.name: np.asarray(getattr(self, f.name))
            for f in dataclasses.fields(self)
        }
        return out

    @classmethod
    def from_host_arrays(cls, arrays) -> "FrontierPlan":
        """Rebuild from :meth:`to_host_arrays` output (shape-validated)."""
        D, S, H, L = (int(arrays[k]) for k in ("D", "S", "H", "L"))
        plan = cls(
            D=D,
            P_loc=int(arrays["P_loc"]),
            L=L,
            H=H,
            S=S,
            delta=int(arrays["delta"]),
            n=int(arrays["n"]),
            vertex_bounds=np.asarray(arrays["vertex_bounds"], dtype=np.int64),
            halo_sizes=np.asarray(arrays["halo_sizes"], dtype=np.int64),
            boundary_entries_per_round=int(arrays["boundary_entries_per_round"]),
            src_loc=jnp.asarray(arrays["src_loc"]),
            rows_loc=jnp.asarray(arrays["rows_loc"]),
            send_idx=jnp.asarray(arrays["send_idx"]),
            recv_idx=jnp.asarray(arrays["recv_idx"]),
            gather_index=jnp.asarray(arrays["gather_index"]),
            owned_flat=jnp.asarray(arrays["owned_flat"]),
        )
        if (
            plan.send_idx.shape != (S, D, H)
            or plan.recv_idx.shape != (S, D, D * H)
            or plan.gather_index.shape != (D, L)
            or plan.vertex_bounds.shape != (D + 1,)
            or plan.src_loc.shape[:3] != (D, S, plan.P_loc)
            or plan.rows_loc.shape != (D, S, plan.P_loc, plan.delta)
        ):
            raise ValueError("plan arrays inconsistent with (S, D, H, L)")
        return plan

    def gather_x(self, x_loc, dump=None):
        """Stacked ``(D, L)+feat`` local view → ``(n + 1,)+feat`` global frontier."""
        feat = jnp.shape(x_loc)[2:]
        flat = jnp.reshape(x_loc, (-1,) + tuple(feat))
        owned = flat[self.owned_flat]
        if dump is None:
            dump = flat[-1:]
        return jnp.concatenate([owned, dump])


def plan_shard_bounds(sched: DeviceSchedule, n_shards: int) -> np.ndarray:
    """Shard vertex bounds ``vb (D + 1,)`` for ``sched`` over ``n_shards``."""
    if sched.block_bounds is None:
        raise ValueError("sched has no block_bounds (rebuild via make_schedule)")
    bounds = np.asarray(sched.block_bounds, dtype=np.int64)
    D = int(n_shards)
    if sched.P % D != 0:
        raise ValueError(f"P={sched.P} not divisible by D={D}")
    P_loc = sched.P // D
    vb = bounds[::P_loc]
    assert vb.shape == (D + 1,) and vb[-1] == sched.n
    return vb


def build_plan_shard(
    sched: DeviceSchedule, vb_lo: int, vb_hi: int, w0: int, w1: int
) -> dict:
    """One shard's plan piece: halo set + local index arrays (host numpy).

    The unit of targeted plan invalidation: it reads only the shard's own
    worker slices of the schedule (``src``/``dst_local``/``rows`` columns
    ``[w0, w1)``) and its owned interval ``[vb_lo, vb_hi)``, so it can be
    content-addressed (:func:`repro.persist.keys.plan_shard_fingerprint`) and
    reused when a mutation leaves those workers' stripes unchanged.  Dump
    slots are stored as ``-1`` sentinels because the real dump index ``L - 1``
    depends on *every* shard's halo size — :func:`assemble_frontier_plan`
    substitutes it.
    """
    src_d = np.asarray(sched.src)[:, w0:w1, :].astype(np.int64)
    real_d = np.asarray(sched.dst_local)[:, w0:w1, :] < sched.delta
    remote = real_d & ((src_d < vb_lo) | (src_d >= vb_hi))
    halo = np.unique(src_d[remote])
    owned_d = int(vb_hi - vb_lo)

    loc = np.full(src_d.shape, -1, dtype=np.int64)
    own = real_d & (src_d >= vb_lo) & (src_d < vb_hi)
    loc[own] = src_d[own] - vb_lo
    rem = real_d & ~own
    if halo.size:
        loc[rem] = owned_d + np.searchsorted(halo, src_d[rem])
    rr = np.asarray(sched.rows)[:, w0:w1, :].astype(np.int64)
    rows_loc = np.where(rr >= sched.n, -1, rr - vb_lo)
    return {
        "halo": halo,
        "src_loc": loc.astype(np.int32),
        "rows_loc": rows_loc.astype(np.int32),
    }


def make_frontier_plan(sched: DeviceSchedule, n_shards: int) -> FrontierPlan:
    """Build the owner-computes halo plan for ``sched`` over ``n_shards``.

    Halo sets are derived from the schedule's own edge lists (the same cut
    edges :meth:`repro.graphs.partition.Partition.from_bounds` reports, but
    resolved against the padded stripe layout so padding conventions can
    never drift): shard ``d``'s halo is every real source vertex its workers
    gather that lies outside its owned range.
    """
    D = int(n_shards)
    vb = plan_shard_bounds(sched, D)
    P_loc = sched.P // D
    pieces = [
        build_plan_shard(
            sched, int(vb[d]), int(vb[d + 1]), d * P_loc, (d + 1) * P_loc
        )
        for d in range(D)
    ]
    return assemble_frontier_plan(sched, D, pieces)


def assemble_frontier_plan(
    sched: DeviceSchedule, n_shards: int, pieces: list
) -> FrontierPlan:
    """Stitch per-shard pieces into a :class:`FrontierPlan`.

    ``pieces[d]`` is :func:`build_plan_shard`'s dict (freshly built or loaded
    from the content-addressed store).  Everything global — ``L``, ``H``, the
    send/recv exchange indices, ``gather_index``, ``owned_flat`` — is
    recomputed here from the halos plus the schedule's ``rows``; that is the
    cheap, shard-coupled part, so it is never cached piecewise.  Output is
    bit-identical to the monolithic plan build.
    """
    rows = np.asarray(sched.rows)
    S = sched.S
    delta, n, D = sched.delta, sched.n, int(n_shards)
    P_loc = sched.P // D
    vb = plan_shard_bounds(sched, D)
    owned = np.diff(vb)

    halo = [np.asarray(p["halo"], dtype=np.int64) for p in pieces]
    halo_sizes = np.array([h.size for h in halo], dtype=np.int64)
    L = int((owned + halo_sizes).max()) + 1 if D else 1
    dump = L - 1

    # Shard-major (D, S, P_loc, ·): each shard's block is written straight
    # from its piece — no full-width (S, P, M) stripe monolith is ever
    # materialized host-side, and shard_map in_specs slice axis 0 directly.
    src_loc = np.empty((D, S, P_loc, sched.M), dtype=np.int32)
    rows_loc = np.empty((D, S, P_loc, delta), dtype=np.int32)
    for d, p in enumerate(pieces):
        sl, rl = p["src_loc"], p["rows_loc"]
        src_loc[d] = np.where(sl < 0, dump, sl)
        rows_loc[d] = np.where(rl < 0, dump, rl)

    # Boundary traffic: per (step, shard), the committed rows some other
    # shard keeps a halo copy of.  H pads to the worst (step, shard) cell.
    boundary = (
        np.unique(np.concatenate(halo)) if halo_sizes.sum() else np.zeros(0, np.int64)
    )
    chunks = [
        [
            rows[s, d * P_loc : (d + 1) * P_loc, :].reshape(-1).astype(np.int64)
            for d in range(D)
        ]
        for s in range(S)
    ]
    send_pos = [
        [np.nonzero((c < n) & np.isin(c, boundary))[0] for c in chunks[s]]
        for s in range(S)
    ]
    counts = np.array([[p.size for p in row] for row in send_pos], dtype=np.int64)
    H = max(1, int(counts.max())) if counts.size else 1

    send_idx = np.zeros((S, D, H), dtype=np.int32)
    recv_idx = np.full((S, D, D * H), dump, dtype=np.int32)
    for s in range(S):
        for d in range(D):
            pos = send_pos[s][d]
            send_idx[s, d, : pos.size] = pos
            gv = chunks[s][d][pos]  # global vertices shipped by shard d
            for e in range(D):
                he = halo[e]
                if e == d or he.size == 0 or gv.size == 0:
                    continue
                ins = np.minimum(np.searchsorted(he, gv), he.size - 1)
                hit = he[ins] == gv
                recv_idx[s, e, d * H + np.nonzero(hit)[0]] = owned[e] + ins[hit]

    gather_index = np.full((D, L), n, dtype=np.int32)  # unused slots → dump
    owned_flat = np.zeros(n, dtype=np.int32)
    for d in range(D):
        gather_index[d, : owned[d]] = np.arange(vb[d], vb[d + 1])
        gather_index[d, owned[d] : owned[d] + halo[d].size] = halo[d]
        owned_flat[vb[d] : vb[d + 1]] = d * L + np.arange(owned[d])

    return FrontierPlan(
        D=D,
        P_loc=P_loc,
        L=L,
        H=H,
        S=S,
        delta=delta,
        n=n,
        vertex_bounds=vb,
        halo_sizes=halo_sizes,
        boundary_entries_per_round=int(counts.sum()),
        src_loc=jnp.asarray(src_loc),
        rows_loc=jnp.asarray(rows_loc),
        send_idx=jnp.asarray(send_idx),
        recv_idx=jnp.asarray(recv_idx),
        gather_index=jnp.asarray(gather_index),
        owned_flat=jnp.asarray(owned_flat),
    )


def frontier_sharded_round_fn(
    sched: DeviceSchedule,
    plan: FrontierPlan,
    semiring: Semiring,
    row_update,
    mesh,
    axis: str = "data",
    feature_dims: int = 0,
) -> Callable:
    """Owner-computes round over the sharded frontier ``(D, L)``.

    Returns jit-able
    ``(x_loc, src_loc, val, dst_local, rows, rows_loc, send_idx, recv_idx, q)
    -> x_loc`` where ``x_loc`` is the stacked per-shard frontier and
    ``row_update`` is the 4-arg query form.  Each commit step publishes the
    shard's own chunk locally, then all-gathers only the ``(D, H)`` boundary
    entries — O(boundary) wire instead of the replicated O(P·δ).

    With ``feature_dims=1`` the local frontier is ``(D, L, F)`` and each halo
    all-gather ships ``(H, F)`` boundary *blocks* — the FrontierPlan is
    unchanged; only the gathered payload widens.
    """
    axis_size = mesh_axis_sizes(mesh)[axis]
    if axis_size != plan.D:
        raise ValueError(f"plan built for D={plan.D}, mesh axis |{axis}|={axis_size}")
    delta, S = sched.delta, sched.S

    def body(x, src_loc, val, dst_local, rows_g, rows_loc, send_idx, recv_idx, q):
        # Per-shard blocks: x (1, L)+feat; plan blocks (1, S, P_loc, ·);
        # schedule cells (S, P_loc, ·); send (S, 1, H); recv (S, 1, D·H).
        sl, rl = src_loc[0], rows_loc[0]
        P_loc = sl.shape[1]
        feat = x.shape[2:]

        def commit_step(s, xv):
            src_s = jax.lax.dynamic_index_in_dim(sl, s, 0, keepdims=False)
            val_s = jax.lax.dynamic_index_in_dim(val, s, 0, keepdims=False)
            dst_s = jax.lax.dynamic_index_in_dim(dst_local, s, 0, keepdims=False)
            rg_s = jax.lax.dynamic_index_in_dim(rows_g, s, 0, keepdims=False)
            rl_s = jax.lax.dynamic_index_in_dim(rl, s, 0, keepdims=False)
            snd_s = jax.lax.dynamic_index_in_dim(send_idx, s, 0, keepdims=False)[0]
            rcv_s = jax.lax.dynamic_index_in_dim(recv_idx, s, 0, keepdims=False)[0]

            gathered = xv[src_s]  # (P_loc, M)+feat — owned + halo reads, local
            val_b = val_s.reshape(val_s.shape + (1,) * len(feat))
            contrib = semiring.mul(gathered, val_b)
            seg = dst_s + (jnp.arange(P_loc, dtype=jnp.int32) * (delta + 1))[:, None]
            reduced = semiring.segment_reduce(
                contrib.reshape((-1,) + feat), seg.reshape(-1), P_loc * (delta + 1)
            ).reshape((P_loc, delta + 1) + feat)[:, :delta]
            old = xv[rl_s]
            new = row_update(old, reduced, rg_s, q)
            newv = new.reshape((-1,) + feat).astype(xv.dtype)
            # Owner-computes publish: only this shard writes its owned rows.
            xv = xv.at[rl_s.reshape(-1)].set(newv, mode="drop", unique_indices=False)
            # Halo exchange: ship only the boundary entries of this commit.
            buf = jax.lax.all_gather(newv[snd_s], axis, axis=0, tiled=True)
            return xv.at[rcv_s].set(
                buf.astype(xv.dtype), mode="drop", unique_indices=False
            )

        return jax.lax.fori_loop(0, S, commit_step, x[0])[None]

    cell = P(None, axis, None)
    block = P(axis, None, None, None)
    x_spec = P(axis, *((None,) * (1 + feature_dims)))
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, block, cell, cell, cell, block, cell, cell, P()),
        out_specs=x_spec,
        check_vma=False,
    )


def frontier_round_ext_fn(
    sched: DeviceSchedule,
    plan: FrontierPlan,
    semiring: Semiring,
    row_update,
    mesh,
    axis: str = "data",
    feature_dims: int = 0,
) -> Callable:
    """Global-frontier view of the halo round: ``(x_ext, q, *plan args) -> x_ext``.

    Scatters ``x_ext`` into the owner-computes layout, runs one halo round,
    and gathers the owned entries back (the dump slot passes through), so
    host-driven convergence loops and residuals see the familiar
    ``(n + 1,)+feat`` frontier.  Argument order after ``q`` matches
    :func:`frontier_plan_args`.
    """
    rnd = frontier_sharded_round_fn(
        sched, plan, semiring, row_update, mesh, axis, feature_dims
    )

    def fn(
        x_ext, q, src_loc, val, dst_local, rows_g, rows_loc, send, recv, gidx, oflat
    ):
        feat = x_ext.shape[1:]
        x_loc = x_ext[gidx]
        x_out = rnd(x_loc, src_loc, val, dst_local, rows_g, rows_loc, send, recv, q)
        owned = x_out.reshape((-1,) + feat)[oflat]
        return jnp.concatenate([owned, x_ext[-1:]])

    return fn


def frontier_ef_init(plan: FrontierPlan, feat: tuple = ()) -> jnp.ndarray:
    """Zero error-feedback residuals ``(D, S, H)+feat`` f32 for the quantized halo.

    One residual per (shard, commit step, boundary row[, feature column]):
    whatever the quantizer could not represent this round is added back to the
    same boundary row's send value next round, so quantization error
    accumulates into the iteration as bounded staleness instead of bias.
    Harmless (all zeros stay zero) when ``halo_dtype="f32"``.  ``feat`` is the
    frontier's trailing feature shape — matrix frontiers quantize per column,
    so they carry per-feature residuals.
    """
    return jnp.zeros((plan.D, plan.S, plan.H) + tuple(feat), jnp.float32)


def frontier_pallas_round_fn(
    sched: DeviceSchedule,
    plan: FrontierPlan,
    semiring: Semiring,
    row_update,
    mesh,
    axis: str = "data",
    halo_dtype: str = "f32",
    interpret: bool | None = None,
    feature_dims: int = 0,
) -> Callable:
    """Fused owner-computes round: one Pallas kernel per commit per shard.

    Returns jit-able
    ``(x_loc, ef, src_loc, val, dst_local, rows, rows_loc, send_idx, recv_idx,
    q) -> (x_loc, ef)``.  Identical exchange discipline to
    :func:`frontier_sharded_round_fn`, but each shard's commit step —
    gather/⊗/segment-⊕/row-update/publish plus boundary-row selection — runs
    as a single :func:`repro.kernels.round_block.fused_halo_step_fn` kernel
    with the shard's ``(L,)`` frontier slice pinned in VMEM.  Only the
    ``(D, H)`` boundary all-gather (and, quantized, a ``(D,)`` scale gather)
    runs in XLA between kernel invocations; the cross-shard dependency of
    commit ``s`` on commit ``s - 1`` makes that exchange irreducible.

    ``halo_dtype="f32"`` is bit-identical per round to the XLA halo round
    (and hence to every other backend).  ``"int8"`` / ``"fp8"`` quantize each
    shard's send rows against a per-(shard, commit) max-abs scale with
    error-feedback residuals ``ef`` carried across rounds — the all-gathered
    payload is genuinely 1 byte/element on the wire, at the price of
    quantization noise entering the iteration as extra staleness.

    With ``feature_dims=1`` each send is an ``(H, F)`` boundary block and
    quantization applies **per feature column**: the max-abs scale is ``(F,)``
    per (shard, commit) and the error-feedback residuals carry a feature axis,
    so one large column can never wash out another's resolution.
    """
    axis_size = mesh_axis_sizes(mesh)[axis]
    if axis_size != plan.D:
        raise ValueError(f"plan built for D={plan.D}, mesh axis |{axis}|={axis_size}")
    resolve_halo_dtype(halo_dtype, semiring)
    qinfo = _HALO_QUANT.get(halo_dtype)
    S, H = sched.S, plan.H
    step = fused_halo_step_fn(
        semiring,
        row_update,
        P_loc=plan.P_loc,
        M=sched.M,
        delta=sched.delta,
        L=plan.L,
        H=H,
        interpret=interpret,
    )

    def body(x, ef, src_loc, val, dst_local, rows_g, rows_loc, send_idx, recv_idx, q):
        # Per-shard blocks: x (1, L); ef (1, S, H); plan blocks
        # (1, S, P_loc, ·); schedule cells (S, P_loc, ·); send (S, 1, H);
        # recv (S, 1, D·H).
        sl, rl = src_loc[0], rows_loc[0]

        def commit_step(s, carry):
            xv, efv = carry
            src_s = jax.lax.dynamic_index_in_dim(sl, s, 0, keepdims=False)
            val_s = jax.lax.dynamic_index_in_dim(val, s, 0, keepdims=False)
            dst_s = jax.lax.dynamic_index_in_dim(dst_local, s, 0, keepdims=False)
            rg_s = jax.lax.dynamic_index_in_dim(rows_g, s, 0, keepdims=False)
            rl_s = jax.lax.dynamic_index_in_dim(rl, s, 0, keepdims=False)
            snd_s = jax.lax.dynamic_index_in_dim(send_idx, s, 0, keepdims=False)[0]
            rcv_s = jax.lax.dynamic_index_in_dim(recv_idx, s, 0, keepdims=False)[0]

            # Fused commit: publish locally, select boundary rows, in-place
            # on the VMEM-resident frontier slice.
            xv, send = step(xv, src_s, val_s, dst_s, rg_s, rl_s, snd_s, q)

            if qinfo is None:
                buf = jax.lax.all_gather(send, axis, axis=0, tiled=True)
                xv = xv.at[rcv_s].set(
                    buf.astype(xv.dtype), mode="drop", unique_indices=False
                )
                return xv, efv

            qdtype, qmax = qinfo
            ef_s = jax.lax.dynamic_index_in_dim(efv, s, 0, keepdims=False)
            want = send.astype(jnp.float32) + ef_s  # (H,)+feat
            # Per-feature max-abs scale: () for vectors, (F,) for matrices.
            scale = jnp.maximum(jnp.max(jnp.abs(want), axis=0), 1e-30) / qmax
            if qdtype == jnp.int8:
                qv = jnp.clip(jnp.round(want / scale), -qmax, qmax).astype(qdtype)
            else:
                qv = jnp.clip(want / scale, -qmax, qmax).astype(qdtype)
            # 1-byte elements on the wire; scales are a (D,)+feat f32 side
            # channel.
            qbuf = jax.lax.all_gather(qv, axis, axis=0, tiled=True)
            sbuf = jax.lax.all_gather(scale[None], axis, axis=0, tiled=True)
            deq = qbuf.astype(jnp.float32) * jnp.repeat(sbuf, H, axis=0)
            efv = jax.lax.dynamic_update_index_in_dim(
                efv, want - qv.astype(jnp.float32) * scale, s, 0
            )
            xv = xv.at[rcv_s].set(
                deq.astype(xv.dtype), mode="drop", unique_indices=False
            )
            return xv, efv

        xv, efv = jax.lax.fori_loop(0, S, commit_step, (x[0], ef[0]))
        return xv[None], efv[None]

    cell = P(None, axis, None)
    block = P(axis, None, None, None)
    x_spec = P(axis, *((None,) * (1 + feature_dims)))
    ef_spec = P(axis, *((None,) * (2 + feature_dims)))
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(
            x_spec,
            ef_spec,
            block,
            cell,
            cell,
            cell,
            block,
            cell,
            cell,
            P(),
        ),
        out_specs=(x_spec, ef_spec),
        check_vma=False,
    )


def frontier_pallas_round_ext_fn(
    sched: DeviceSchedule,
    plan: FrontierPlan,
    semiring: Semiring,
    row_update,
    mesh,
    axis: str = "data",
    halo_dtype: str = "f32",
    interpret: bool | None = None,
    feature_dims: int = 0,
) -> Callable:
    """Global-frontier view of the fused halo round.

    ``(x_ext, ef, q, *plan args) -> (x_ext, ef)`` — same scatter/gather
    framing as :func:`frontier_round_ext_fn` (argument order after ``q``
    matches :func:`frontier_plan_args`), with the error-feedback residuals
    threaded through so callers carry them across rounds.
    """
    rnd = frontier_pallas_round_fn(
        sched,
        plan,
        semiring,
        row_update,
        mesh,
        axis,
        halo_dtype,
        interpret,
        feature_dims,
    )

    def fn(
        x_ext,
        ef,
        q,
        src_loc,
        val,
        dst_local,
        rows_g,
        rows_loc,
        send,
        recv,
        gidx,
        oflat,
    ):
        feat = x_ext.shape[1:]
        x_loc = x_ext[gidx]
        x_out, ef_out = rnd(
            x_loc, ef, src_loc, val, dst_local, rows_g, rows_loc, send, recv, q
        )
        owned = x_out.reshape((-1,) + feat)[oflat]
        return jnp.concatenate([owned, x_ext[-1:]]), ef_out

    return fn


def frontier_plan_args(sched: DeviceSchedule, plan: FrontierPlan) -> tuple:
    """The runtime argument tuple for :func:`frontier_round_ext_fn`."""
    return (
        plan.src_loc,
        sched.val,
        sched.dst_local,
        sched.rows,
        plan.rows_loc,
        plan.send_idx,
        plan.recv_idx,
        plan.gather_index,
        plan.owned_flat,
    )
