"""shard_map execution of the delayed-async engine over a worker mesh axis.

``sharded_round_fn`` distributes the ``P`` schedule workers over a mesh axis:
each device runs the chunk-SpMV + row update for its worker shard against the
replicated frontier, then the per-chunk results are all-gathered (the flush
collective) and published with *exactly* the scatter the single-device
``round_fn`` executes — same update list, same order — so the sharded round
is bit-identical to the reference, dump slot included.

The schedule arrays are function arguments (not closure constants) so the
worker axis can be sharded by ``shard_map`` in_specs and the whole round is
AOT-lowerable from ``input_specs_for_engine``.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.engine import DeviceSchedule
from repro.core.semiring import Semiring
from repro.dist.compat import mesh_axis_sizes, shard_map

__all__ = ["input_specs_for_engine", "sharded_round_fn"]


def sharded_round_fn(
    sched: DeviceSchedule,
    semiring: Semiring,
    row_update,
    mesh,
    axis: str = "data",
) -> Callable:
    """Return jit-able ``(x_ext, src, val, dst_local, rows) -> x_ext``.

    One full round (``S`` commit steps) with the worker dimension of the
    schedule sharded over mesh ``axis``; ``x_ext`` stays replicated.  Requires
    ``sched.P`` divisible by the axis size (workers per device is static).
    """
    axis_size = mesh_axis_sizes(mesh)[axis]
    if sched.P % axis_size != 0:
        raise ValueError(f"P={sched.P} not divisible by |{axis}|={axis_size}")
    delta = sched.delta

    def body(x_ext, src, val, dst_local, rows):
        P_loc = src.shape[1]

        def commit_step(s, x):
            src_s = jax.lax.dynamic_index_in_dim(src, s, 0, keepdims=False)
            val_s = jax.lax.dynamic_index_in_dim(val, s, 0, keepdims=False)
            dst_s = jax.lax.dynamic_index_in_dim(dst_local, s, 0, keepdims=False)
            rows_s = jax.lax.dynamic_index_in_dim(rows, s, 0, keepdims=False)

            gathered = x[src_s]  # (P_loc, M) — committed frontier reads
            contrib = semiring.mul(gathered, val_s)
            seg = dst_s + (jnp.arange(P_loc, dtype=jnp.int32) * (delta + 1))[:, None]
            reduced = semiring.segment_reduce(
                contrib.reshape(-1), seg.reshape(-1), P_loc * (delta + 1)
            ).reshape(P_loc, delta + 1)[:, :delta]
            old = x[rows_s]
            new = row_update(old, reduced, rows_s)
            # Flush: gather every worker's chunk, publish with the reference
            # engine's scatter (same updates, same order → bit-identical).
            new_full = jax.lax.all_gather(new, axis, axis=0, tiled=True)
            rows_full = jax.lax.all_gather(rows_s, axis, axis=0, tiled=True)
            return x.at[rows_full.reshape(-1)].set(
                new_full.reshape(-1).astype(x.dtype),
                mode="drop",
                unique_indices=False,
            )

        return jax.lax.fori_loop(0, sched.S, commit_step, x_ext)

    sched_spec = P(None, axis, None)
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(None), sched_spec, sched_spec, sched_spec, sched_spec),
        out_specs=P(None),
        check_vma=False,
    )


def input_specs_for_engine(sched: DeviceSchedule, semiring: Semiring) -> tuple:
    """ShapeDtypeStructs matching ``sharded_round_fn``'s signature (AOT path)."""
    SDS = jax.ShapeDtypeStruct
    return (
        SDS((sched.n_slots,), semiring.dtype),
        SDS(sched.src.shape, jnp.int32),
        SDS(sched.val.shape, sched.val.dtype),
        SDS(sched.dst_local.shape, jnp.int32),
        SDS(sched.rows.shape, jnp.int32),
    )
