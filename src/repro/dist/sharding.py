"""Logical-axis sharding rules (DESIGN.md §7).

Model code annotates activations with *logical* axis names
(:func:`logical`) and never mentions mesh axes; a :class:`Rules` table maps
logical names to physical mesh axes (``pod`` / ``data`` / ``model``) per
deployment.  :func:`tree_param_specs` resolves a parameter pytree to
``PartitionSpec``s by parameter name — FSDP over ``data`` on the d_model
dimension, tensor parallel over ``model`` on heads / ff / vocab / experts —
dropping any axis that does not divide the dimension, so the same rules apply
to every arch in the registry and to reduced CPU configs alike.

Outside a mesh context (unit tests, single-device smoke runs) every
annotation is a no-op, so model code is mesh-free by default.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
from jax.sharding import PartitionSpec as P

from repro.dist.compat import current_mesh, mesh_axis_sizes

__all__ = ["Rules", "current_rules", "logical", "tree_param_specs", "use_rules"]

_active = threading.local()


@dataclasses.dataclass(frozen=True)
class Rules:
    """Logical-axis → mesh-axis mapping.

    Values are a mesh axis name, a tuple of names (the dim is sharded over
    their product, e.g. batch over ``("pod", "data")``), or ``None``
    (replicated).
    """

    mapping: dict

    @classmethod
    def default(cls, shard_cache_heads: bool = False, seq_axis=None) -> "Rules":
        """The production mapping (DESIGN.md §7).

        ``seq_axis="model"`` turns on Megatron-style sequence parallelism for
        the residual stream; ``shard_cache_heads`` moves the decode kv cache
        from sequence-sharded to head-sharded (when heads divide the model
        axis).
        """
        return cls(
            mapping={
                # activations
                "batch": ("pod", "data"),
                "seq": seq_axis,
                "embed": None,
                "vocab": "model",
                # parameters
                "embed_fsdp": "data",
                "heads": "model",
                "ff": "model",
                "experts": "model",
                # decode cache
                "cache_batch": ("pod", "data"),
                "kv_heads": "model" if shard_cache_heads else None,
                "cache_seq": None if shard_cache_heads else "model",
            }
        )

    def to_dict(self) -> dict:
        return dict(self.mapping)

    @classmethod
    def from_dict(cls, d: dict) -> "Rules":
        return cls(mapping=dict(d))

    def physical(self, logical_axes) -> tuple:
        """Resolve logical names to raw mesh-axis entries (no mesh filtering)."""
        return tuple(
            self.mapping.get(a) if isinstance(a, str) else a for a in logical_axes
        )

    def spec(self, logical_axes, mesh, shape) -> P:
        """PartitionSpec for ``shape`` under ``mesh``.

        Axes absent from the mesh, axes whose size does not divide the
        dimension, and axes already consumed by an earlier dimension are
        dropped (replicated) — the same leniency jit demands of argument
        shardings.
        """
        sizes = mesh_axis_sizes(mesh)
        used: set = set()
        out = []
        for dim, entry in zip(shape, self.physical(logical_axes)):
            axes = entry if isinstance(entry, tuple) else (entry,)
            axes = tuple(
                a for a in axes if a is not None and a in sizes and a not in used
            )
            total = 1
            for a in axes:
                total *= sizes[a]
            if not axes or dim % total != 0:
                out.append(None)
                continue
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
        return P(*out)


@contextlib.contextmanager
def use_rules(rules: Rules):
    """Activate ``rules`` for :func:`logical` annotations under this scope."""
    prev = getattr(_active, "rules", None)
    _active.rules = rules
    try:
        yield rules
    finally:
        _active.rules = prev


def current_rules() -> Rules | None:
    return getattr(_active, "rules", None)


def logical(x, axes):
    """Constrain ``x`` to the sharding its logical ``axes`` resolve to.

    A no-op (returns ``x`` itself) when no rules or no mesh are active, so
    model code runs unmodified on a single device.
    """
    rules = current_rules()
    mesh = current_mesh()
    if rules is None or mesh is None:
        return x
    spec = rules.spec(axes, mesh, x.shape)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------------------- #
# Parameter specs by name
# --------------------------------------------------------------------------- #

# Logical axes of each named parameter's *trailing* dims; leading dims (layer
# stacks) are padded with None.  Unknown names stay replicated.
_PARAM_AXES = {
    # embeddings / unembedding
    "embed": ("vocab", "embed_fsdp"),
    "w_out": ("embed_fsdp", "vocab"),
    # attention projections (column-, column-, column-, row-parallel)
    "wq": ("embed_fsdp", "heads"),
    "wk": ("embed_fsdp", "heads"),
    "wv": ("embed_fsdp", "heads"),
    "wo": ("heads", "embed_fsdp"),
    "xq": ("embed_fsdp", "heads"),
    "xk": ("embed_fsdp", "heads"),
    "xv": ("embed_fsdp", "heads"),
    "xo": ("heads", "embed_fsdp"),
    # dense MLP
    "wg": ("embed_fsdp", "ff"),
    "wu": ("embed_fsdp", "ff"),
    "wd": ("ff", "embed_fsdp"),
    # mamba2 / rglru
    "in_proj": ("embed_fsdp", "heads"),
    "out_proj": ("heads", "embed_fsdp"),
    "w_gate": ("embed_fsdp", "heads"),
    "w_x": ("embed_fsdp", "heads"),
    "w_r": ("embed_fsdp", "heads"),
    "w_i": ("embed_fsdp", "heads"),
    "w_out_proj": ("heads", "embed_fsdp"),
}

# Inside a "moe" subtree the 3-D expert weights gain a leading experts dim
# and FSDP moves to the middle (matching the shard_map EP in_specs).
_MOE_AXES = {
    "router": (None, None),
    "wg": ("experts", "embed_fsdp", None),
    "wu": ("experts", "embed_fsdp", None),
    "wd": ("experts", None, "embed_fsdp"),
}


def _path_keys(path) -> list:
    keys = []
    for entry in path:
        name = getattr(entry, "key", None)
        if name is None:
            name = getattr(entry, "name", None)
        if name is None and hasattr(entry, "idx"):
            name = entry.idx
        keys.append(name)
    return keys


def tree_param_specs(params, rules: Rules, mesh) -> dict:
    """PartitionSpec pytree mirroring ``params`` (arrays or ShapeDtypeStructs).

    Resolution is by leaf name through ``rules`` with divisibility checked
    against the mesh, so the result is directly usable as jit in/out
    shardings for any config in the registry.
    """

    def spec_for(path, leaf):
        keys = _path_keys(path)
        name = next((k for k in reversed(keys) if isinstance(k, str)), None)
        table = _MOE_AXES if "moe" in keys else _PARAM_AXES
        axes = table.get(name, _PARAM_AXES.get(name)) if name else None
        shape = tuple(leaf.shape)
        if axes is None or len(axes) > len(shape):
            return P(*([None] * len(shape)))
        pad = (None,) * (len(shape) - len(axes))
        return rules.spec(pad + tuple(axes), mesh, shape)

    return jax.tree_util.tree_map_with_path(spec_for, params)
