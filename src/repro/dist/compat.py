"""Version portability for the jax sharding surface this repo drives.

The distribution layer is written against the modern jax API (``jax.set_mesh``,
``jax.shard_map``, ``AxisType`` meshes, ``get_abstract_mesh``).  The pinned
toolchain ships jax 0.4.x where those either do not exist or live under
experimental names; every call site in this repo goes through this module so
each symbol is resolved once, here, instead of being feature-detected at every
use.  On a current jax the wrappers are thin pass-throughs.
"""

from __future__ import annotations

import contextlib
import threading

import jax

__all__ = [
    "AxisType",
    "current_mesh",
    "export_deserialize",
    "export_serialize",
    "make_mesh",
    "set_mesh",
    "shard_map",
]

try:  # jax >= 0.6
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    import enum

    class AxisType(enum.Enum):
        """Stand-in for jax.sharding.AxisType on jax 0.4.x.

        Old meshes have no per-axis type; carrying the enum keeps mesh
        construction sites identical across versions.
        """

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


_local = threading.local()


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` accepting ``axis_types`` on every jax version."""
    try:
        return jax.make_mesh(
            axis_shapes, axis_names, axis_types=axis_types, devices=devices
        )
    except TypeError:  # jax 0.4.x: no axis_types kwarg
        return jax.make_mesh(axis_shapes, axis_names, devices=devices)


@contextlib.contextmanager
def set_mesh(mesh):
    """Activate ``mesh`` for sharding-constraint resolution (context manager).

    Maps to ``jax.set_mesh`` when available, else to the legacy global mesh
    context (``with mesh:``), which is what lets bare ``PartitionSpec``s in
    ``with_sharding_constraint`` resolve on jax 0.4.x.
    """
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
        return
    prev = getattr(_local, "mesh", None)
    _local.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _local.mesh = prev


def current_mesh():
    """The mesh active for tracing, or ``None`` outside any mesh context.

    The legacy stash is consulted first so this stays in sync with whatever
    path :func:`set_mesh` took — on jax versions that have
    ``get_abstract_mesh`` but not ``jax.set_mesh`` the abstract mesh is never
    populated, and probing it first would silently report no mesh.
    """
    mesh = getattr(_local, "mesh", None)
    if mesh is not None:
        return mesh
    if hasattr(jax.sharding, "get_abstract_mesh"):
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and mesh.axis_names:
            return mesh
        return None
    # `with mesh:` entered directly rather than through set_mesh()
    from jax._src.mesh import thread_resources

    mesh = thread_resources.env.physical_mesh
    return mesh if mesh.axis_names else None


def mesh_axis_sizes(mesh) -> dict:
    """{axis name: size} for concrete and abstract meshes alike."""
    shape = getattr(mesh, "shape", None)
    if shape is not None:
        return dict(shape)
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on every jax version.

    jax 0.4.x returns a one-element list of per-program dicts; newer jax
    returns the dict directly.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def export_serialize(fn, args) -> bytes | None:
    """AOT-export ``jit(fn)`` for ``args``' shapes to a portable blob, or None.

    The blob is the :mod:`jax.export` serialization of the traced program —
    closure constants (stripe schedules, Jacobi tables) baked in — and
    deserializes on any same-version jax without re-running the Python that
    built ``fn``.  Returns ``None`` (callers then keep their freshly traced
    executable for this process only — the lower-only fallback) when:

    * jax predates ``jax.export`` (the 0.4.x floor this repo's compat layer
      targets has it, but the graceful path costs nothing);
    * the program spans **more than one device** (a shard_map export pins the
      device assignment and refuses to load into a different-width context, so
      persisting it could never hit);
    * export itself rejects the program (exotic primitives).
    """
    try:
        from jax import export as jax_export
    except ImportError:  # pragma: no cover - depends on installed jax
        return None
    try:
        specs = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tuple(args)
        )
        exported = jax_export.export(jax.jit(fn))(*specs)
        if exported.nr_devices != 1:
            return None
        return exported.serialize()
    except Exception:
        return None


def export_deserialize(blob: bytes):
    """The jit-able callable of a serialized export, or ``None`` on any failure.

    A corrupt, truncated, or version-incompatible blob is a cache *miss* (the
    caller re-traces), never an error surfaced to the solve path.
    """
    try:
        from jax import export as jax_export

        return jax_export.deserialize(blob).call
    except Exception:
        return None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """``jax.shard_map`` with the modern keyword surface on every version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )
