"""Transformer building blocks: attention layer, dense MLP, MoE MLP.

Every block is a pair ``init_*(key, cfg) -> params`` / ``apply_*(params, x,
...) -> y`` over plain dicts of jnp arrays, so parameter trees stack cleanly
along a leading layer axis for ``lax.scan`` and shard with PartitionSpecs
resolved by name (repro.dist.sharding).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.compat import current_mesh, shard_map
from repro.dist.sharding import logical
from repro.models.config import ModelConfig
from repro.models.layers import (
    F32,
    act_fn,
    apply_rope,
    decode_attention,
    dense_init,
    flash_attention,
    rms_norm,
    split_keys,
)

# --------------------------------------------------------------------------- #
# Attention layer (self-attention + MLP), llama-style pre-norm
# --------------------------------------------------------------------------- #


def init_attn_layer(key, cfg: ModelConfig, dtype):
    d, hd, Hq, Hkv, ff = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv, cfg.d_ff
    ks = split_keys(key, 8)
    p = {
        "ln1": jnp.ones((d,), dtype),
        "wq": dense_init(ks[0], (d, Hq * hd), dtype),
        "wk": dense_init(ks[1], (d, Hkv * hd), dtype),
        "wv": dense_init(ks[2], (d, Hkv * hd), dtype),
        "wo": dense_init(ks[3], (Hq * hd, d), dtype),
        "ln2": jnp.ones((d,), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    if cfg.family == "moe":
        p["moe"] = init_moe_mlp(ks[4], cfg, dtype)
    else:
        p["mlp"] = init_dense_mlp(ks[4], d, ff, dtype)
    return p


def _qkv(p, cfg: ModelConfig, x, angles):
    B, S, d = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    # Megatron-SP boundary: re-gather the sequence here so the projections
    # run (tokens_full × d) × (d × out_shard) — weight grads then reduce
    # *sharded* instead of as full-matrix all-reduces (§Perf).
    h = logical(h, ("batch", None, "embed"))
    q = (h @ p["wq"]).reshape(B, S, Hq, hd)
    k = (h @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (h @ p["wv"]).reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    return q, k, v


def apply_attn_layer(p, cfg: ModelConfig, x, angles, *, window=0, causal=True):
    """Training / prefill path (no cache). Returns (y, (k, v)) for caching."""
    B, S, d = x.shape
    q, k, v = _qkv(p, cfg, x, angles)
    o = flash_attention(
        q,
        k,
        v,
        causal=causal,
        q_chunk=cfg.q_chunk,
        kv_chunk=cfg.kv_chunk,
        window=window,
        schedule=cfg.attn_schedule,
    )
    o = logical(o.reshape(B, S, -1) @ p["wo"], ("batch", "seq", "embed"))
    x = x + o  # reduce-scatter back to the SP layout
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = apply_moe_mlp(p["moe"], cfg, h)
    else:
        h = logical(h, ("batch", None, "embed"))  # SP boundary (MLP)
        y = logical(apply_dense_mlp(p["mlp"], cfg, h), ("batch", "seq", "embed"))
        aux = jnp.zeros((), F32)
    return x + y, (k, v), aux


def _quant_i8(x):
    """x (..., hd) → (int8, f32 scale over hd)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(F32)), axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(F32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def apply_attn_layer_decode(
    p, cfg: ModelConfig, x, angles, cache, cur_len, *, window=0
):
    """Decode path: x (B,1,d); cache = (k_cache, v_cache) (B,S,Hkv,hd) or the
    int8-quantized 4-tuple (k_i8, v_i8, k_scale, v_scale)."""
    B, _, d = x.shape
    q, k_new, v_new = _qkv(p, cfg, x, angles)
    quant = cfg.kv_quant_int8 and len(cache) == 4
    if quant:
        k_cache, v_cache, k_sc, v_sc = cache
    else:
        k_cache, v_cache = cache
    mesh = _current_mesh_info()
    S = k_cache.shape[1]
    if (
        mesh is not None
        and "model" in mesh.axis_names
        and mesh.shape["model"] > 1
        and cfg.n_kv % mesh.shape["model"] != 0  # cache is seq-sharded
        and S % mesh.shape["model"] == 0
        and not window
    ):
        # §Perf: sequence-parallel decode — local cache write + partial
        # softmax, psum-combined (replaces cache-sized all-gathers).
        from repro.models.layers import seq_parallel_decode_attention

        scales = (k_sc, v_sc) if quant else None
        o, new_cache = seq_parallel_decode_attention(
            q, k_cache, v_cache, k_new, v_new, cur_len, mesh, scales=scales
        )
        x = x + o.reshape(B, 1, -1) @ p["wo"]
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = apply_moe_mlp(p["moe"], cfg, h)
        else:
            y = apply_dense_mlp(p["mlp"], cfg, h)
        return x + y, new_cache
    # write the new kv at cur_len (per-batch dynamic index)
    idx = cur_len  # (B,)
    bidx = jnp.arange(B)
    if quant:
        kq, ks = _quant_i8(k_new[:, 0])
        vq, vs = _quant_i8(v_new[:, 0])
        k_cache = k_cache.at[bidx, idx].set(kq)
        v_cache = v_cache.at[bidx, idx].set(vq)
        k_sc = k_sc.at[bidx, idx].set(ks)
        v_sc = v_sc.at[bidx, idx].set(vs)
        k_deq = (k_cache.astype(F32) * k_sc[..., None]).astype(k_new.dtype)
        v_deq = (v_cache.astype(F32) * v_sc[..., None]).astype(v_new.dtype)
        o = decode_attention(q, k_deq, v_deq, (cur_len + 1)[:, None], window=window)
        new_cache = (k_cache, v_cache, k_sc, v_sc)
    else:
        k_cache = k_cache.at[bidx, idx].set(k_new[:, 0])
        v_cache = v_cache.at[bidx, idx].set(v_new[:, 0])
        o = decode_attention(q, k_cache, v_cache, (cur_len + 1)[:, None], window=window)
        new_cache = (k_cache, v_cache)
    x = x + o.reshape(B, 1, -1) @ p["wo"]
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = apply_moe_mlp(p["moe"], cfg, h)
    else:
        y = apply_dense_mlp(p["mlp"], cfg, h)
    return x + y, new_cache


# --------------------------------------------------------------------------- #
# Dense (SwiGLU / GeLU) MLP
# --------------------------------------------------------------------------- #


def init_dense_mlp(key, d, ff, dtype):
    ks = split_keys(key, 3)
    return {
        "wg": dense_init(ks[0], (d, ff), dtype),
        "wu": dense_init(ks[1], (d, ff), dtype),
        "wd": dense_init(ks[2], (ff, d), dtype),
    }


def apply_dense_mlp(p, cfg: ModelConfig, h):
    a = act_fn(cfg.act)
    return (a(h @ p["wg"]) * (h @ p["wu"])) @ p["wd"]


# --------------------------------------------------------------------------- #
# MoE MLP: top-k routing, sort-based capacity dispatch (dropping), EP-ready
# --------------------------------------------------------------------------- #


def init_moe_mlp(key, cfg: ModelConfig, dtype):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = split_keys(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), F32, scale=0.02),
        "wg": dense_init(ks[1], (E, d, ff), dtype),
        "wu": dense_init(ks[2], (E, d, ff), dtype),
        "wd": dense_init(ks[3], (E, ff, d), dtype),
    }


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(np.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # multiple of 8 lanes


def _current_mesh_info():
    return current_mesh()


def apply_moe_mlp(p, cfg: ModelConfig, x):
    """MoE layer dispatcher: shard_map EP when a mesh with a "model" axis is
    active (production path, explicit all-to-alls), local sort-based capacity
    dispatch otherwise (single-device smoke tests)."""
    mesh = _current_mesh_info()
    if (
        mesh is not None
        and "model" in mesh.axis_names
        and cfg.n_experts % mesh.shape["model"] == 0
        and mesh.shape["model"] > 1
    ):
        return _moe_shardmap(p, cfg, x, mesh)
    return _moe_local(p, cfg, x)


def _moe_local(p, cfg: ModelConfig, x):
    """x (B,S,d) → (y, load_balance_loss).  Sort-based capacity dispatch:

    tokens are argsorted by expert id and packed into an (E, C+1, d) buffer
    (slot C = overflow drop), experts run as one batched einsum (grouped
    GEMM), and results scatter back weighted by the top-k gates.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    N = B * S
    x2 = x.reshape(N, d)
    a = act_fn(cfg.act)

    logits = (x2.astype(F32) @ p["router"]).astype(F32)  # (N, E)
    gates_full = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates_full, k)  # (N, k)
    gates = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # load-balance loss (Switch/GShard style)
    counts = jnp.zeros((E,), F32).at[topi.reshape(-1)].add(1.0)
    frac_tokens = counts / (N * k)
    frac_prob = gates_full.mean(0)
    lb_loss = E * jnp.sum(frac_tokens * frac_prob)

    flat_e = topi.reshape(-1)  # (N*k,)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos_in_e = jnp.arange(N * k) - seg_start[sorted_e]
    C = moe_capacity(cfg, N)
    slot = jnp.minimum(pos_in_e, C)  # C = overflow slot
    token_of = order // k

    buf = jnp.zeros((E, C + 1, d), x.dtype)
    buf = buf.at[sorted_e, slot].set(x2[token_of])
    h = jnp.einsum("ecd,edf->ecf", buf, p["wg"], preferred_element_type=F32)
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"], preferred_element_type=F32)
    hexp = (a(h) * u).astype(x.dtype)
    out_buf = jnp.einsum("ecf,efd->ecd", hexp, p["wd"], preferred_element_type=F32)

    vals = out_buf[sorted_e, slot]  # (N*k, d)
    w = gates.reshape(-1)[order] * (pos_in_e < C)
    vals = vals * w[:, None]
    y = jax.ops.segment_sum(vals, token_of, num_segments=N)
    return y.reshape(B, S, d).astype(x.dtype), lb_loss


# ---- shard_map expert parallelism ----------------------------------------- #


def _pack_by_group(ids, n_groups: int, capacity: int):
    """Sort items by group id; returns (order, group, slot, keep).

    ``slot`` is each item's position within its group, clipped to
    ``capacity`` (the drop slot).
    """
    order = jnp.argsort(ids)
    sorted_g = ids[order]
    seg_start = jnp.searchsorted(sorted_g, jnp.arange(n_groups))
    pos = jnp.arange(ids.shape[0]) - seg_start[jnp.clip(sorted_g, 0, n_groups - 1)]
    keep = (pos < capacity) & (sorted_g < n_groups)
    slot = jnp.where(keep, pos, capacity)
    return order, sorted_g, slot, keep


def _expert_ffn(p_loc, cfg, buf):
    """buf (E_loc, C, d) → (E_loc, C, d) through the gated MLP."""
    a = act_fn(cfg.act)
    h = jnp.einsum("ecd,edf->ecf", buf, p_loc["wg"], preferred_element_type=F32)
    u = jnp.einsum("ecd,edf->ecf", buf, p_loc["wu"], preferred_element_type=F32)
    hexp = (a(h) * u).astype(buf.dtype)
    return jnp.einsum("ecf,efd->ecd", hexp, p_loc["wd"], preferred_element_type=F32)


def _moe_shardmap(p, cfg: ModelConfig, x, mesh):
    """Expert parallelism with explicit collectives (the production path).

    Experts are sharded over "model" (E_loc per rank); expert weights are
    additionally FSDP-sharded over "data" and all-gathered per layer (the
    gather's transpose is the grad reduce-scatter).  Two schedules:

    * seq divisible by the model axis (train/prefill): tokens are SP-sharded;
      assignments are packed per target rank and exchanged with
      ``all_to_all``, computed by the owning rank, and returned by the
      inverse ``all_to_all`` (MoE dispatch/combine exactly as deployed).
    * otherwise (decode, S == 1): tokens are replicated over "model"; each
      rank computes only its own experts' assignments and the partial sums
      are ``psum``-ed — no all_to_all on the hot decode path.
    """
    B, S, d = x.shape
    E, k, M = cfg.n_experts, cfg.top_k, mesh.shape["model"]
    E_loc = E // M
    axes = set(mesh.axis_names)
    dp = tuple(a for a in ("pod", "data") if a in axes)
    fsdp_ok = "data" in axes
    a2a_path = S % M == 0 and (B % max(np.prod([mesh.shape[a] for a in dp]), 1) == 0)
    P_ = jax.sharding.PartitionSpec

    def gather_w(w, axis):
        return jax.lax.all_gather(w, "data", axis=axis, tiled=True) if fsdp_ok else w

    def body(x_loc, router, wg, wu, wd):
        p_loc = {
            "wg": gather_w(wg, 1).astype(x_loc.dtype),
            "wu": gather_w(wu, 1).astype(x_loc.dtype),
            "wd": gather_w(wd, 2).astype(x_loc.dtype),
        }
        b, s, _ = x_loc.shape
        N = b * s
        x2 = x_loc.reshape(N, d)
        logits = x2.astype(F32) @ router
        gates_full = jax.nn.softmax(logits, axis=-1)
        topv, topi = jax.lax.top_k(gates_full, k)  # (N, k)
        gates = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

        counts = jnp.zeros((E,), F32).at[topi.reshape(-1)].add(1.0)
        lb = E * jnp.sum(counts / (N * k) * gates_full.mean(0))
        lb = jax.lax.pmean(lb, tuple(a for a in ("pod", "data", "model") if a in axes))

        flat_e = topi.reshape(-1)  # (N·k,) global expert ids
        flat_g = gates.reshape(-1)

        if a2a_path:
            # ---- pack per destination rank and exchange ------------------ #
            C_send = max(8, -(-int(np.ceil(N * k * cfg.capacity_factor / M)) // 8) * 8)
            rank_of = flat_e // E_loc
            order, _, slot, keep = _pack_by_group(rank_of, M, C_send)
            token_of = order // k
            send = jnp.zeros((M, C_send + 1, d), x_loc.dtype)
            send = send.at[rank_of[order], slot].set(x2[token_of] * keep[:, None])
            send_eid = jnp.full((M, C_send + 1), E_loc, jnp.int32)
            send_eid = send_eid.at[rank_of[order], slot].set(
                jnp.where(keep, flat_e[order] % E_loc, E_loc).astype(jnp.int32)
            )
            recv = jax.lax.all_to_all(
                send[:, :C_send], "model", split_axis=0, concat_axis=0, tiled=True
            )  # (M, C_send, d) — what every rank sent to me
            recv_eid = jax.lax.all_to_all(
                send_eid[:, :C_send], "model", split_axis=0, concat_axis=0, tiled=True
            )
            # ---- local grouped GEMM over my experts ---------------------- #
            R = M * C_send
            r2 = recv.reshape(R, d)
            eid = recv_eid.reshape(R)
            C_e = max(8, -(-int(np.ceil(R * 1.0 / E_loc)) // 8) * 8)
            order2, _, slot2, keep2 = _pack_by_group(eid, E_loc, C_e)
            buf = jnp.zeros((E_loc, C_e + 1, d), x_loc.dtype)
            buf = buf.at[eid[order2].clip(0, E_loc - 1) * keep2, slot2].set(
                r2[order2] * keep2[:, None]
            )
            out_buf = _expert_ffn(p_loc, cfg, buf[:, :C_e]).astype(x_loc.dtype)
            out_r = jnp.zeros((R, d), x_loc.dtype)
            out_r = out_r.at[order2].set(
                out_buf[
                    eid[order2].clip(0, E_loc - 1) * keep2, jnp.minimum(slot2, C_e - 1)
                ]
                * keep2[:, None]
            )
            back = jax.lax.all_to_all(
                out_r.reshape(M, C_send, d), "model", split_axis=0, concat_axis=0,
                tiled=True,
            )
            # ---- combine ------------------------------------------------- #
            vals = jnp.zeros((N * k, d), x_loc.dtype)
            vals = vals.at[order].set(
                back[rank_of[order], jnp.minimum(slot, C_send - 1)] * keep[:, None]
            )
            y = jax.ops.segment_sum(
                vals * flat_g[:, None].astype(x_loc.dtype), jnp.arange(N * k) // k, N
            )
        else:
            # ---- replicated tokens; my experts only; psum over model ----- #
            my_rank = jax.lax.axis_index("model")
            local = (flat_e // E_loc) == my_rank
            eid = jnp.where(local, flat_e % E_loc, E_loc).astype(jnp.int32)
            C_e = max(
                8,
                -(-int(np.ceil(N * k * cfg.capacity_factor / max(E, 1) * E_loc)) // 8)
                * 8,
            )
            order2, _, slot2, keep2 = _pack_by_group(eid, E_loc, C_e)
            token_of2 = order2 // k
            buf = jnp.zeros((E_loc, C_e + 1, d), x_loc.dtype)
            buf = buf.at[eid[order2].clip(0, E_loc - 1) * keep2, slot2].set(
                x2[token_of2] * keep2[:, None]
            )
            out_buf = _expert_ffn(p_loc, cfg, buf[:, :C_e]).astype(x_loc.dtype)
            vals = out_buf[
                eid[order2].clip(0, E_loc - 1), jnp.minimum(slot2, C_e - 1)
            ] * keep2[:, None]
            y = jax.ops.segment_sum(
                vals * flat_g[order2][:, None].astype(x_loc.dtype), token_of2, N
            )
            y = jax.lax.psum(y, "model")
        return y.reshape(b, s, d), lb

    seq_spec = "model" if a2a_path else None
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P_(dp if dp else None, seq_spec, None),  # x
            P_(None, None),  # router
            P_("model", "data" if fsdp_ok else None, None),  # wg
            P_("model", "data" if fsdp_ok else None, None),  # wu
            P_("model", None, "data" if fsdp_ok else None),  # wd
        ),
        out_specs=(P_(dp if dp else None, seq_spec, None), P_()),
        check_vma=False,
    )(x, p["router"], p["wg"], p["wu"], p["wd"])
