"""Mamba-2 (SSD, state-space duality) block — arXiv:2405.21060.

Chunked SSD forward: within chunks the recurrence is computed as a masked
quadratic form (MXU-friendly), across chunks a ``lax.scan`` carries the
(H, P, N) state.  Decode is the O(1) recurrent step — this is why
``long_500k`` runs for this family (no KV cache; the context lives in the
state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import F32, dense_init, rms_norm, split_keys


def init_ssm_layer(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    din, H, N, cw = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.conv_width
    ks = split_keys(key, 4)
    d_in_proj = 2 * din + 2 * N + H  # z, x, B, C, dt  (ngroups = 1)
    conv_ch = din + 2 * N  # conv over (x, B, C)
    return {
        "ln1": jnp.ones((d,), dtype),
        "in_proj": dense_init(ks[0], (d, d_in_proj), dtype),
        "conv_w": dense_init(ks[1], (cw, conv_ch), dtype, scale=0.5),
        "A_log": jnp.zeros((H,), F32),  # A = -exp(A_log) = -1
        "D": jnp.ones((H,), F32),
        "dt_bias": jnp.zeros((H,), F32),
        "gnorm": jnp.ones((din,), dtype),
        "out_proj": dense_init(ks[2], (din, d), dtype),
    }


def _causal_depthwise_conv(x, w, state=None):
    """x (B, S, C), w (cw, C) — causal depthwise conv.

    If ``state`` (B, cw-1, C) is given, runs one decode step (S == 1) and
    returns (y, new_state).
    """
    cw = w.shape[0]
    if state is not None:
        window = jnp.concatenate([state, x], axis=1)  # (B, cw, C)
        y = jnp.einsum("bwc,wc->bc", window.astype(F32), w.astype(F32))
        return y[:, None, :].astype(x.dtype), window[:, 1:]
    B, S, C = x.shape
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    windows = jnp.stack([xp[:, i : i + S] for i in range(cw)], axis=-1)  # (B,S,C,cw)
    return jnp.einsum("bscw,wc->bsc", windows.astype(F32), w.astype(F32)).astype(
        x.dtype
    ), None


def _segsum(dA):
    """dA (..., Q) → L (..., Q, Q): L[i, j] = Σ_{j < t ≤ i} dA_t (−inf above diag)."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # (..., i, j): sum over (j, i]
    i = jnp.arange(Q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_forward(xs, dt, A, Bmat, Cmat, chunk):
    """Chunked SSD.

    xs (B,S,H,P); dt (B,S,H) post-softplus; A (H,) negative; Bmat/Cmat
    (B,S,N) (single group, broadcast over heads).  Returns y (B,S,H,P) and
    the final state (B,H,P,N).
    """
    Bb, S0, H, P = xs.shape
    N = Bmat.shape[-1]
    Q = min(chunk, S0)
    S = -(-S0 // Q) * Q
    if S != S0:
        # dt = 0 on padding → decay 1, no state contribution; outputs sliced
        pad = ((0, 0), (0, S - S0))
        xs = jnp.pad(xs, pad + ((0, 0), (0, 0)))
        dt = jnp.pad(dt, pad + ((0, 0),))
        Bmat = jnp.pad(Bmat, pad + ((0, 0),))
        Cmat = jnp.pad(Cmat, pad + ((0, 0),))
    nc = S // Q
    xs = xs.reshape(Bb, nc, Q, H, P)
    dt = dt.reshape(Bb, nc, Q, H)
    Bm = Bmat.reshape(Bb, nc, Q, N)
    Cm = Cmat.reshape(Bb, nc, Q, N)

    dA = dt * A  # (B,nc,Q,H)
    dA = jnp.moveaxis(dA, -1, 2)  # (B,nc,H,Q)
    L = jnp.exp(_segsum(dA))  # (B,nc,H,Q,Q)

    # intra-chunk (quadratic, MXU):  Y_intra = (L ∘ C Bᵀ) (dt·X)
    CB = jnp.einsum("bnqs,bnks->bnqk", Cm, Bm, preferred_element_type=F32)  # (B,nc,Q,Q)
    dtx = xs * dt[..., None]  # (B,nc,Q,H,P)
    y_intra = jnp.einsum(
        "bnqk,bnhqk,bnkhp->bnqhp", CB, L, dtx, preferred_element_type=F32
    )

    # per-chunk outgoing state:  S_c = Σ_j exp(cumΔ_last − cumΔ_j) dt_j B_j x_jᵀ
    cum = jnp.cumsum(dA, axis=-1)  # (B,nc,H,Q)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)  # (B,nc,H,Q)
    S_local = jnp.einsum(
        "bnhq,bnqm,bnqhp->bnhpm",
        decay_to_end,
        Bm,
        dtx,
        preferred_element_type=F32,
    )  # (B,nc,H,P,N)
    chunk_decay = jnp.exp(cum[..., -1])  # (B,nc,H)

    # inter-chunk: scan carrying the running state
    def step(carry, inp):
        s_prev = carry  # (B,H,P,N)
        s_loc, cdecay, c_in, dA_c = inp
        # contribution of the incoming state to this chunk's outputs
        decay_in = jnp.exp(jnp.cumsum(dA_c, axis=-1))  # (B,H,Q)
        y_in = jnp.einsum(
            "bqn,bhpn,bhq->bqhp", c_in, s_prev, decay_in, preferred_element_type=F32
        )
        s_new = s_prev * cdecay[..., None, None] + s_loc
        return s_new, y_in

    init = jnp.zeros((Bb, H, P, N), F32)
    xs_scan = (
        jnp.moveaxis(S_local, 1, 0),
        jnp.moveaxis(chunk_decay, 1, 0),
        jnp.moveaxis(Cm, 1, 0),
        jnp.moveaxis(dA, 1, 0),
    )
    s_final, y_inter = jax.lax.scan(step, init, xs_scan)
    y_inter = jnp.moveaxis(y_inter, 0, 1)  # (B,nc,Q,H,P) after moveaxis
    y = (y_intra + y_inter).reshape(Bb, S, H, P)
    return y[:, :S0], s_final


def apply_ssm_layer(p, cfg: ModelConfig, x, *, state=None, conv_state=None):
    """Train/prefill when ``state is None``; otherwise one decode step.

    Returns (y, (ssd_state, conv_state)).
    """
    B, S, d = x.shape
    din, H, N, Pd = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"]  # (B,S, 2*din + 2N + H)
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * N], axis=-1)
    decode = state is not None
    cw = cfg.conv_width
    if not decode:
        # conv tail (pre-activation) so decode can continue after prefill
        tail = jnp.pad(xbc, ((0, 0), (max(cw - 1 - S, 0), 0), (0, 0)))[:, -(cw - 1) :]
        xbc, _ = _causal_depthwise_conv(xbc, p["conv_w"], None)
        new_conv = tail
    else:
        xbc, new_conv = _causal_depthwise_conv(xbc, p["conv_w"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [din, din + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,)
    xs = xs.reshape(B, S, H, Pd)

    if not decode:
        y, s_final = ssd_forward(
            xs.astype(F32), dt, A, Bm.astype(F32), Cm.astype(F32), cfg.ssm_chunk
        )
    else:
        # recurrent step: h' = h·exp(dt A) + dt·B xᵀ ; y = C·h' + D x
        dA = jnp.exp(dt[:, 0] * A)  # (B,H)
        dbx = jnp.einsum(
            "bn,bhp,bh->bhpn", Bm[:, 0].astype(F32), xs[:, 0].astype(F32), dt[:, 0]
        )
        s_final = state * dA[..., None, None] + dbx
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(F32), s_final)[:, None]
    y = y + xs.astype(F32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, din).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["gnorm"], cfg.norm_eps)
    return x + y @ p["out_proj"], (s_final, new_conv)


def ssd_reference(xs, dt, A, Bmat, Cmat):
    """O(S·N·P) sequential oracle for tests: plain recurrence."""
    Bb, S, H, P = xs.shape
    N = Bmat.shape[-1]
    s = jnp.zeros((Bb, H, P, N), F32)
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A)  # (B,H)
        s = s * dA[..., None, None] + jnp.einsum(
            "bn,bhp,bh->bhpn", Bmat[:, t], xs[:, t], dt[:, t]
        )
        ys.append(jnp.einsum("bn,bhpn->bhp", Cmat[:, t], s))
    return jnp.stack(ys, axis=1), s
