"""Shared neural-net layers (pure JAX, TPU-shaped).

Attention is implemented as a chunked, numerically-stable streaming softmax
(flash-attention schedule) in pure JAX: the dry-run must lower on the CPU
backend where ``pallas_call`` is unavailable outside interpret mode, so the
kernel-level tiling is expressed with ``lax.scan`` over (q-chunk × kv-chunk)
tiles — the same VMEM-sized working set a Pallas flash kernel would use
(DESIGN.md §8).  Two causal schedules are provided:

* ``masked`` — every q-chunk visits every kv-chunk with a mask (baseline;
  2× FLOP waste on causal).
* ``banded`` — q-chunk ``i`` visits kv-chunks ``0..i`` only, via a
  lower-triangular gather of tile coordinates (the §Perf compute-term fix).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.compat import shard_map

F32 = jnp.float32

# --------------------------------------------------------------------------- #
# Norms / activations
# --------------------------------------------------------------------------- #


def rms_norm(x, w, eps=1e-5):
    h = x.astype(F32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * w.astype(F32)).astype(x.dtype)


def layer_norm(x, w, b, eps=1e-5):
    h = x.astype(F32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean((h - mu) ** 2, axis=-1, keepdims=True)
    return ((h - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(x.dtype)


def act_fn(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# --------------------------------------------------------------------------- #
# RoPE / M-RoPE
# --------------------------------------------------------------------------- #


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))  # (hd/2,)


def rope_angles(positions, head_dim, theta, mrope_sections=()):
    """Angles (…, S, hd/2) from positions.

    ``positions``: (B, S) int32 for standard RoPE, or (B, 3, S) for M-RoPE
    (temporal / height / width streams — Qwen2-VL §3).  With M-RoPE the
    hd/2 frequency slots are split into ``mrope_sections`` groups, each
    driven by its own position stream.
    """
    freqs = jnp.asarray(rope_freqs(head_dim, theta), dtype=F32)
    if not mrope_sections:
        return positions[..., None].astype(F32) * freqs  # (B, S, hd/2)
    sections = np.asarray(mrope_sections)
    assert sections.sum() == head_dim // 2
    stream_of_freq = np.repeat(np.arange(len(sections)), sections)  # (hd/2,)
    # positions (B, 3, S) → per-freq stream positions (B, S, hd/2)
    pos = positions.astype(F32)[:, stream_of_freq, :]  # (B, hd/2, S)
    pos = jnp.swapaxes(pos, 1, 2)  # (B, S, hd/2)
    return pos * freqs


def apply_rope(x, angles):
    """x: (B, S, H, hd); angles: (B, S, hd/2). Rotate-half convention."""
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Chunked flash-style attention (training / prefill)
# --------------------------------------------------------------------------- #


def _attend_tile(q, k, v, mask, scale):
    """One (qc × kc) tile. q:(B,qc,Hkv,G,D) k:(B,kc,Hkv,D) v:(B,kc,Hkv,D).

    Returns (scores_max, exp_sum, weighted_v) in f32 for streaming combine.
    """
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=F32)
    logits = logits * scale
    logits = jnp.where(mask, logits, -1e30)
    m = jnp.max(logits, axis=-1)  # (B,H,G,q)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)  # (B,H,G,q)
    pv = jnp.einsum(
        "bhgqk,bkhd->bhgqd", p.astype(v.dtype), v, preferred_element_type=F32
    )
    return m, l, pv


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    window: int = 0,
    schedule: str = "banded",
    q_offset: int = 0,
):
    """Streaming-softmax attention.  q:(B,Sq,Hq,D), k/v:(B,Skv,Hkv,D).

    GQA via reshape of q-heads into (Hkv, G).  ``window`` > 0 restricts to a
    local causal band (recurrentgemma).  ``q_offset`` is the absolute position
    of q[0] (prefill continuation).  Output (B,Sq,Hq,D) in q.dtype.
    """
    B, Sq0, Hq, D = q.shape
    _, Skv0, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    q_chunk = min(q_chunk, Sq0)
    kv_chunk = min(kv_chunk, Skv0)
    # pad to chunk multiples; padded keys are masked out, padded q rows sliced
    Sq = -(-Sq0 // q_chunk) * q_chunk
    Skv = -(-Skv0 // kv_chunk) * kv_chunk
    if Sq != Sq0:
        q = jnp.pad(q, ((0, 0), (0, Sq - Sq0), (0, 0), (0, 0)))
    if Skv != Skv0:
        k = jnp.pad(k, ((0, 0), (0, Skv - Skv0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skv - Skv0), (0, 0), (0, 0)))
    nq, nk = Sq // q_chunk, Skv // kv_chunk

    qr = q.reshape(B, nq, q_chunk, Hkv, G, D)
    kr = k.reshape(B, nk, kv_chunk, Hkv, D)
    vr = v.reshape(B, nk, kv_chunk, Hkv, D)

    q_pos = q_offset + jnp.arange(Sq).reshape(nq, q_chunk)
    k_pos = jnp.arange(Skv).reshape(nk, kv_chunk)

    def tile_mask(qi, ki):
        qp = q_pos[qi][:, None]  # (qc, 1)
        kp = k_pos[ki][None, :]  # (1, kc)
        m = kp < Skv0  # mask kv padding
        if causal:
            m &= kp <= qp
        if window:
            m &= kp > qp - window
        return m  # (qc, kc)

    def combine(carry, tile):
        m_prev, l_prev, acc = carry
        m_t, l_t, pv_t = tile
        m_new = jnp.maximum(m_prev, m_t)
        a = jnp.exp(m_prev - m_new)
        b = jnp.exp(m_t - m_new)
        l_new = l_prev * a + l_t * b
        acc = acc * a[..., None] + pv_t * b[..., None]
        return m_new, l_new, acc

    @jax.checkpoint  # flash-style backward: recompute tiles, save only q/k/v
    def one_q_chunk(qi):
        qc = jax.lax.dynamic_index_in_dim(qr, qi, 1, keepdims=False)  # (B,qc,Hkv,G,D)

        if schedule == "banded" and causal:
            # kv chunks strictly above the diagonal are fully masked; visit
            # only 0..diag (and, with a window, only the band).  The loop
            # length is static (= nk); skipped tiles cost a predicated copy.
            def kv_step(carry, ki):
                def visit(carry):
                    kc = jax.lax.dynamic_index_in_dim(kr, ki, 1, keepdims=False)
                    vc = jax.lax.dynamic_index_in_dim(vr, ki, 1, keepdims=False)
                    tile = _attend_tile(
                        qc, kc, vc, tile_mask(qi, ki)[None, None, None], scale
                    )
                    return combine(carry, tile)

                # live iff this tile intersects the causal band
                first_k = k_pos[ki][0]
                last_k = k_pos[ki][-1]
                lo = q_pos[qi][0] - (window - 1) if window else -1
                live = (last_k >= lo) & (first_k <= q_pos[qi][-1])
                return jax.lax.cond(live, visit, lambda c: c, carry), None

            init = (
                jnp.full((B, Hkv, G, q_chunk), -jnp.inf, F32),
                jnp.zeros((B, Hkv, G, q_chunk), F32),
                jnp.zeros((B, Hkv, G, q_chunk, D), F32),
            )
            (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        else:

            def kv_step(carry, ki):
                kc = jax.lax.dynamic_index_in_dim(kr, ki, 1, keepdims=False)
                vc = jax.lax.dynamic_index_in_dim(vr, ki, 1, keepdims=False)
                tile = _attend_tile(
                    qc, kc, vc, tile_mask(qi, ki)[None, None, None], scale
                )
                return combine(carry, tile), None

            init = (
                jnp.full((B, Hkv, G, q_chunk), -jnp.inf, F32),
                jnp.zeros((B, Hkv, G, q_chunk), F32),
                jnp.zeros((B, Hkv, G, q_chunk, D), F32),
            )
            (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))

        out = acc / jnp.maximum(l[..., None], 1e-30)  # (B,H,G,q,D)
        return jnp.transpose(out, (0, 3, 1, 2, 4)).astype(q.dtype)  # (B,q,Hkv,G,D)

    out = jax.lax.map(one_q_chunk, jnp.arange(nq))  # (nq,B,qc,Hkv,G,D)
    out = jnp.transpose(out, (1, 0, 2, 3, 4, 5)).reshape(B, Sq, Hq, D)
    return out[:, :Sq0]


# --------------------------------------------------------------------------- #
# Decode attention (single query step against a cache)
# --------------------------------------------------------------------------- #


def seq_parallel_decode_attention(
    q, k_cache, v_cache, k_new, v_new, cur_len, mesh, scales=None
):
    """Sequence-parallel decode attention + cache update (shard_map).

    The §Perf fix for collective-bound decode: with the KV cache sharded over
    "model" on the *sequence* dim, the naive pjit lowering all-gathers the
    cache both for the dynamic cache update and for the softmax.  Here every
    shard (a) writes the new K/V locally iff ``cur_len`` lands in its range,
    and (b) computes flash-style partial (max, sum, weighted-V) over its seq
    slice; the cross-shard combine is two psums of (B,H)-sized tensors —
    KBs instead of the cache's GBs.

    q (B,1,Hq,D); caches (B,S,Hkv,D) sharded P(dp, "model", None, None);
    k_new/v_new (B,1,Hkv,D) replicated over "model"; cur_len (B,).
    With ``scales=(k_scale, v_scale)`` the caches are int8 and dequantised
    per shard (§Perf: halves the compulsory cache read traffic).
    Returns (out (B,1,Hq,D), new_cache_tuple).
    """
    B, S, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    axes = set(mesh.axis_names)
    dp = tuple(a for a in ("pod", "data") if a in axes)
    Ps = jax.sharding.PartitionSpec
    att_scale = 1.0 / np.sqrt(D)
    quant = scales is not None

    def _q_i8(x):
        s = jnp.maximum(jnp.max(jnp.abs(x.astype(F32)), axis=-1), 1e-8) / 127.0
        return (
            jnp.clip(jnp.round(x.astype(F32) / s[..., None]), -127, 127).astype(
                jnp.int8
            ),
            s,
        )

    def body(q, kc, vc, kn, vn, cur, *sc):
        b_loc, s_loc = kc.shape[0], kc.shape[1]  # local shapes
        rank = jax.lax.axis_index("model")
        lo = rank * s_loc
        # (a) local cache write: slot = cur - lo when 0 ≤ slot < s_loc
        slot = cur - lo  # (b_loc,)
        bidx = jnp.arange(b_loc)
        in_range = (slot >= 0) & (slot < s_loc)
        safe = jnp.clip(slot, 0, s_loc - 1)
        if quant:
            ksc, vsc = sc
            knq, kns = _q_i8(kn[:, 0])
            vnq, vns = _q_i8(vn[:, 0])
            kc = kc.at[bidx, safe].set(
                jnp.where(in_range[:, None, None], knq, kc[bidx, safe])
            )
            vc = vc.at[bidx, safe].set(
                jnp.where(in_range[:, None, None], vnq, vc[bidx, safe])
            )
            ksc = ksc.at[bidx, safe].set(
                jnp.where(in_range[:, None], kns, ksc[bidx, safe])
            )
            vsc = vsc.at[bidx, safe].set(
                jnp.where(in_range[:, None], vns, vsc[bidx, safe])
            )
            k_use = kc.astype(F32) * ksc[..., None]
            v_use = vc.astype(F32) * vsc[..., None]
        else:
            kc = kc.at[bidx, safe].set(
                jnp.where(in_range[:, None, None], kn[:, 0], kc[bidx, safe])
            )
            vc = vc.at[bidx, safe].set(
                jnp.where(in_range[:, None, None], vn[:, 0], vc[bidx, safe])
            )
            k_use, v_use = kc, vc
        # (b) partial flash over my seq slice
        qr = q.reshape(b_loc, Hkv, G, D)
        logits = jnp.einsum("bhgd,bshd->bhgs", qr, k_use, preferred_element_type=F32)
        logits = logits * att_scale
        pos = lo + jnp.arange(s_loc)
        mask = pos[None, :] <= cur[:, None]  # keys 0..cur (incl. new token)
        logits = jnp.where(mask[:, None, None, :], logits, -1e30)
        m_loc = jnp.max(logits, axis=-1)  # (b,Hkv,G)
        m_glob = jax.lax.pmax(m_loc, "model")
        p = jnp.exp(logits - m_glob[..., None])
        l_loc = jnp.sum(p, axis=-1)
        pv_loc = jnp.einsum(
            "bhgs,bshd->bhgd", p.astype(q.dtype), v_use.astype(q.dtype),
            preferred_element_type=F32,
        )
        l = jax.lax.psum(l_loc, "model")  # (b,Hkv,G)   — KBs
        pv = jax.lax.psum(pv_loc, "model")  # (b,Hkv,G,D) — KBs
        out = (pv / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
        if quant:
            return out.reshape(b_loc, 1, Hq, D), kc, vc, ksc, vsc
        return out.reshape(b_loc, 1, Hq, D), kc, vc

    cache_spec = Ps(dp, "model", None, None)
    sc_spec = Ps(dp, "model", None)
    in_specs = [
        Ps(dp, None, None, None),  # q
        cache_spec,
        cache_spec,
        Ps(dp, None, None, None),  # k_new
        Ps(dp, None, None, None),  # v_new
        Ps(dp),  # cur_len
    ]
    out_specs = [Ps(dp, None, None, None), cache_spec, cache_spec]
    args = [q, k_cache, v_cache, k_new, v_new, cur_len]
    if quant:
        in_specs += [sc_spec, sc_spec]
        out_specs += [sc_spec, sc_spec]
        args += list(scales)
    res = shard_map(
        body,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=tuple(out_specs),
        check_vma=False,
    )(*args)
    return res[0], tuple(res[1:])


def decode_attention(q, k_cache, v_cache, cur_len, *, window: int = 0):
    """q:(B,1,Hq,D); caches:(B,S,Hkv,D); attends keys < cur_len.

    Plain einsum with f32 softmax — the (B,H,S) logits tensor is the sharded
    object the decode roofline tracks (KV cache sharded over seq → partial
    softmax all-reduce, DESIGN.md §7).
    """
    B, S, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    qr = q.reshape(B, Hkv, G, D)
    logits = jnp.einsum("bhgd,bshd->bhgs", qr, k_cache, preferred_element_type=F32)
    logits = logits / np.sqrt(D)
    pos = jnp.arange(S)
    mask = pos[None, :] < cur_len  # (B, S) — cur_len (B,1) or scalar
    if window:
        mask &= pos[None, :] >= cur_len - window
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=F32)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# --------------------------------------------------------------------------- #
# Parameter init helpers
# --------------------------------------------------------------------------- #


def dense_init(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, F32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))
