"""RecurrentGemma / Griffin recurrent block (RG-LRU) — arXiv:2402.19427.

Block: two d→W projections; branch 1 gates (GeLU), branch 2 goes through a
width-4 causal depthwise conv then the RG-LRU linear recurrence:

    r_t = σ(W_r x_t + b_r)          (recurrence gate)
    i_t = σ(W_i x_t + b_i)          (input gate)
    a_t = exp(c · r_t · log σ(Λ))   (c = 8)
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

The sequence form uses ``jax.lax.associative_scan`` on the affine maps
(h → a·h + b compose associatively), giving O(log S) depth — the TPU-native
realisation of a linear recurrence.  Decode is the O(1) step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import F32, dense_init, rms_norm, split_keys
from repro.models.mamba2 import _causal_depthwise_conv

_C = 8.0


def init_rglru_layer(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    W = cfg.lru_width or d
    ks = split_keys(key, 8)
    return {
        "ln1": jnp.ones((d,), dtype),
        "w_gate": dense_init(ks[0], (d, W), dtype),  # GeLU branch
        "w_x": dense_init(ks[1], (d, W), dtype),  # recurrent branch
        "conv_w": dense_init(ks[2], (cfg.conv_width, W), dtype, scale=0.5),
        "w_r": dense_init(ks[3], (W, W), dtype),
        "b_r": jnp.zeros((W,), F32),
        "w_i": dense_init(ks[4], (W, W), dtype),
        "b_i": jnp.zeros((W,), F32),
        "lam": jnp.full((W,), 2.0, F32),  # Λ: σ(2) ≈ 0.88 decay
        "w_out_proj": dense_init(ks[5], (W, d), dtype),
        "ln2": jnp.ones((d,), dtype),
        "mlp": {
            "wg": dense_init(ks[6], (d, cfg.d_ff), dtype),
            "wu": dense_init(ks[7], (d, cfg.d_ff), dtype),
            "wd": dense_init(split_keys(ks[5], 2)[1], (cfg.d_ff, d), dtype),
        },
    }


def _rglru_scan(x, a_log):
    """h_t = a_t h_{t−1} + b_t via associative scan.  x=(a, b): (B,S,W) f32."""

    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a = jnp.exp(a_log)
    b = x
    aa, bb = jax.lax.associative_scan(op, (a, b), axis=1)
    return bb  # h_t (initial state 0)


def apply_rglru_layer(p, cfg: ModelConfig, x, *, state=None, conv_state=None):
    """Train/prefill when ``state is None``; otherwise one decode step.

    state: (h (B,W) f32).  Returns (y, (h, conv_state)).
    """
    B, S, d = x.shape
    h0 = rms_norm(x, p["ln1"], cfg.norm_eps)
    gate = jax.nn.gelu(h0 @ p["w_gate"])  # (B,S,W)
    xr = h0 @ p["w_x"]
    cw = cfg.conv_width
    if state is None:
        tail = jnp.pad(xr, ((0, 0), (max(cw - 1 - S, 0), 0), (0, 0)))[:, -(cw - 1) :]
        xr, _ = _causal_depthwise_conv(xr, p["conv_w"], None)
        new_conv = tail
    else:
        xr, new_conv = _causal_depthwise_conv(xr, p["conv_w"], conv_state)

    xf = xr.astype(F32)
    r = jax.nn.sigmoid(xf @ p["w_r"].astype(F32) + p["b_r"])
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(F32) + p["b_i"])
    log_a = _C * r * jax.nn.log_sigmoid(p["lam"])  # (B,S,W), ≤ 0
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    gated_in = beta * (i * xf)

    if state is None:
        h = _rglru_scan(gated_in, log_a)  # (B,S,W)
        new_state = h[:, -1]
    else:
        h = jnp.exp(log_a[:, 0]) * state + gated_in[:, 0]
        new_state = h
        h = h[:, None]
    y = (h.astype(x.dtype) * gate) @ p["w_out_proj"]
    x = x + y
    hm = rms_norm(x, p["ln2"], cfg.norm_eps)
    m = p["mlp"]
    y2 = (jax.nn.gelu(hm @ m["wg"]) * (hm @ m["wu"])) @ m["wd"]
    return x + y2, (new_state, new_conv)
