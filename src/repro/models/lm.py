"""Unified language model: init / train / prefill / decode for every family.

One assembly covers the whole zoo via per-layer *kinds* ("attn", "ssm",
"rglru") taken from ``cfg.layer_kinds``:

* homogeneous stacks (dense / moe / vlm / ssm) scan over a (L, …) stacked
  param tree (fast compiles at 88 layers);
* heterogeneous stacks (recurrentgemma's rglru/rglru/attn pattern) scan over
  *superlayers* (one pattern period) with any remainder unrolled;
* whisper (enc-dec) unrolls its 6+6 layers and adds cross-attention.

Decode paths are unrolled (small graphs) and operate on explicit cache
pytrees so the serve step is a pure function ``(params, cache, tokens) →
(logits, cache)`` — the object the decode dry-run cells lower.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import logical
from repro.models.blocks import (
    apply_attn_layer,
    apply_attn_layer_decode,
    apply_dense_mlp,
    init_attn_layer,
)
from repro.models.config import ModelConfig
from repro.models.layers import (
    F32,
    dense_init,
    flash_attention,
    decode_attention,
    rms_norm,
    rope_angles,
    split_keys,
)
from repro.models.mamba2 import apply_ssm_layer, init_ssm_layer
from repro.models.rglru import apply_rglru_layer, init_rglru_layer

# --------------------------------------------------------------------------- #
# Parameter construction
# --------------------------------------------------------------------------- #

_KIND_INIT = {
    "attn": init_attn_layer,
    "ssm": init_ssm_layer,
    "rglru": init_rglru_layer,
}


def _stack_init(key, init_fn, n, cfg, dtype):
    keys = jnp.stack(split_keys(key, n))
    return jax.vmap(lambda k: init_fn(k, cfg, dtype))(keys)


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    ks = split_keys(key, 8)
    p = {"final_norm": jnp.ones((cfg.d_model,), dtype)}
    if cfg.family != "vlm":
        p["embed"] = dense_init(ks[0], (cfg.vocab, cfg.d_model), dtype, scale=0.02)
    if not cfg.tie_embeddings:
        p["w_out"] = dense_init(ks[1], (cfg.d_model, cfg.vocab), dtype)

    kinds = cfg.layer_kinds
    pattern = cfg.pattern if cfg.pattern else (kinds[0],)
    plen = len(pattern)
    n_super, n_rem = divmod(cfg.n_layers, plen)

    if cfg.scan_layers and n_super > 0:
        super_p = {}
        for i, kind in enumerate(pattern):
            super_p[f"b{i}_{kind}"] = _stack_init(
                ks[2 + i % 4], _KIND_INIT[kind], n_super, cfg, dtype
            )
        p["layers"] = super_p
    else:
        p["layers_unrolled"] = [
            _KIND_INIT[k](kk, cfg, dtype)
            for k, kk in zip(
                kinds[: n_super * plen], split_keys(ks[2], max(n_super * plen, 1))
            )
        ]
    if n_rem:
        p["rem_layers"] = [
            _KIND_INIT[k](kk, cfg, dtype)
            for k, kk in zip(kinds[n_super * plen :], split_keys(ks[6], n_rem))
        ]
    if cfg.encoder_layers:
        p["encoder"] = _stack_init(
            ks[7], init_attn_layer, cfg.encoder_layers, cfg, dtype
        )
        p["xattn"] = _stack_init(ks[3], _init_xattn_layer, cfg.n_layers, cfg, dtype)
    return p


def _init_xattn_layer(key, cfg: ModelConfig, dtype):
    d, hd, Hq, Hkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv
    ksx = split_keys(key, 4)
    return {
        "lnx": jnp.ones((d,), dtype),
        "xq": dense_init(ksx[0], (d, Hq * hd), dtype),
        "xk": dense_init(ksx[1], (d, Hkv * hd), dtype),
        "xv": dense_init(ksx[2], (d, Hkv * hd), dtype),
        "xo": dense_init(ksx[3], (Hq * hd, d), dtype),
    }


# --------------------------------------------------------------------------- #
# Forward (train / prefill shared body)
# --------------------------------------------------------------------------- #


def _angles_for(cfg: ModelConfig, positions):
    if cfg.family == "ssm":
        return None
    return rope_angles(positions, cfg.hd, cfg.rope_theta, cfg.mrope_sections)


def _apply_kind(kind, lp, cfg, x, angles, collect_cache):
    window = cfg.window if kind == "attn" and cfg.pattern else 0
    if kind == "attn":
        y, kv, aux = apply_attn_layer(lp, cfg, x, angles, window=window)
        cache = kv if collect_cache else None
        return y, cache, aux
    if kind == "ssm":
        y, (s, conv_tail) = apply_ssm_layer(lp, cfg, x)
        return y, ((s, conv_tail) if collect_cache else None), jnp.zeros((), F32)
    if kind == "rglru":
        y, (h, conv_tail) = apply_rglru_layer(lp, cfg, x)
        return y, ((h, conv_tail) if collect_cache else None), jnp.zeros((), F32)
    raise ValueError(kind)


def _backbone(params, cfg: ModelConfig, x, angles, collect_cache=False):
    """Run the layer stack.  Returns (x, caches, aux_loss)."""
    kinds = cfg.layer_kinds
    pattern = cfg.pattern if cfg.pattern else (kinds[0],)
    plen = len(pattern)
    n_super, n_rem = divmod(cfg.n_layers, plen)
    caches, aux_total = [], jnp.zeros((), F32)

    if "layers" in params and n_super > 0:

        def super_fn(x, lp):
            auxs = jnp.zeros((), F32)
            ys = []
            for i, kind in enumerate(pattern):
                x, cache, aux = _apply_kind(
                    kind, lp[f"b{i}_{kind}"], cfg, x, angles, collect_cache
                )
                auxs += aux
                ys.append(cache)
            x = logical(x, ("batch", "seq", "embed"))
            return x, (tuple(ys), auxs)

        if cfg.remat:
            super_fn = jax.checkpoint(super_fn)
        x, (stacked_caches, auxs) = jax.lax.scan(super_fn, x, params["layers"])
        aux_total += auxs.sum()
        if collect_cache:
            # unstack (n_super, …) scan caches into the flat per-layer list
            for s in range(n_super):
                for i in range(plen):
                    caches.append(
                        jax.tree.map(lambda a: a[s], stacked_caches[i])
                    )
    else:
        for lp, kind in zip(params.get("layers_unrolled", []), kinds):
            x, cache, aux = _apply_kind(kind, lp, cfg, x, angles, collect_cache)
            aux_total += aux
            if collect_cache:
                caches.append(cache)

    for lp, kind in zip(params.get("rem_layers", []), kinds[n_super * plen :]):
        x, cache, aux = _apply_kind(kind, lp, cfg, x, angles, collect_cache)
        aux_total += aux
        if collect_cache:
            caches.append(cache)
    return x, caches, aux_total


def _encode(params, cfg: ModelConfig, frames):
    """Whisper encoder over precomputed (stub) frame embeddings."""
    x = frames + _sinusoid(frames.shape[1], cfg.d_model, frames.dtype)

    def enc_fn(x, lp):
        y, _, _ = apply_attn_layer(lp, cfg, x, None, causal=False)
        return y, None

    if cfg.remat:
        enc_fn = jax.checkpoint(enc_fn)
    x, _ = jax.lax.scan(enc_fn, x, params["encoder"])
    return x


def _sinusoid(S, d, dtype):
    pos = np.arange(S)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * dim / d))
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, dtype)[None]


def _apply_xattn(lp, cfg, x, enc_kv):
    """Whisper cross-attention sublayer (full, non-causal, cached enc K/V)."""
    B, S, d = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    ek, ev = enc_kv
    h = rms_norm(x, lp["lnx"], cfg.norm_eps)
    q = (h @ lp["xq"]).reshape(B, S, Hq, hd)
    o = flash_attention(
        q, ek, ev, causal=False, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk
    )
    return x + o.reshape(B, S, -1) @ lp["xo"]


def _enc_kv(lp, cfg, enc_out):
    B, T, d = enc_out.shape
    hd, Hkv = cfg.hd, cfg.n_kv
    ek = (enc_out @ lp["xk"]).reshape(B, T, Hkv, hd)
    ev = (enc_out @ lp["xv"]).reshape(B, T, Hkv, hd)
    return ek, ev


def _whisper_decoder(params, cfg, x, angles, enc_out, collect_cache=False):
    caches = []
    xattn = [jax.tree.map(lambda a: a[i], params["xattn"]) for i in range(cfg.n_layers)]
    for lp, xp in zip(params["layers_unrolled"], xattn):
        x, kv, _ = apply_attn_layer(lp, cfg, x, angles)
        x = _apply_xattn(xp, cfg, x, _enc_kv(xp, cfg, enc_out))
        if collect_cache:
            caches.append(kv)
    return x, caches, jnp.zeros((), F32)


# --------------------------------------------------------------------------- #
# Heads / loss
# --------------------------------------------------------------------------- #


def _unembed(params, cfg, x):
    w = params["embed"].T if cfg.tie_embeddings else params["w_out"]
    return x, w


def lm_loss(params, cfg: ModelConfig, x, labels, chunk=512):
    """Chunked cross-entropy (f32 log-softmax); labels < 0 are masked."""
    B, S, d = x.shape
    x, w = _unembed(params, cfg, x)
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    xr = x.reshape(B, nc, chunk, d)
    lr = labels.reshape(B, nc, chunk)

    @jax.checkpoint  # recompute the (B, chunk, V) logits in backward
    def one(args):
        xc, lc = args  # (B, chunk, d), (B, chunk)
        logits = (xc @ w).astype(F32)
        logits = logical(logits, ("batch", None, "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lc >= 0).astype(F32)
        return ((lse - gold) * mask).sum(), mask.sum()

    nll, cnt = jax.lax.map(one, (jnp.moveaxis(xr, 1, 0), jnp.moveaxis(lr, 1, 0)))
    return nll.sum() / jnp.maximum(cnt.sum(), 1.0)


# --------------------------------------------------------------------------- #
# Public API: train / prefill / decode
# --------------------------------------------------------------------------- #


def train_loss(params, cfg: ModelConfig, batch) -> tuple:
    """batch: {tokens|embeds|frames+tokens, labels, [positions]} → (loss, metrics)."""
    labels = batch["labels"]
    if cfg.family == "vlm":
        x = batch["embeds"]
        positions = batch.get("positions")
        if positions is None:
            S = x.shape[1]
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (x.shape[0], S)
            )
            positions = jnp.broadcast_to(positions[:, None, :], (x.shape[0], 3, S))
    else:
        tokens = batch["tokens"]
        x = params["embed"][tokens]
        S = tokens.shape[1]
        positions = batch.get(
            "positions",
            jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), tokens.shape),
        )
    x = logical(x, ("batch", "seq", "embed"))
    angles = _angles_for(cfg, positions)

    if cfg.family == "encdec":
        enc_out = _encode(params, cfg, batch["frames"])
        x, _, aux = _whisper_decoder(params, cfg, x, angles, enc_out)
    else:
        x, _, aux = _backbone(params, cfg, x, angles)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    loss = lm_loss(params, cfg, x, labels)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux}


def prefill(params, cfg: ModelConfig, batch) -> tuple:
    """Full-sequence forward returning (last_logits, cache).

    Cache layout matches :func:`init_cache_specs`; attention caches hold the
    prefill keys/values (length = prompt length), SSM/RG-LRU caches hold the
    final recurrent state + conv tail.
    """
    if cfg.family == "vlm":
        x = batch["embeds"]
        B, S = x.shape[0], x.shape[1]
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
            positions = jnp.broadcast_to(positions[:, None, :], (B, 3, S))
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = params["embed"][tokens]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = logical(x, ("batch", "seq", "embed"))
    angles = _angles_for(cfg, positions)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(params, cfg, batch["frames"])
        x, caches, _ = _whisper_decoder(params, cfg, x, angles, enc_out, True)
    else:
        x, caches, _ = _backbone(params, cfg, x, angles, collect_cache=True)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    xl, w = _unembed(params, cfg, x[:, -1:])
    logits = (xl @ w).astype(F32)
    cur_len = jnp.full((B,), S, jnp.int32)
    cache = {"layers": caches, "cur_len": cur_len}
    if enc_out is not None:
        cache["enc"] = enc_out
    return logits[:, 0], cache


def _layer_param_list(params, cfg: ModelConfig):
    """Unstack scanned layer params into a per-layer list (decode path)."""
    kinds = cfg.layer_kinds
    pattern = cfg.pattern if cfg.pattern else (kinds[0],)
    plen = len(pattern)
    n_super = cfg.n_layers // plen
    out = []
    if "layers" in params and n_super > 0:
        for s in range(n_super):
            for i, kind in enumerate(pattern):
                lp = jax.tree.map(lambda a: a[s], params["layers"][f"b{i}_{kind}"])
                out.append((kind, lp))
    else:
        out.extend(zip(kinds, params.get("layers_unrolled", [])))
    for lp, kind in zip(params.get("rem_layers", []), kinds[n_super * plen :]):
        out.append((kind, lp))
    return out


def init_cache_specs(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    """ShapeDtypeStructs of the decode cache (the decode dry-run input)."""
    hd, Hkv = cfg.hd, cfg.n_kv
    layers = []
    for kind in cfg.layer_kinds:
        if kind == "attn":
            L = min(cfg.window, max_len) if (cfg.pattern and cfg.window) else max_len
            if cfg.kv_quant_int8 and not (cfg.pattern and cfg.window):
                layers.append(
                    (
                        jax.ShapeDtypeStruct((batch, L, Hkv, hd), jnp.int8),
                        jax.ShapeDtypeStruct((batch, L, Hkv, hd), jnp.int8),
                        jax.ShapeDtypeStruct((batch, L, Hkv), F32),  # k scale
                        jax.ShapeDtypeStruct((batch, L, Hkv), F32),  # v scale
                    )
                )
                continue
            layers.append(
                (
                    jax.ShapeDtypeStruct((batch, L, Hkv, hd), dtype),
                    jax.ShapeDtypeStruct((batch, L, Hkv, hd), dtype),
                )
            )
        elif kind == "ssm":
            layers.append(
                (
                    jax.ShapeDtypeStruct(
                        (batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), F32
                    ),
                    jax.ShapeDtypeStruct(
                        (batch, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.ssm_state),
                        dtype,
                    ),
                )
            )
        elif kind == "rglru":
            W = cfg.lru_width or cfg.d_model
            layers.append(
                (
                    jax.ShapeDtypeStruct((batch, W), F32),
                    jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, W), dtype),
                )
            )
    cache = {
        "layers": layers,
        "cur_len": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }
    if cfg.family == "encdec":
        cache["enc"] = jax.ShapeDtypeStruct((batch, cfg.enc_seq, cfg.d_model), dtype)
    return cache


def pad_cache(cfg: ModelConfig, cache: dict, max_len: int) -> dict:
    """Pad prefill attention caches (length = prompt) out to ``max_len``."""

    def pad_layer(kind, lc):
        if kind == "attn" and lc is not None:
            k, v = lc
            S = k.shape[1]
            if (not (cfg.pattern and cfg.window)) and S < max_len:
                pad = ((0, 0), (0, max_len - S), (0, 0), (0, 0))
                return (jnp.pad(k, pad), jnp.pad(v, pad))
            if cfg.pattern and cfg.window:
                W = min(cfg.window, max_len)
                if S > W:  # keep last window, rolled so slot = pos mod W
                    k, v = k[:, -W:], v[:, -W:]
                    shift = S % W
                    k = jnp.roll(k, shift, axis=1)
                    v = jnp.roll(v, shift, axis=1)
                elif S < W:
                    # place tokens at slots 0..S-1 (cur_len = S < W)
                    pad = ((0, 0), (0, W - S), (0, 0), (0, 0))
                    k, v = jnp.pad(k, pad), jnp.pad(v, pad)
                return (k, v)
        return lc

    out = {
        "layers": [
            pad_layer(kind, lc)
            for kind, lc in zip(cfg.layer_kinds, cache["layers"])
        ],
        "cur_len": cache["cur_len"],
    }
    if "enc" in cache:
        out["enc"] = cache["enc"]
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    """Zero-initialised decode cache (smoke tests / serving cold start)."""
    specs = init_cache_specs(cfg, batch, max_len, dtype)
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype)
        if isinstance(s, jax.ShapeDtypeStruct)
        else s,
        specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def decode_step(params, cfg: ModelConfig, cache, tokens) -> tuple:
    """One token step.  tokens (B, 1) int32 (or embeds (B,1,d) for vlm).

    Returns (logits (B, V), new_cache).
    """
    cur_len = cache["cur_len"]
    B = cur_len.shape[0]
    if cfg.family == "vlm":
        x = tokens  # (B, 1, d) stub embeddings
        pos = jnp.broadcast_to(cur_len[:, None, None], (B, 3, 1)).astype(jnp.int32)
    else:
        x = params["embed"][tokens]
        pos = cur_len[:, None].astype(jnp.int32)
    angles = _angles_for(cfg, pos)

    layer_params = _layer_param_list(params, cfg)
    layer_caches = cache["layers"]
    enc_out = cache.get("enc")
    if cfg.family == "encdec":
        xattn = [
            jax.tree.map(lambda a: a[i], params["xattn"]) for i in range(cfg.n_layers)
        ]

    new_caches = []
    for li, ((kind, lp), lc) in enumerate(zip(layer_params, layer_caches)):
        if kind == "attn":
            window = cfg.window if cfg.pattern else 0
            if window:
                # rolling local cache: absolute slot = cur_len mod window
                k_cache, v_cache = lc
                Wn = k_cache.shape[1]
                q, k_new, v_new = _decode_qkv(lp, cfg, x, angles)
                slot = cur_len % Wn
                bidx = jnp.arange(B)
                k_cache = k_cache.at[bidx, slot].set(k_new[:, 0])
                v_cache = v_cache.at[bidx, slot].set(v_new[:, 0])
                n_valid = jnp.minimum(cur_len + 1, Wn)
                o = decode_attention(q, k_cache, v_cache, n_valid[:, None])
                x = x + o.reshape(B, 1, -1) @ lp["wo"]
                h = rms_norm(x, lp["ln2"], cfg.norm_eps)
                x = x + apply_dense_mlp(lp["mlp"], cfg, h)
                new_caches.append((k_cache, v_cache))
            else:
                x, kv = apply_attn_layer_decode(lp, cfg, x, angles, lc, cur_len)
                new_caches.append(kv)
        elif kind == "ssm":
            s, conv = lc
            x, (s, conv) = apply_ssm_layer(lp, cfg, x, state=s, conv_state=conv)
            new_caches.append((s, conv))
        elif kind == "rglru":
            h, conv = lc
            x, (h, conv) = apply_rglru_layer(lp, cfg, x, state=h, conv_state=conv)
            new_caches.append((h, conv))
        if enc_out is not None:
            x = _apply_xattn(xattn[li], cfg, x, _enc_kv(xattn[li], cfg, enc_out))

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    xl, w = _unembed(params, cfg, x)
    logits = (xl @ w).astype(F32)[:, 0]
    logits = logical(logits, ("batch", "vocab"))
    new_cache = {"layers": new_caches, "cur_len": cur_len + 1}
    if enc_out is not None:
        new_cache["enc"] = enc_out
    return logits, new_cache


def _decode_qkv(lp, cfg, x, angles):
    from repro.models.blocks import _qkv

    return _qkv(lp, cfg, x, angles)
