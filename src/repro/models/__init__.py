from repro.models.config import ModelConfig
from repro.models.lm import (
    decode_step,
    init_cache_specs,
    init_params,
    prefill,
    train_loss,
)

__all__ = [
    "ModelConfig",
    "decode_step",
    "init_cache_specs",
    "init_params",
    "prefill",
    "train_loss",
]
