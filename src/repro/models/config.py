"""Unified model configuration for the architecture zoo."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 → d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4

    # hybrid (recurrentgemma)
    window: int = 0  # local attention window (0 → global)
    pattern: tuple = ()  # per-layer block kinds, cycled; () → all "attn"
    lru_width: int = 0  # 0 → d_model

    # VLM
    mrope_sections: tuple = ()  # e.g. (16, 24, 24) over head_dim // 2

    # encoder-decoder
    encoder_layers: int = 0
    enc_seq: int = 1500  # precomputed frame embeddings (frontend stub)

    # common
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "silu"
    qk_norm: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    q_chunk: int = 512
    kv_chunk: int = 1024
    # causal attention schedule: "masked" computes all kv chunks with a mask
    # (baseline), "banded" skips fully-masked kv chunks (see §Perf hillclimb)
    attn_schedule: str = "banded"
    # §Perf: store the decode KV cache in int8 with per-(token, kv-head)
    # scales — halves the dominant memory term of decode cells
    kv_quant_int8: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:  # mamba2
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def layer_kinds(self) -> tuple:
        if self.family == "ssm":
            return tuple("ssm" for _ in range(self.n_layers))
        if self.pattern:
            reps = -(-self.n_layers // len(self.pattern))
            return (self.pattern * reps)[: self.n_layers]
        return tuple("attn" for _ in range(self.n_layers))

    def param_count(self) -> int:
        """Total parameters N (embedding included once if tied)."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd, Hq, Hkv = self.hd, self.n_heads, self.n_kv
        total = V * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * Hq + 2 * d * hd * Hkv + hd * Hq * d
        mlp = 3 * d * ff
        if self.family == "moe":
            mlp = self.n_experts * 3 * d * ff + d * self.n_experts
        ssm = 0
        if self.family == "ssm":
            din, H, N = self.d_inner, self.ssm_heads, self.ssm_state
            ssm = d * (2 * din + 2 * N + H) + din * d + 3 * H  # in/out proj + heads
        per_layer = 0
        for kind in self.layer_kinds:
            if kind == "attn":
                per_layer += attn + mlp
            elif kind == "rglru":
                w = self.lru_width or d
                per_layer += 2 * d * w + w * d + 3 * w + mlp  # gates + proj + lru
            elif kind == "ssm":
                per_layer += ssm
        total += per_layer + 2 * d * L  # norms
        if self.encoder_layers:
            total += self.encoder_layers * (attn + mlp)
        return int(total)

    def active_param_count(self) -> int:
        """N_active for MoE (top-k experts per token), else N."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_mlp = self.top_k * 3 * d * ff + d * self.n_experts
        full_mlp = self.n_experts * 3 * d * ff + d * self.n_experts
        return self.param_count() - self.n_layers * (full_mlp - dense_mlp)
