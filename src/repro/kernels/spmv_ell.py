"""Pallas TPU kernel: semiring SpMV over a blocked-ELL graph layout.

The per-round hot spot of every algorithm in the paper is the pull-style
⊕/⊗ reduction over in-edges.  TPU adaptation (DESIGN.md §8): rows are tiled
in (row_tile × max_deg) ELL tiles staged through VMEM; the frontier vector
``x_ext`` is VMEM-resident (a scale-20 graph's fp32 frontier is 4 MB — well
inside the ~16 MB v5e VMEM budget, and the BlockSpec pins it once for the
whole grid rather than re-streaming it from HBM per tile, which is the whole
point: edge traffic streams, frontier traffic stays on-chip).

Per grid step ``r`` (one row tile):
    idx_tile (row_tile, max_deg) int32   — VMEM in
    val_tile (row_tile, max_deg)         — VMEM in
    x_ext    (n_slots,)                  — VMEM resident (index_map → 0)
    out      (row_tile,)                 — VMEM out

The gather ``x_ext[idx]`` vectorises on the VPU (8×128 lanes; max_deg padded
to 128 multiples by the schedule builder).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.semiring import INT_INF
from repro.kernels.round_block import resolve_interpret

DEFAULT_ROW_TILE = 256


def _kernel_plus_times(x_ref, idx_ref, val_ref, out_ref):
    idx = idx_ref[...]  # (rows, max_deg)
    val = val_ref[...]
    gathered = x_ref[...][idx]  # vectorised VMEM gather, (rows, max_deg)+feat
    val_b = val.reshape(val.shape + (1,) * (gathered.ndim - val.ndim))
    out_ref[...] = jnp.sum(gathered * val_b, axis=1)


def _kernel_min_plus(x_ref, idx_ref, val_ref, out_ref):
    idx = idx_ref[...]
    val = val_ref[...]
    gathered = x_ref[...][idx]
    val_b = val.reshape(val.shape + (1,) * (gathered.ndim - val.ndim))
    relaxed = jnp.minimum(gathered + val_b, INT_INF)  # saturating int32
    out_ref[...] = jnp.min(relaxed, axis=1)


_KERNELS = {"plus_times": _kernel_plus_times, "min_plus": _kernel_min_plus}


@partial(jax.jit, static_argnames=("semiring", "row_tile", "interpret"))
def spmv_ell(
    x_ext,
    idx,
    val,
    *,
    semiring: str = "plus_times",
    row_tile: int = DEFAULT_ROW_TILE,
    interpret: bool | None = None,
):
    """rows = ⊕_j x_ext[idx[r, j]] ⊗ val[r, j] via pl.pallas_call.

    ``x_ext`` may be ``(n_slots,)`` or ``(n_slots, F)``; with a matrix
    frontier the output is ``(rows, F)`` and the whole ``(n_slots, F)`` tile
    is pinned in VMEM (feature columns are contiguous lanes).

    ``interpret=None`` (the default) auto-dispatches: compiled on TPU,
    interpret-mode emulation elsewhere.  Pass ``True``/``False`` to force.
    """
    interpret = resolve_interpret(interpret)
    rows, max_deg = idx.shape
    feat = x_ext.shape[1:]
    row_tile = min(row_tile, rows)
    assert rows % row_tile == 0, (rows, row_tile)
    grid = (rows // row_tile,)
    kernel = _KERNELS[semiring]
    zeros = (0,) * len(feat)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # frontier: whole vector/matrix resident in VMEM for every step
            pl.BlockSpec(x_ext.shape, lambda r, z=zeros: (0,) + z),
            pl.BlockSpec((row_tile, max_deg), lambda r: (r, 0)),
            pl.BlockSpec((row_tile, max_deg), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile,) + feat, lambda r, z=zeros: (r,) + z),
        out_shape=jax.ShapeDtypeStruct((rows,) + feat, val.dtype),
        interpret=interpret,
    )(x_ext, idx, val)
