"""Pallas TPU kernel: one full engine round (all S commit steps) fused.

This is the production realisation of the paper's thread-local delay buffer:
the extended frontier ``x_ext`` is input/output-aliased in VMEM and every
commit step reads the values committed by the steps before it — chunk compute,
δ-buffer, and flush never leave the chip.  HBM sees each edge stripe exactly
once and the frontier exactly twice (one read in, one write out) per round,
where the XLA round (:func:`repro.core.engine.round_fn`) round-trips the
frontier through HBM on every one of the ``S`` commit steps.

Generalises the retired ``delayed_block.py`` (hardcoded ⊕=+/⊗=× and
PageRank's row update) to the full ``Semiring`` × ``row_update`` family, and
is driven directly by the engine's ``(S, P, M)`` stripe layout — the same
:class:`repro.core.engine.DeviceSchedule` arrays the XLA round consumes, so
``backend="pallas"`` needs no second schedule build:

* grid = ``(S,)`` with ``dimension_semantics=("arbitrary",)`` — commit steps
  execute sequentially, so step ``s`` reads steps ``< s``'s commits (block
  Gauss–Seidel, exactly :func:`repro.core.engine._commit_step`'s order);
* per step the BlockSpecs stage that step's ``(P, M)`` edge stripe through
  VMEM while the frontier and any row-update constants stay VMEM-resident
  (index_map → 0 for the whole grid);
* the kernel body runs the *same* semiring ops as the XLA commit step
  (⊗, per-worker segment-⊕, ``row_update``, publish scatter), which is what
  makes the parity bar bit-identical rather than merely allclose.

``row_update`` is an arbitrary callable and may close over device arrays
(Jacobi's ``b/diag`` table, a PPR teleport vector).  Pallas kernels cannot
capture traced constants, so the builder traces ``row_update`` to a jaxpr
once, hoists its closure constants into explicit kernel inputs, and
re-evaluates the jaxpr inside the kernel — any engine-compatible row update
runs unmodified.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.semiring import Semiring

__all__ = [
    "fused_halo_step_fn",
    "fused_round_fn",
    "fused_round_fn_q",
    "resolve_interpret",
]

# Version portability (same spirit as repro.dist.compat): the typed
# compiler-params class is CompilerParams on current jax, TPUCompilerParams
# on 0.4.x; eval_jaxpr lives in jax.core on 0.4.x and jax.extend.core later.
_COMPILER_PARAMS_CLS = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)

try:  # pragma: no cover - depends on installed jax
    from jax.extend.core import eval_jaxpr as _eval_jaxpr
except ImportError:
    from jax.core import eval_jaxpr as _eval_jaxpr


def _sequential_grid_params() -> dict:
    """``compiler_params`` pinning the grid sequential (commit order) on TPU."""
    if _COMPILER_PARAMS_CLS is not None:
        return {
            "compiler_params": _COMPILER_PARAMS_CLS(
                dimension_semantics=("arbitrary",)
            )
        }
    return {"compiler_params": dict(mosaic=dict(dimension_semantics=("arbitrary",)))}


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` → compiled on TPU, interpret-mode emulation elsewhere.

    Explicit ``True``/``False`` is honoured as given (validation runs force
    interpretation; TPU unit tests may force compilation).
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _full_spec(shape: tuple) -> pl.BlockSpec:
    """A BlockSpec pinning the whole array VMEM-resident for every grid step."""
    return pl.BlockSpec(shape, lambda s, _nd=len(shape): (0,) * _nd)


def _at_least_1d(leaf):
    arr = jnp.asarray(leaf)
    return arr.reshape((1,)) if arr.ndim == 0 else arr


def _trace_row_update(row_update_q, semiring: Semiring, P, delta, q_avals, feat=()):
    """Trace ``row_update(old, reduced, rows, q)`` and hoist its constants.

    ``feat`` is the frontier's trailing feature shape — ``()`` for the vector
    engine, ``(F,)`` for matrix frontiers — so ``old``/``reduced`` trace at
    the same rank the kernel will feed them.
    """
    closed = jax.make_jaxpr(row_update_q)(
        jax.ShapeDtypeStruct((P, delta) + tuple(feat), semiring.dtype),
        jax.ShapeDtypeStruct((P, delta) + tuple(feat), semiring.dtype),
        jax.ShapeDtypeStruct((P, delta), np.int32),
        *q_avals,
    )
    consts = [jnp.asarray(c) for c in closed.consts]
    return closed.jaxpr, consts


def fused_round_fn_q(
    sched, semiring: Semiring, row_update, *, interpret: bool | None = None
):
    """Return ``(x_ext, q) -> x_ext`` running one full round in one kernel.

    Drop-in for :func:`repro.core.engine.round_fn_q`: same schedule, same
    ``row_update(old, reduced, rows, q)`` contract, bit-identical per round
    (the kernel body applies the identical semiring ops in the identical
    order).  ``q`` is a per-query pytree whose leaves ride along as
    VMEM-resident kernel inputs, so the returned callable vmaps for
    :func:`repro.solve.batch.solve_batch` and iterates inside
    ``lax.while_loop`` for the fused solve path.
    """
    S, P, M, delta = sched.S, sched.P, sched.M, sched.delta
    n_slots = sched.n_slots
    interp = resolve_interpret(interpret)

    def rnd(x_ext, q):
        feat = tuple(jnp.shape(x_ext)[1:])  # () vector, (F,) matrix frontier
        q_leaves, q_tree = jax.tree_util.tree_flatten(q)
        q_avals = [
            jax.ShapeDtypeStruct(jnp.shape(leaf), jnp.result_type(leaf))
            for leaf in q_leaves
        ]

        def row_update_flat(old, reduced, rows, *leaves):
            return row_update(
                old, reduced, rows, jax.tree_util.tree_unflatten(q_tree, leaves)
            )

        jaxpr, consts = _trace_row_update(
            row_update_flat, semiring, P, delta, q_avals, feat
        )
        c_shapes = [c.shape for c in consts]
        c_in = [_at_least_1d(c) for c in consts]
        q_in = [_at_least_1d(leaf) for leaf in q_leaves]
        n_consts, n_q = len(c_in), len(q_in)

        def kernel(*refs):
            # refs = (src, val, dst, rows, *consts, *q, x_in, x_out); x_in is
            # the alias donor — x_ref below is the persistent VMEM frontier.
            src_ref, val_ref, dst_ref, rows_ref = refs[:4]
            c_refs = refs[4 : 4 + n_consts]
            q_refs = refs[4 + n_consts : 4 + n_consts + n_q]
            x_ref = refs[-1]
            src = src_ref[0]  # (P, M) — this commit step's edge stripe
            val = val_ref[0]
            dst = dst_ref[0]
            rows = rows_ref[0]  # (P, delta)
            x = x_ref[...]  # reads every prior step's commits
            val_b = val.reshape(val.shape + (1,) * len(feat))
            contrib = semiring.mul(x[src], val_b)
            # Per-worker segment-⊕ into δ + 1 slots (last = padding dump).
            seg = dst + (jnp.arange(P, dtype=jnp.int32) * (delta + 1))[:, None]
            reduced = semiring.segment_reduce(
                contrib.reshape((-1,) + feat), seg.reshape(-1), P * (delta + 1)
            ).reshape((P, delta + 1) + feat)[:, :delta]
            old = x[rows]
            c_vals = [c[...].reshape(shape) for c, shape in zip(c_refs, c_shapes)]
            leaves = [r[...].reshape(a.shape) for r, a in zip(q_refs, q_avals)]
            (new,) = _eval_jaxpr(jaxpr, c_vals, old, reduced, rows, *leaves)
            # The flush: commit this step's chunks into the VMEM frontier.
            if feat:
                chunk = new.reshape((-1,) + feat).astype(x_ref.dtype)
                x_ref[...] = x.at[rows.reshape(-1)].set(chunk)
            else:
                x_ref[rows.reshape(-1)] = new.reshape(-1).astype(x_ref.dtype)

        stripe = [
            pl.BlockSpec((1, P, M), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, P, M), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, P, M), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, P, delta), lambda s: (s, 0, 0)),
        ]
        resident = [_full_spec(a.shape) for a in (*c_in, *q_in)]
        return pl.pallas_call(
            kernel,
            grid=(S,),
            in_specs=stripe + resident + [_full_spec((n_slots,) + feat)],
            out_specs=_full_spec((n_slots,) + feat),
            out_shape=jax.ShapeDtypeStruct((n_slots,) + feat, semiring.dtype),
            # x_ext in ↔ out: commits stay visible across sequential steps
            input_output_aliases={4 + n_consts + n_q: 0},
            interpret=interp,
            **_sequential_grid_params(),
        )(sched.src, sched.val, sched.dst_local, sched.rows, *c_in, *q_in, x_ext)

    return rnd


def fused_halo_step_fn(
    semiring: Semiring,
    row_update,
    *,
    P_loc: int,
    M: int,
    delta: int,
    L: int,
    H: int,
    interpret: bool | None = None,
):
    """One owner-computes halo commit step, fused into a single kernel.

    Returns ``(x_loc, src_s, val_s, dst_s, rows_g_s, rows_loc_s, send_s, q)
    -> (x_loc, send_vals)`` — the per-shard half of one commit step of
    :func:`repro.dist.engine_sharded.frontier_pallas_round_fn`: gather,
    ⊗, per-worker segment-⊕, ``row_update``, the owner-computes publish into
    the shard's ``(L,)`` local frontier (input/output-aliased, so the
    frontier never leaves VMEM inside the step), and the selection of the
    ``(H,)`` boundary rows this commit must ship.  Only the all-gather of
    those boundary rows stays outside the kernel — it is the one part of a
    halo commit that must cross devices, so it is also the only part whose
    intermediates touch HBM.

    Unlike :func:`fused_round_fn_q` the grid holds a single step: shard ``e``
    at step ``s`` reads remote boundary values committed at ``s - 1``, so a
    cross-device exchange must run between commits and an all-``S`` fused
    grid per shard cannot reproduce the reference order.  The engine calls
    this kernel ``S`` times per round under ``lax.fori_loop``, exchanging
    halos between invocations.

    ``rows_loc_s`` are shard-local row slots (dump ``= L - 1``) used for the
    read-modify-write; ``rows_g_s`` are the global row ids ``row_update``
    sees (PPR teleports index ``q`` by global vertex).  ``send_s`` indexes
    the flat ``(P_loc·δ,)`` committed chunk, exactly like the XLA halo
    round's ``send_idx``.
    """
    interp = resolve_interpret(interpret)

    def step(x_loc, src_s, val_s, dst_s, rows_g_s, rows_loc_s, send_s, q):
        feat = tuple(jnp.shape(x_loc)[1:])  # () vector, (F,) matrix frontier
        q_leaves, q_tree = jax.tree_util.tree_flatten(q)
        q_avals = [
            jax.ShapeDtypeStruct(jnp.shape(leaf), jnp.result_type(leaf))
            for leaf in q_leaves
        ]

        def row_update_flat(old, reduced, rows, *leaves):
            return row_update(
                old, reduced, rows, jax.tree_util.tree_unflatten(q_tree, leaves)
            )

        jaxpr, consts = _trace_row_update(
            row_update_flat, semiring, P_loc, delta, q_avals, feat
        )
        c_shapes = [c.shape for c in consts]
        c_in = [_at_least_1d(c) for c in consts]
        q_in = [_at_least_1d(leaf) for leaf in q_leaves]
        n_consts, n_q = len(c_in), len(q_in)

        def kernel(*refs):
            src_ref, val_ref, dst_ref, rg_ref, rl_ref, snd_ref = refs[:6]
            c_refs = refs[6 : 6 + n_consts]
            q_refs = refs[6 + n_consts : 6 + n_consts + n_q]
            # x is aliased input ↔ output 0; send is output 1.
            x_ref, send_ref = refs[-2], refs[-1]
            src = src_ref[...]  # (P_loc, M) — owned + halo reads, all local
            val = val_ref[...]
            dst = dst_ref[...]
            rows_g = rg_ref[...]  # (P_loc, delta) global ids for row_update
            rows_l = rl_ref[...]  # (P_loc, delta) local slots (dump = L - 1)
            x = x_ref[...]
            val_b = val.reshape(val.shape + (1,) * len(feat))
            contrib = semiring.mul(x[src], val_b)
            seg = dst + (jnp.arange(P_loc, dtype=jnp.int32) * (delta + 1))[:, None]
            reduced = semiring.segment_reduce(
                contrib.reshape((-1,) + feat), seg.reshape(-1), P_loc * (delta + 1)
            ).reshape((P_loc, delta + 1) + feat)[:, :delta]
            old = x[rows_l]
            c_vals = [c[...].reshape(shape) for c, shape in zip(c_refs, c_shapes)]
            leaves = [r[...].reshape(a.shape) for r, a in zip(q_refs, q_avals)]
            (new,) = _eval_jaxpr(jaxpr, c_vals, old, reduced, rows_g, *leaves)
            chunk = new.reshape((-1,) + feat).astype(x_ref.dtype)
            # Owner-computes publish: commit this shard's chunk in VMEM.
            if feat:
                x_ref[...] = x.at[rows_l.reshape(-1)].set(chunk)
            else:
                x_ref[rows_l.reshape(-1)] = chunk
            # Boundary selection for the halo exchange, also in VMEM.
            send_ref[...] = chunk[snd_ref[...]]

        ins = (src_s, val_s, dst_s, rows_g_s, rows_loc_s, send_s, *c_in, *q_in, x_loc)
        return pl.pallas_call(
            kernel,
            grid=(1,),
            in_specs=[_full_spec(jnp.shape(a)) for a in ins],
            out_specs=[_full_spec((L,) + feat), _full_spec((H,) + feat)],
            out_shape=[
                jax.ShapeDtypeStruct((L,) + feat, semiring.dtype),
                jax.ShapeDtypeStruct((H,) + feat, semiring.dtype),
            ],
            input_output_aliases={len(ins) - 1: 0},
            interpret=interp,
            **_sequential_grid_params(),
        )(*ins)

    return step


def fused_round_fn(
    sched, semiring: Semiring, row_update, *, interpret: bool | None = None
):
    """Return ``x_ext -> x_ext``: the query-free fused round (one kernel)."""
    fn_q = fused_round_fn_q(
        sched,
        semiring,
        lambda old, reduced, rows, q: row_update(old, reduced, rows),
        interpret=interpret,
    )
    dummy = jnp.zeros((), jnp.int32)
    return lambda x_ext: fn_q(x_ext, dummy)
