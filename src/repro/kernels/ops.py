"""Dispatch wrappers: Pallas kernel on TPU, interpret-mode or XLA fallback
elsewhere.  Public entry points used by the engine and benchmarks."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.delayed_block import delayed_block_pagerank
from repro.kernels.spmv_ell import spmv_ell


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def spmv(x_ext, idx, val, semiring: str = "plus_times", use_kernel: bool = True):
    """Semiring SpMV; Pallas when requested (compiled on TPU, interpreted on
    CPU), pure-jnp otherwise."""
    if use_kernel:
        return spmv_ell(x_ext, idx, val, semiring=semiring, interpret=not _on_tpu())
    return ref.spmv_ell_ref(x_ext, idx, val, semiring)


def delayed_round(x_ext, idx, val, rows, teleport, use_kernel: bool = True):
    """Fused delayed-async PageRank round for one worker block."""
    if use_kernel:
        return delayed_block_pagerank(
            x_ext, idx, val, rows, teleport, interpret=not _on_tpu()
        )
    return ref.delayed_block_ref(
        x_ext, idx, val, rows, teleport, n_chunks=idx.shape[0]
    )


def ell_from_csr(graph, rows_slice=None, lane_pad: int = 128):
    """Build padded ELL (idx, val) from a CSRGraph (host-side, numpy).

    Padding entries point at the dump slot with annihilating values so the
    kernels need no masks.  ``max_deg`` is padded to a lane multiple.
    """
    indptr, indices, values = graph.indptr, graph.indices, graph.values
    n = graph.n
    rows = np.arange(n) if rows_slice is None else rows_slice
    degs = indptr[rows + 1] - indptr[rows]
    max_deg = int(max(degs.max(), 1))
    max_deg = -(-max_deg // lane_pad) * lane_pad
    idx = np.zeros((len(rows), max_deg), np.int32)
    pad_val = np.float32(0.0) if values.dtype.kind == "f" else np.int32(2**30 - 1)
    val = np.full((len(rows), max_deg), pad_val, values.dtype)
    for i, r in enumerate(rows):
        e0, e1 = indptr[r], indptr[r + 1]
        idx[i, : e1 - e0] = indices[e0:e1]
        val[i, : e1 - e0] = values[e0:e1]
    return idx, val
