"""Dispatch wrappers and host-side layout builders for the Pallas kernels.

Public entry points used by the engine's ``backend="pallas"`` path
(:func:`fused_round` ↔ :mod:`repro.kernels.round_block`), tests, and
benchmarks.  Kernels auto-dispatch on backend: compiled on TPU,
interpret-mode emulation elsewhere (``interpret=None``); ``use_kernel=False``
falls back to the pure-jnp oracles in :mod:`repro.kernels.ref`.
"""

from __future__ import annotations

import numpy as np

from repro.kernels import ref
from repro.kernels.round_block import fused_round_fn, fused_round_fn_q
from repro.kernels.spmv_ell import spmv_ell


def spmv(x_ext, idx, val, semiring: str = "plus_times", use_kernel: bool = True):
    """Semiring SpMV; Pallas when requested (compiled on TPU, interpreted
    elsewhere), pure-jnp otherwise."""
    if use_kernel:
        return spmv_ell(x_ext, idx, val, semiring=semiring)
    return ref.spmv_ell_ref(x_ext, idx, val, semiring)


def fused_round(
    x_ext,
    sched,
    semiring,
    row_update,
    q=None,
    use_kernel: bool = True,
    interpret: bool | None = None,
):
    """One full engine round (all S commit steps) over ``sched``.

    The kernel path runs :mod:`repro.kernels.round_block`'s single fused
    ``pallas_call`` (frontier VMEM-resident across commits); the fallback is
    the engine's XLA round itself — the parity reference.  Pass ``q`` for
    query-parameterized row updates (``row_update(old, reduced, rows, q)``).
    """
    if use_kernel:
        if q is None:
            return fused_round_fn(sched, semiring, row_update, interpret=interpret)(
                x_ext
            )
        return fused_round_fn_q(sched, semiring, row_update, interpret=interpret)(
            x_ext, q
        )
    return ref.fused_round_ref(x_ext, sched, semiring, row_update, q)


def ell_from_csr(graph, rows_slice=None, lane_pad: int = 128):
    """Build padded ELL (idx, val) from a CSRGraph (host-side, numpy).

    Padding entries gather vertex 0 but carry the semiring's *annihilating*
    edge value, so they contribute the ⊕-identity and the kernels need no
    masks.  ``max_deg`` is padded to a lane multiple.
    Fully vectorized (numpy fancy indexing) — no per-row Python loop, so
    host-side layout cost stays flat in ``n`` like
    :func:`repro.graphs.formats.build_stripe_schedule`.
    """
    indptr, indices, values = graph.indptr, graph.indices, graph.values
    n = graph.n
    rows = np.arange(n) if rows_slice is None else np.asarray(rows_slice)
    degs = (indptr[rows + 1] - indptr[rows]).astype(np.int64)
    max_deg = int(max(degs.max() if degs.size else 0, 1))
    max_deg = -(-max_deg // lane_pad) * lane_pad
    pad_val = np.float32(0.0) if values.dtype.kind == "f" else np.int32(2**30 - 1)
    if graph.nnz == 0:
        idx = np.zeros((len(rows), max_deg), np.int32)
        val = np.full((len(rows), max_deg), pad_val, values.dtype)
        return idx, val
    # edge slot (r, j) holds the row's j-th in-edge; mask kills the overhang
    offs = np.arange(max_deg, dtype=np.int64)[None, :]
    mask = offs < degs[:, None]
    pos = np.minimum(indptr[rows][:, None] + offs, graph.nnz - 1)
    idx = np.where(mask, indices[pos], 0).astype(np.int32)
    val = np.where(mask, values[pos], pad_val).astype(values.dtype)
    return idx, val
