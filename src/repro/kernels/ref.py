"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def spmv_ell_ref(x_ext, idx, val, semiring: str):
    """Semiring SpMV over ELL rows.

    x_ext: (n_slots,) frontier (+ dump slot); idx: (rows, max_deg) int32
    (padding points anywhere, val annihilates); val: (rows, max_deg).
    Returns (rows,) = ⊕_j x_ext[idx[r, j]] ⊗ val[r, j].
    """
    gathered = x_ext[idx]  # (rows, max_deg)
    if semiring == "plus_times":
        return jnp.sum(gathered * val, axis=1)
    if semiring == "min_plus":
        return jnp.min(
            jnp.minimum(gathered.astype(jnp.int64) + val.astype(jnp.int64), 2**30 - 1),
            axis=1,
        ).astype(val.dtype)
    raise ValueError(semiring)


def delayed_block_ref(x_ext, idx, val, rows, teleport, n_chunks, semiring="plus_times"):
    """Oracle for the fused delayed-async PageRank block kernel.

    Processes ``n_chunks`` δ-chunks sequentially; chunk c reads the frontier
    *including* all previously committed chunks (block Gauss–Seidel).

    idx/val: (n_chunks, delta, max_deg); rows: (n_chunks, delta) int32 row
    ids (dump = len(x_ext) - 1).
    """
    for c in range(n_chunks):
        red = spmv_ell_ref(x_ext, idx[c], val[c], semiring)
        new = teleport + red
        x_ext = x_ext.at[rows[c]].set(new.astype(x_ext.dtype), mode="drop")
    return x_ext
