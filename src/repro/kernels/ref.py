"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""

from __future__ import annotations

import jax.numpy as jnp


def spmv_ell_ref(x_ext, idx, val, semiring: str):
    """Semiring SpMV over ELL rows.

    x_ext: (n_slots,)+feat frontier (+ dump slot), feat ∈ {(), (F,)};
    idx: (rows, max_deg) int32 (padding points anywhere, val annihilates);
    val: (rows, max_deg) — one ⊗ weight per edge, broadcast over features.
    Returns (rows,)+feat = ⊕_j x_ext[idx[r, j]] ⊗ val[r, j].
    """
    gathered = x_ext[idx]  # (rows, max_deg) + feat
    val_b = val.reshape(val.shape + (1,) * (gathered.ndim - val.ndim))
    if semiring == "plus_times":
        return jnp.sum(gathered * val_b, axis=1)
    if semiring == "min_plus":
        return jnp.min(
            jnp.minimum(
                gathered.astype(jnp.int64) + val_b.astype(jnp.int64), 2**30 - 1
            ),
            axis=1,
        ).astype(val.dtype)
    raise ValueError(semiring)


def fused_round_ref(x_ext, sched, semiring, row_update, q=None):
    """Oracle for the fused-round kernel (:mod:`repro.kernels.round_block`).

    The kernel's contract is literally "the engine's round, in one kernel" —
    so the oracle IS the engine's XLA round (:func:`repro.core.engine.
    round_fn`), not a third copy of the commit-step math.
    """
    from repro.core.engine import round_fn, round_fn_q

    if q is None:
        return round_fn(sched, semiring, row_update)(x_ext)
    return round_fn_q(sched, semiring, row_update)(x_ext, q)
