"""Pallas TPU kernel: fused delayed-async inner loop (beyond-paper fusion).

One kernel instance owns a worker's whole vertex block and executes ALL of
its δ-chunks, committing each chunk into the VMEM-resident frontier copy
before computing the next (block Gauss–Seidel).  On the CPU of the paper this
round-trips through the cache hierarchy between chunks; here chunk compute,
buffer, and flush all stay in VMEM — the on-chip realisation of the paper's
thread-local delay buffer.  HBM sees exactly one read of the edge tiles and
one write of the final frontier.

Grid = (n_chunks,) with ``x_ext`` aliased in/out (input_output_aliasing), so
grid step c reads the frontier state committed by steps < c —
``dimension_semantics=("arbitrary",)`` pins the sequential order on TPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(tele_ref, idx_ref, val_ref, rows_ref, x_in_ref, x_ref):
    # x_ref is the aliased frontier: initialised from x_in, persistent across
    # the (sequential) grid steps — reads here see every prior chunk's commit.
    del x_in_ref
    idx = idx_ref[0]  # (delta, max_deg)
    val = val_ref[0]
    rows = rows_ref[0]  # (delta,)
    gathered = x_ref[idx]
    red = jnp.sum(gathered * val, axis=1)  # ⊕ = +, ⊗ = × (PageRank)
    new = tele_ref[0] + red
    # the flush: commit this δ-chunk into the shared frontier copy
    x_ref[rows] = new.astype(x_ref.dtype)


@partial(jax.jit, static_argnames=("interpret",))
def delayed_block_pagerank(x_ext, idx, val, rows, teleport, *, interpret: bool = True):
    """Run one worker round: all δ-chunks with in-VMEM commits.

    x_ext (n_slots,) f32 — frontier + dump slot (aliased output);
    idx/val (n_chunks, delta, max_deg); rows (n_chunks, delta) int32.
    """
    n_chunks, delta, max_deg = idx.shape
    tele = jnp.full((1,), teleport, x_ext.dtype)
    grid = (n_chunks,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda c: (0,)),
            pl.BlockSpec((1, delta, max_deg), lambda c: (c, 0, 0)),
            pl.BlockSpec((1, delta, max_deg), lambda c: (c, 0, 0)),
            pl.BlockSpec((1, delta), lambda c: (c, 0)),
            pl.BlockSpec(x_ext.shape, lambda c: (0,)),
        ],
        out_specs=pl.BlockSpec(x_ext.shape, lambda c: (0,)),
        out_shape=jax.ShapeDtypeStruct(x_ext.shape, x_ext.dtype),
        input_output_aliases={4: 0},  # x_ext in ↔ out: commits are visible
        interpret=interpret,
    )(tele, idx, val, rows, x_ext)
