"""The :class:`Problem` spec — what an iterative graph computation *is*.

A pull-style fixed point ``x'[u] = row_update(x[u], ⊕_{v∈in(u)} x[v] ⊗ A[v,u])``
is fully described by a semiring, a row update, a residual (the convergence
metric), an initial-state factory, and a tolerance.  Everything else — the
commit period δ, the backend, schedule construction, compilation — is a
*runtime* decision the :class:`repro.solve.Solver` makes.  The four public
algorithms are one-line factories over this type.

Query-parameterized problems (``takes_query=True``) thread an extra per-query
pytree ``q`` into ``row_update`` — this is how personalized PageRank gets a
per-seed teleport vector while sharing one compiled round function across the
whole batch.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.semiring import INT_INF, MIN_PLUS, PLUS_TIMES, Semiring
from repro.graphs.formats import CSRGraph

__all__ = [
    "Problem",
    "min_label_row_update",
    "count_changed_residual",
    "l1_residual",
    "pagerank_problem",
    "ppr_problem",
    "sssp_problem",
    "cc_problem",
    "jacobi_problem",
    "multi_source_x0",
    "ppr_teleport",
]


@dataclasses.dataclass(frozen=True)
class Problem:
    """Frozen spec of one iterative graph computation.

    * ``semiring``        — ⊕/⊗ algebra (also fixes the state dtype).
    * ``make_row_update`` — ``graph -> row_update``; the returned callable is
      ``(old, reduced, rows) -> new`` (or ``(old, reduced, rows, q) -> new``
      when ``takes_query``).  ``rows`` holds global row ids (dump slot = n).
    * ``residual``        — ``(x_prev, x_new) -> scalar``; converged when
      ``residual ≤ tol``.
    * ``x0``              — ``graph -> (n,) ndarray`` initial state factory.
    * ``edge_values``     — optional ``graph -> (nnz,) ndarray`` override used
      when building the schedule (e.g. CC zeroes the weights so ⊗ is a no-op).
    * ``default_query``   — optional ``graph -> q`` for query problems, used
      when :meth:`Solver.solve` is called without an explicit ``q``.
    """

    name: str
    semiring: Semiring
    make_row_update: Callable
    residual: Callable
    x0: Callable
    tol: float
    max_rounds: int = 1000
    edge_values: Callable | None = None
    takes_query: bool = False
    default_query: Callable | None = None

    @property
    def dtype(self) -> np.dtype:
        return np.dtype(self.semiring.dtype)


# --------------------------------------------------------------------------- #
# Shared kernels (deduplicated from sssp.py / cc.py, which carried this pair
# verbatim): min-plus label propagation converging when no vertex changed.
# --------------------------------------------------------------------------- #
def min_label_row_update(graph: CSRGraph):
    """``min(old, ⊕-reduced)`` — the min-plus relaxation row update."""
    del graph  # state-free: same update for every topology

    def row_update(old, reduced, rows):
        return jnp.minimum(old, reduced)

    return row_update


def count_changed_residual(x_prev, x_new):
    """Number of vertices whose value changed this round (paper's stop rule)."""
    return jnp.sum((x_prev != x_new).astype(jnp.float32))


def l1_residual(x_prev, x_new):
    """Total absolute change across vertices (PageRank/Jacobi stop rule)."""
    return jnp.sum(jnp.abs(x_new - x_prev))


# --------------------------------------------------------------------------- #
# Problem factories — the whole public algorithm surface.
# --------------------------------------------------------------------------- #
def pagerank_problem(
    damping: float = 0.85, tol: float = 1e-4, max_rounds: int = 1000
) -> Problem:
    """PageRank (paper §IV-A): edge values must hold ``d / outdeg(src)``."""

    def make_row_update(graph):
        teleport = np.float32((1.0 - damping) / graph.n)

        def row_update(old, reduced, rows):
            return teleport + reduced

        return row_update

    return Problem(
        name="pagerank",
        semiring=PLUS_TIMES,
        make_row_update=make_row_update,
        residual=l1_residual,
        x0=lambda g: np.full(g.n, 1.0 / g.n, dtype=np.float32),
        tol=tol,
        max_rounds=max_rounds,
    )


def ppr_teleport(graph: CSRGraph, seeds, damping: float = 0.85) -> np.ndarray:
    """(Q, n) teleport vectors ``(1-d)·e_seed`` for :func:`ppr_problem`."""
    seeds = np.atleast_1d(np.asarray(seeds, dtype=np.int64))
    t = np.zeros((seeds.shape[0], graph.n), dtype=np.float32)
    t[np.arange(seeds.shape[0]), seeds] = np.float32(1.0 - damping)
    return t


def ppr_problem(
    damping: float = 0.85, tol: float = 1e-4, max_rounds: int = 1000
) -> Problem:
    """Personalized PageRank: the teleport vector is a *query parameter*.

    ``q`` is a dense (n,) teleport vector (see :func:`ppr_teleport` for the
    single-seed form).  With the uniform vector ``(1-d)/n`` this is exactly
    :func:`pagerank_problem` — bit-identical — which is the parity test.
    Indexing ``q[rows]`` relies on jax's clipping gather for the dump rows
    (``rows == n``): whatever they read is written to the write-only dump slot.
    """

    def make_row_update(graph):
        def row_update(old, reduced, rows, q):
            return q[rows] + reduced

        return row_update

    return Problem(
        name="ppr",
        semiring=PLUS_TIMES,
        make_row_update=make_row_update,
        residual=l1_residual,
        x0=lambda g: np.full(g.n, 1.0 / g.n, dtype=np.float32),
        tol=tol,
        max_rounds=max_rounds,
        takes_query=True,
        default_query=lambda g: np.full(g.n, (1.0 - damping) / g.n, dtype=np.float32),
    )


def multi_source_x0(graph: CSRGraph, sources) -> np.ndarray:
    """(Q, n) SSSP initial states, one per source — feed to ``solve_batch``."""
    sources = np.atleast_1d(np.asarray(sources, dtype=np.int64))
    x0 = np.full((sources.shape[0], graph.n), INT_INF, dtype=np.int32)
    x0[np.arange(sources.shape[0]), sources] = 0
    return x0


def sssp_problem(source: int = 0, max_rounds: int = 10_000) -> Problem:
    """Bellman-Ford SSSP (paper §IV-D): int32 min-plus relaxation."""

    def x0(graph):
        x = np.full(graph.n, INT_INF, dtype=np.int32)
        x[source] = 0
        return x

    return Problem(
        name="sssp",
        semiring=MIN_PLUS,
        make_row_update=min_label_row_update,
        residual=count_changed_residual,
        x0=x0,
        tol=0.5,  # "no vertex updated last round"
        max_rounds=max_rounds,
    )


def cc_problem(max_rounds: int = 10_000) -> Problem:
    """Connected components via min-label propagation (symmetric graphs)."""
    return Problem(
        name="cc",
        semiring=MIN_PLUS,
        make_row_update=min_label_row_update,
        residual=count_changed_residual,
        x0=lambda g: np.arange(g.n, dtype=np.int32),
        tol=0.5,
        max_rounds=max_rounds,
        edge_values=lambda g: np.zeros(g.nnz, dtype=np.int32),
    )


def jacobi_problem(
    diag: np.ndarray, b: np.ndarray, tol: float = 1e-6, max_rounds: int = 5000
) -> Problem:
    """Jacobi/block-GS fixed point for ``A x = b``.

    The graph must carry the pull splitting ``-A_ij / A_ii`` on edge
    ``(j -> i)`` (see :func:`repro.algorithms.jacobi.jacobi_graph`).
    """
    b_over_diag = (np.asarray(b) / np.asarray(diag)).astype(np.float32)

    def make_row_update(graph):
        # b / diag gathered per row; padded slot (row == n) contributes 0.
        ext = jnp.asarray(np.concatenate([b_over_diag, [np.float32(0.0)]]))

        def row_update(old, reduced, rows):
            return ext[rows] + reduced

        return row_update

    return Problem(
        name="jacobi",
        semiring=PLUS_TIMES,
        make_row_update=make_row_update,
        residual=l1_residual,
        x0=lambda g: np.zeros(g.n, dtype=np.float32),
        tol=tol,
        max_rounds=max_rounds,
    )
