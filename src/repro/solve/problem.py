"""The :class:`Problem` spec — what an iterative graph computation *is*.

A pull-style fixed point ``x'[u] = row_update(x[u], ⊕_{v∈in(u)} x[v] ⊗ A[v,u])``
is fully described by a semiring, a row update, a residual (the convergence
metric), an initial-state factory, and a tolerance.  Everything else — the
commit period δ, the backend, schedule construction, compilation — is a
*runtime* decision the :class:`repro.solve.Solver` makes.  The four public
algorithms are one-line factories over this type.

Query-parameterized problems (``takes_query=True``) thread an extra per-query
pytree ``q`` into ``row_update`` — this is how personalized PageRank gets a
per-seed teleport vector while sharing one compiled round function across the
whole batch.

State need not be a vector: a problem with ``feature_dim = F > 1`` iterates an
``(n, F)`` frontier *matrix* on the same engine — each commit step segment-⊕s
F-wide rows instead of scalars.  :func:`rwr_embedding_problem` (random-walk-
with-restart, F restart columns) and :func:`label_propagation_problem`
(F classes, row-normalized ⊕) are the two built-in matrix workloads.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.semiring import INT_INF, MIN_PLUS, PLUS_TIMES, Semiring
from repro.graphs.formats import CSRGraph

__all__ = [
    "Problem",
    "min_label_row_update",
    "count_changed_residual",
    "l1_residual",
    "pagerank_problem",
    "ppr_problem",
    "sssp_problem",
    "cc_problem",
    "jacobi_problem",
    "rwr_embedding_problem",
    "label_propagation_problem",
    "multi_source_x0",
    "ppr_teleport",
    "rwr_restart",
    "labelprop_anchors",
    "default_landmarks",
]


@dataclasses.dataclass(frozen=True)
class Problem:
    """Frozen spec of one iterative graph computation.

    * ``semiring``        — ⊕/⊗ algebra (also fixes the state dtype).
    * ``make_row_update`` — ``graph -> row_update``; the returned callable is
      ``(old, reduced, rows) -> new`` (or ``(old, reduced, rows, q) -> new``
      when ``takes_query``).  ``rows`` holds global row ids (dump slot = n).
    * ``residual``        — ``(x_prev, x_new) -> scalar``; converged when
      ``residual ≤ tol``.
    * ``x0``              — ``graph -> (n,) ndarray`` initial state factory.
    * ``edge_values``     — optional ``graph -> (nnz,) ndarray`` override used
      when building the schedule (e.g. CC zeroes the weights so ⊗ is a no-op).
    * ``default_query``   — optional ``graph -> q`` for query problems, used
      when :meth:`Solver.solve` is called without an explicit ``q``.
    * ``feature_dim``     — frontier width F.  ``1`` (the default) is the
      classic vector engine; problems with ``F > 1`` iterate an ``(n, F)``
      matrix state (``x0`` must then return ``(n, F)``).  A ``feature_dim=1``
      problem also accepts an explicit ``(n, 1)`` initial state, which runs
      the matrix code path and is bit-identical to the vector solve — the
      degeneracy invariant the tests pin on every backend.
    """

    name: str
    semiring: Semiring
    make_row_update: Callable
    residual: Callable
    x0: Callable
    tol: float
    max_rounds: int = 1000
    edge_values: Callable | None = None
    takes_query: bool = False
    default_query: Callable | None = None
    feature_dim: int = 1

    @property
    def dtype(self) -> np.dtype:
        """State dtype, fixed by the semiring."""
        return np.dtype(self.semiring.dtype)


# --------------------------------------------------------------------------- #
# Shared kernels (deduplicated from sssp.py / cc.py, which carried this pair
# verbatim): min-plus label propagation converging when no vertex changed.
# --------------------------------------------------------------------------- #
def min_label_row_update(graph: CSRGraph):
    """``min(old, ⊕-reduced)`` — the min-plus relaxation row update."""
    del graph  # state-free: same update for every topology

    def row_update(old, reduced, rows):
        return jnp.minimum(old, reduced)

    return row_update


def count_changed_residual(x_prev, x_new):
    """Number of vertices whose value changed this round (paper's stop rule)."""
    return jnp.sum((x_prev != x_new).astype(jnp.float32))


def l1_residual(x_prev, x_new):
    """Total absolute change across vertices (PageRank/Jacobi stop rule)."""
    return jnp.sum(jnp.abs(x_new - x_prev))


def _match_features(table, reduced):
    """Align a per-row gather against ``reduced``'s optional feature axis.

    ``table`` is a per-row vector gather like ``q[rows]`` (shape ``(P, δ)``);
    when the engine runs a matrix frontier, ``reduced`` is ``(P, δ, F)`` and
    the vector table must broadcast as ``(P, δ, 1)``.  The rank test is
    static, so the vector path's jaxpr is untouched (bit-identity).
    """
    if reduced.ndim == table.ndim + 1:
        return table[..., None]
    return table


# --------------------------------------------------------------------------- #
# Problem factories — the whole public algorithm surface.
# --------------------------------------------------------------------------- #
def pagerank_problem(
    damping: float = 0.85, tol: float = 1e-4, max_rounds: int = 1000
) -> Problem:
    """PageRank (paper §IV-A): edge values must hold ``d / outdeg(src)``."""

    def make_row_update(graph):
        teleport = np.float32((1.0 - damping) / graph.n)

        def row_update(old, reduced, rows):
            return teleport + reduced

        return row_update

    return Problem(
        name="pagerank",
        semiring=PLUS_TIMES,
        make_row_update=make_row_update,
        residual=l1_residual,
        x0=lambda g: np.full(g.n, 1.0 / g.n, dtype=np.float32),
        tol=tol,
        max_rounds=max_rounds,
    )


def ppr_teleport(graph: CSRGraph, seeds, damping: float = 0.85) -> np.ndarray:
    """(Q, n) teleport vectors ``(1-d)·e_seed`` for :func:`ppr_problem`."""
    seeds = np.atleast_1d(np.asarray(seeds, dtype=np.int64))
    t = np.zeros((seeds.shape[0], graph.n), dtype=np.float32)
    t[np.arange(seeds.shape[0]), seeds] = np.float32(1.0 - damping)
    return t


def ppr_problem(
    damping: float = 0.85, tol: float = 1e-4, max_rounds: int = 1000
) -> Problem:
    """Personalized PageRank: the teleport vector is a *query parameter*.

    ``q`` is a dense (n,) teleport vector (see :func:`ppr_teleport` for the
    single-seed form).  With the uniform vector ``(1-d)/n`` this is exactly
    :func:`pagerank_problem` — bit-identical — which is the parity test.
    Indexing ``q[rows]`` relies on jax's clipping gather for the dump rows
    (``rows == n``): whatever they read is written to the write-only dump slot.
    """

    def make_row_update(graph):
        def row_update(old, reduced, rows, q):
            return _match_features(q[rows], reduced) + reduced

        return row_update

    return Problem(
        name="ppr",
        semiring=PLUS_TIMES,
        make_row_update=make_row_update,
        residual=l1_residual,
        x0=lambda g: np.full(g.n, 1.0 / g.n, dtype=np.float32),
        tol=tol,
        max_rounds=max_rounds,
        takes_query=True,
        default_query=lambda g: np.full(g.n, (1.0 - damping) / g.n, dtype=np.float32),
    )


def multi_source_x0(graph: CSRGraph, sources) -> np.ndarray:
    """(Q, n) SSSP initial states, one per source — feed to ``solve_batch``."""
    sources = np.atleast_1d(np.asarray(sources, dtype=np.int64))
    x0 = np.full((sources.shape[0], graph.n), INT_INF, dtype=np.int32)
    x0[np.arange(sources.shape[0]), sources] = 0
    return x0


def sssp_problem(source: int = 0, max_rounds: int = 10_000) -> Problem:
    """Bellman-Ford SSSP (paper §IV-D): int32 min-plus relaxation."""

    def x0(graph):
        x = np.full(graph.n, INT_INF, dtype=np.int32)
        x[source] = 0
        return x

    return Problem(
        name="sssp",
        semiring=MIN_PLUS,
        make_row_update=min_label_row_update,
        residual=count_changed_residual,
        x0=x0,
        tol=0.5,  # "no vertex updated last round"
        max_rounds=max_rounds,
    )


def cc_problem(max_rounds: int = 10_000) -> Problem:
    """Connected components via min-label propagation (symmetric graphs)."""
    return Problem(
        name="cc",
        semiring=MIN_PLUS,
        make_row_update=min_label_row_update,
        residual=count_changed_residual,
        x0=lambda g: np.arange(g.n, dtype=np.int32),
        tol=0.5,
        max_rounds=max_rounds,
        edge_values=lambda g: np.zeros(g.nnz, dtype=np.int32),
    )


def jacobi_problem(
    diag: np.ndarray, b: np.ndarray, tol: float = 1e-6, max_rounds: int = 5000
) -> Problem:
    """Jacobi/block-GS fixed point for ``A x = b``.

    The graph must carry the pull splitting ``-A_ij / A_ii`` on edge
    ``(j -> i)`` (see :func:`repro.algorithms.jacobi.jacobi_graph`).
    """
    b_over_diag = (np.asarray(b) / np.asarray(diag)).astype(np.float32)

    def make_row_update(graph):
        # b / diag gathered per row; padded slot (row == n) contributes 0.
        ext = jnp.asarray(np.concatenate([b_over_diag, [np.float32(0.0)]]))

        def row_update(old, reduced, rows):
            return _match_features(ext[rows], reduced) + reduced

        return row_update

    return Problem(
        name="jacobi",
        semiring=PLUS_TIMES,
        make_row_update=make_row_update,
        residual=l1_residual,
        x0=lambda g: np.zeros(g.n, dtype=np.float32),
        tol=tol,
        max_rounds=max_rounds,
    )


# --------------------------------------------------------------------------- #
# Matrix-frontier factories: the engine's (n, F) workloads.
# --------------------------------------------------------------------------- #
def default_landmarks(n: int, feature_dim: int) -> np.ndarray:
    """``feature_dim`` evenly spaced landmark vertices on an ``n``-vertex graph."""
    return (np.arange(int(feature_dim), dtype=np.int64) * int(n)) // int(feature_dim)


def rwr_restart(graph: CSRGraph, seeds, damping: float = 0.85) -> np.ndarray:
    """(n, F) restart-mass matrix for :func:`rwr_embedding_problem`.

    Column ``f`` carries ``(1-d)·e_{seeds[f]}`` — one personalized-PageRank
    restart distribution per landmark, stacked side by side so a single
    matrix solve computes all F proximity columns at once.
    """
    seeds = np.atleast_1d(np.asarray(seeds, dtype=np.int64))
    r = np.zeros((graph.n, seeds.shape[0]), dtype=np.float32)
    r[seeds, np.arange(seeds.shape[0])] = np.float32(1.0 - damping)
    return r


def rwr_embedding_problem(
    feature_dim: int = 4,
    damping: float = 0.85,
    tol: float = 1e-4,
    max_rounds: int = 1000,
) -> Problem:
    """Random-walk-with-restart embeddings: F restart columns, one solve.

    Each column of the ``(n, F)`` state solves personalized PageRank toward
    one landmark (``q`` is the :func:`rwr_restart` matrix), so a vertex's row
    is its F-dimensional proximity embedding.  Edge values must hold
    ``d / outdeg(src)`` exactly like :func:`pagerank_problem`.  With
    ``feature_dim=1`` and a single-seed restart column this is bit-identical
    to :func:`ppr_problem` — the cross-factory parity test.
    """
    F = int(feature_dim)

    def make_row_update(graph):
        def row_update(old, reduced, rows, q):
            return _match_features(q[rows], reduced) + reduced

        return row_update

    return Problem(
        name="rwr",
        semiring=PLUS_TIMES,
        make_row_update=make_row_update,
        residual=l1_residual,
        x0=lambda g: np.full((g.n, F), 1.0 / g.n, dtype=np.float32),
        tol=tol,
        max_rounds=max_rounds,
        takes_query=True,
        default_query=lambda g: rwr_restart(g, default_landmarks(g.n, F), damping),
        feature_dim=F,
    )


def labelprop_anchors(graph: CSRGraph, seeds) -> np.ndarray:
    """(n, F) one-hot anchor matrix: ``seeds[f]`` is clamped to class ``f``."""
    seeds = np.atleast_1d(np.asarray(seeds, dtype=np.int64))
    a = np.zeros((graph.n, seeds.shape[0]), dtype=np.float32)
    a[seeds, np.arange(seeds.shape[0])] = np.float32(1.0)
    return a


def label_propagation_problem(
    feature_dim: int = 4, mix: float = 0.9, tol: float = 1e-3, max_rounds: int = 2000
) -> Problem:
    """F-class semi-supervised label propagation with a row-normalized ⊕.

    State is an ``(n, F)`` class-membership matrix.  One commit pulls the
    plus-times segment-⊕ of neighbor rows over unit edge weights (the
    ``edge_values`` override makes propagation purely structural), then
    row-normalizes it — the "row-normalized ⊕" — so each row stays a
    distribution over classes.  Anchored rows (``q`` rows with mass, built by
    :func:`labelprop_anchors`) clamp back to their one-hot label every
    commit; rows whose in-edges are all padding keep their previous value.

    ``mix`` damps the update (``mix·prop + (1-mix)·old`` on unanchored rows)
    — the *smooth* label-propagation variant.  Undamped pull updates
    (``mix=1``) oscillate with period 2 on near-bipartite neighborhoods and
    never meet tol for some anchor placements; any ``mix < 1`` breaks the
    cycle while keeping the same fixed points.
    """
    F = int(feature_dim)
    mix = float(mix)
    if not 0.0 < mix <= 1.0:
        raise ValueError(f"mix must be in (0, 1], got {mix}")

    def make_row_update(graph):
        def row_update(old, reduced, rows, q):
            total = jnp.sum(reduced, axis=-1, keepdims=True)
            safe = jnp.where(total > 0, total, jnp.ones_like(total))
            prop = jnp.where(total > 0, mix * (reduced / safe) + (1 - mix) * old, old)
            anchor = q[rows]
            anchored = jnp.sum(anchor, axis=-1, keepdims=True) > 0
            return jnp.where(anchored, anchor, prop)

        return row_update

    return Problem(
        name="labelprop",
        semiring=PLUS_TIMES,
        make_row_update=make_row_update,
        residual=l1_residual,
        x0=lambda g: np.full((g.n, F), 1.0 / F, dtype=np.float32),
        tol=tol,
        max_rounds=max_rounds,
        edge_values=lambda g: np.ones(g.nnz, dtype=np.float32),
        takes_query=True,
        default_query=lambda g: labelprop_anchors(g, default_landmarks(g.n, F)),
        feature_dim=F,
    )
