# The unified Problem/Solver API — the single entry point everything routes
# through: problem specs, backend selection, schedule+compile caching, and
# batched multi-query solving.  See solve/README.md for the paper-term map.
from repro.solve.batch import BatchResult, solve_batch
from repro.solve.problem import (
    Problem,
    cc_problem,
    count_changed_residual,
    jacobi_problem,
    l1_residual,
    min_label_row_update,
    multi_source_x0,
    pagerank_problem,
    ppr_problem,
    ppr_teleport,
    sssp_problem,
)
from repro.solve.solver import BACKENDS, FRONTIERS, Solver, resolve_legacy_args

__all__ = [
    "BACKENDS",
    "FRONTIERS",
    "BatchResult",
    "Problem",
    "Solver",
    "cc_problem",
    "count_changed_residual",
    "jacobi_problem",
    "l1_residual",
    "min_label_row_update",
    "multi_source_x0",
    "pagerank_problem",
    "ppr_problem",
    "ppr_teleport",
    "resolve_legacy_args",
    "solve_batch",
    "sssp_problem",
]
