# The unified Problem/Solver API — the single entry point everything routes
# through: problem specs, backend selection, schedule+compile caching, and
# batched multi-query solving.  See solve/README.md for the paper-term map.
from repro.solve.batch import BatchResult, BatchStepper, RetiredQuery, solve_batch
from repro.solve.problem import (
    Problem,
    cc_problem,
    count_changed_residual,
    default_landmarks,
    jacobi_problem,
    l1_residual,
    label_propagation_problem,
    labelprop_anchors,
    min_label_row_update,
    multi_source_x0,
    pagerank_problem,
    ppr_problem,
    ppr_teleport,
    rwr_embedding_problem,
    rwr_restart,
    sssp_problem,
)
from repro.solve.solver import (
    BACKEND_FRONTIERS,
    BACKENDS,
    FRONTIERS,
    HALO_DTYPES,
    Solver,
)

# Serving-tier wire types, re-exported for callers that speak the typed
# request/response API.  Imported last: types.py is dependency-light, and by
# now every repro.solve submodule it may transitively touch is initialized.
from repro.launch.service.types import QueryRequest, QueryResult

__all__ = [
    "BACKEND_FRONTIERS",
    "BACKENDS",
    "FRONTIERS",
    "HALO_DTYPES",
    "BatchResult",
    "BatchStepper",
    "Problem",
    "QueryRequest",
    "QueryResult",
    "RetiredQuery",
    "Solver",
    "cc_problem",
    "count_changed_residual",
    "default_landmarks",
    "jacobi_problem",
    "l1_residual",
    "label_propagation_problem",
    "labelprop_anchors",
    "min_label_row_update",
    "multi_source_x0",
    "pagerank_problem",
    "ppr_problem",
    "ppr_teleport",
    "rwr_embedding_problem",
    "rwr_restart",
    "solve_batch",
    "sssp_problem",
]
