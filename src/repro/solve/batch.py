"""Batched multi-query solving: Q queries, one schedule, one lowering.

``solve_batch`` vmaps the solver's round function over a batch of initial
states (and, for query-parameterized problems, a batch of query params) and
runs one fused ``lax.while_loop`` until *every* query converges.  This is the
serving-scale scenario: multi-source SSSP or personalized PageRank answered
as a single device program against a warm schedule — no per-query stripe
builds, no per-query retraces, one commit collective per flush shared by the
whole batch.

``backend="sharded"`` vmaps the ``shard_map`` round instead of the
single-device one, so the whole batch spans the worker mesh in one lowering —
with ``frontier="halo"`` each commit moves only boundary entries while all Q
queries ride the same collectives.

Converged queries keep iterating (at their fixed point for idempotent
semirings like min-plus) until the stragglers finish; ``rounds_per_query``
records when each one first converged.  ``compact_every=k`` bounds that
straggler tax: every ``k`` rounds the unconverged subset is gathered on the
host and the loop continues on the smaller batch (one extra compile per
distinct active size); ``compact_every=None`` preserves the single fused
call bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import round_fn_pallas_q, round_fn_q
from repro.ft.inject import fire

__all__ = ["BatchResult", "BatchStepper", "RetiredQuery", "solve_batch"]


@dataclasses.dataclass
class BatchResult:
    """Result of one batched solve (Q queries sharing one schedule)."""

    x: np.ndarray  # (Q, n) or (Q, n, F) per-query converged states
    rounds: int  # rounds executed by the shared loop (= max over queries)
    rounds_per_query: np.ndarray  # (Q,) round of first convergence (0 = never)
    converged: np.ndarray  # (Q,) bool
    residuals: np.ndarray  # (Q,) final per-query residuals
    flushes: int  # schedule commits executed (shared by the batch)
    flush_bytes: int  # bytes published across the whole batch
    delta: int
    P: int
    Q: int
    compile_time_s: float = 0.0  # 0 on a warm cache
    total_time_s: float = 0.0
    compactions: int = 0  # straggler-compaction shrinks performed


def _batched_round(solver, sched, backend: str, frontier: str, feature_dims: int = 0):
    """Build ``(X_ext, qb) -> X_ext`` running one round for all Q queries.

    ``feature_dims`` is 0 for vector frontiers (``X_ext`` is ``(Q, n+1)``)
    and 1 for matrix frontiers (``(Q, n+1, F)``); the sharded builders need
    it to size their per-shard partition specs.
    """
    sr = solver.problem.semiring
    if backend == "pallas" and frontier == "halo":
        # vmapping a shard_map-of-pallas program is not supported; the
        # sharded backend runs the same halo exchange (in XLA) batched.
        raise ValueError(
            "batched halo solves use backend='sharded', frontier='halo' "
            "(backend='pallas' fuses per-shard kernels and cannot be vmapped)"
        )
    if backend in ("jit", "pallas"):
        builder = round_fn_q if backend == "jit" else round_fn_pallas_q
        return jax.vmap(builder(sched, sr, solver._row_update_q), in_axes=(0, 0))
    if backend != "sharded":
        raise ValueError(
            f"batch backend must be 'jit', 'pallas', or 'sharded': {backend!r}"
        )
    mesh = solver._default_mesh()
    if frontier == "replicated":
        from repro.dist.engine_sharded import sharded_round_fn_q

        base = sharded_round_fn_q(
            sched, sr, solver._row_update_q, mesh, axis=solver.mesh_axis,
            feature_dims=feature_dims,
        )
        vm = jax.vmap(base, in_axes=(0, None, None, None, None, 0))
        args = (sched.src, sched.val, sched.dst_local, sched.rows)
        return lambda X, qb: vm(X, *args, qb)
    from repro.dist.engine_sharded import frontier_plan_args, frontier_round_ext_fn

    plan = solver.frontier_plan(sched)
    ext = frontier_round_ext_fn(
        sched, plan, sr, solver._row_update_q, mesh, axis=solver.mesh_axis,
        feature_dims=feature_dims,
    )
    args = frontier_plan_args(sched, plan)
    vm = jax.vmap(ext, in_axes=(0, 0) + (None,) * len(args))
    return lambda X, qb: vm(X, qb, *args)


def _make_batch_solve_fn(rnd, residual_fn):
    """``(X_ext, qb, tol, max_rounds) -> carry`` over a batched round fn."""
    res_fn = jax.vmap(residual_fn, in_axes=(0, 0))

    def solve_loop(X_ext, qb, tol, max_rounds):
        def cond(carry):
            _, _, rounds, converged, _ = carry
            return jnp.logical_and(rounds < max_rounds, ~jnp.all(converged))

        def body(carry):
            X, _, rounds, converged, rpq = carry
            X_new = rnd(X, qb)
            res = res_fn(X[:, :-1], X_new[:, :-1]).astype(jnp.float32)
            # stamp only at first convergence; never-converged queries keep 0
            just_converged = jnp.logical_and(~converged, res <= tol)
            rpq = jnp.where(just_converged, rounds + 1, rpq)
            return X_new, res, rounds + 1, converged | (res <= tol), rpq

        Q = X_ext.shape[0]
        init = (
            X_ext,
            jnp.full((Q,), np.inf, jnp.float32),
            jnp.asarray(0, jnp.int32),
            jnp.zeros((Q,), bool),
            jnp.zeros((Q,), jnp.int32),
        )
        return jax.lax.while_loop(cond, body, init)

    return solve_loop


def _make_open_batch_solve_fn(rnd, residual_fn):
    """``(X_ext, qb, conv0, tol, max_rounds) -> carry`` for an *open* batch.

    Two deltas from :func:`_make_batch_solve_fn`, both load-bearing for
    continuous batching:

    * rows may start already-converged (``conv0``) — that is how empty queue
      slots ride along in a fixed-shape compiled loop without blocking the
      convergence test;
    * a row **freezes at first convergence**: once its residual crosses tol
      its state stops updating, so the value a slot retires with is exactly
      the value a fresh ``solve_batch`` of that query alone would return —
      bit-identical, regardless of how many extra rounds its batchmates need.
    """
    res_fn = jax.vmap(residual_fn, in_axes=(0, 0))

    def solve_loop(X_ext, qb, conv0, tol, max_rounds):
        def cond(carry):
            _, _, rounds, converged, _ = carry
            return jnp.logical_and(rounds < max_rounds, ~jnp.all(converged))

        def body(carry):
            X, res_prev, rounds, converged, rpq = carry
            X_new = rnd(X, qb)
            res = res_fn(X[:, :-1], X_new[:, :-1]).astype(jnp.float32)
            just_converged = jnp.logical_and(~converged, res <= tol)
            rpq = jnp.where(just_converged, rounds + 1, rpq)
            conv_b = converged.reshape(converged.shape + (1,) * (X.ndim - 1))
            X_keep = jnp.where(conv_b, X, X_new)
            res_keep = jnp.where(converged, res_prev, res)
            return X_keep, res_keep, rounds + 1, converged | (res <= tol), rpq

        Q = X_ext.shape[0]
        init = (
            X_ext,
            jnp.full((Q,), np.inf, jnp.float32),
            jnp.asarray(0, jnp.int32),
            conv0,
            jnp.zeros((Q,), jnp.int32),
        )
        return jax.lax.while_loop(cond, body, init)

    return solve_loop


@dataclasses.dataclass
class RetiredQuery:
    """One slot retired from a :class:`BatchStepper` quantum."""

    tag: object  # caller's identifier, passed through admit()
    x: np.ndarray  # (n,) or (n, F) final state (frozen at first convergence)
    rounds: int  # rounds to first convergence (total, across quanta)
    converged: bool  # False = retired on the max_rounds budget
    residual: float


class BatchStepper:
    """A fixed-capacity *open* batch: admit mid-flight, retire converged.

    This is the continuous-batching primitive under
    :mod:`repro.launch.service`.  Where :func:`solve_batch` answers one
    closed set of queries, a stepper owns ``capacity`` slots of one compiled
    loop and interleaves three operations:

    * :meth:`admit` writes a query's initial state (and query params) into a
      free slot;
    * :meth:`run` executes one scheduling quantum — at most ``quantum``
      rounds of the fused loop over **all** slots (free slots ride along
      pre-converged, so the compiled shape never changes);
    * converged slots (and slots out of round budget) retire from
      :meth:`run` as :class:`RetiredQuery` rows, freeing their slots for
      the next admissions.

    Rows are row-independent under ``vmap`` and freeze at first convergence,
    so a retired result is bit-identical to a fresh ``solve_batch`` of that
    query alone — no matter when it slotted in or who shared the batch
    (asserted in ``tests/test_serve_scheduler.py``).

    The compiled loop is cached on the solver under
    ``("batch", "open", backend, frontier, δ, capacity)`` and persists to the
    store like every other executable, so a restarted service still serves
    its first quantum with zero retraces.
    """

    def __init__(
        self,
        solver,
        capacity: int,
        *,
        delta=None,
        backend: str | None = None,
        frontier: str | None = None,
        tol=None,
        max_rounds=None,
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        backend = backend or (
            solver.default_backend if solver.default_backend != "host" else "jit"
        )
        if backend == "host":  # host rounds are not vmappable; jit is the
            backend = "jit"  # same XLA round iterated on-device
        self.solver = solver
        self.backend = backend
        self.frontier = solver.resolve_frontier(frontier, backend)
        self.sched = solver.schedule(delta)
        self.capacity = capacity
        self.tol = solver.tol if tol is None else tol
        self.max_rounds = solver.max_rounds if max_rounds is None else max_rounds
        sr = solver.problem.semiring
        self._sr = sr
        n = solver.graph.n
        # Matrix problems (feature_dim > 1) give every slot a (n+1, F) state;
        # scalar problems keep the historical (n+1,) layout bit-for-bit.
        F = getattr(solver.problem, "feature_dim", 1)
        self._feat = (F,) if F > 1 else ()
        self._X = np.full((capacity, n + 1) + self._feat, sr.zero, dtype=sr.dtype)
        if solver.problem.takes_query:
            self._qb = None  # built from the first admitted row's structure
        else:
            self._qb = np.zeros((capacity,), np.int32)
        self._occupied = np.zeros(capacity, bool)
        self._tags: list = [None] * capacity
        self._rounds_in = np.zeros(capacity, np.int64)
        self.flushes = 0
        self.flush_bytes = 0
        self.rounds_executed = 0  # cumulative, across all quanta
        self.quanta = 0
        key_tail: tuple = ()
        if backend == "sharded":
            from repro.dist.compat import mesh_axis_sizes

            key_tail = (mesh_axis_sizes(solver._default_mesh())[solver.mesh_axis],)
        fk: tuple = ("F", F) if self._feat else ()
        self._key = (
            "batch",
            "open",
            backend,
            self.frontier,
            self.sched.delta,
            capacity,
        ) + key_tail + fk
        self._portable = key_tail in ((), (1,))

    # -------------------------------------------------------------- slots #
    @property
    def occupancy(self) -> int:
        return int(self._occupied.sum())

    @property
    def free_slots(self) -> int:
        return self.capacity - self.occupancy

    def admit(self, x0, q=None, tag=None) -> int:
        """Write one query into a free slot; returns the slot index."""
        free = np.nonzero(~self._occupied)[0]
        if free.size == 0:
            raise ValueError("no free slots (retire via run() first)")
        slot = int(free[0])
        x0 = np.asarray(x0, dtype=self._sr.dtype)
        n = self.solver.graph.n
        want = (n,) + self._feat
        if x0.shape != want:
            raise ValueError(f"x0 must have shape {want}, got {x0.shape}")
        self._X[slot, :n] = x0
        self._X[slot, n] = self._sr.zero
        if self.solver.problem.takes_query:
            if q is None:
                raise ValueError(
                    f"problem {self.solver.problem.name!r} needs a per-row q="
                )
            if self._qb is None:
                self._qb = jax.tree_util.tree_map(
                    lambda leaf: np.zeros(
                        (self.capacity,) + np.shape(leaf), np.asarray(leaf).dtype
                    ),
                    q,
                )
            leaves_b, leaves_q = (
                jax.tree_util.tree_leaves(self._qb),
                jax.tree_util.tree_leaves(q),
            )
            for dst, row in zip(leaves_b, leaves_q):
                dst[slot] = row
        elif q is not None:
            raise ValueError(f"problem {self.solver.problem.name!r} takes no query")
        self._occupied[slot] = True
        self._tags[slot] = tag
        self._rounds_in[slot] = 0
        return slot

    # ---------------------------------------------------------------- run #
    def _compiled_loop(self, X_ext, qb, conv0, tol_a, rounds_a):
        return self.solver.compile_cached(
            self._key,
            _make_open_batch_solve_fn(
                _batched_round(
                    self.solver, self.sched, self.backend, self.frontier,
                    feature_dims=len(self._feat),
                ),
                self.solver.problem.residual,
            ),
            X_ext,
            qb,
            conv0,
            tol_a,
            rounds_a,
            portable=self._portable,
        )

    def evict_all(self) -> list:
        """Clear every occupied slot and return their tags (fault recovery).

        After a faulted quantum the batch state is suspect; the scheduler
        evicts the riders (requeueing them for retry elsewhere) and drops the
        lane.  The stepper itself is left empty but reusable.
        """
        tags = [self._tags[slot] for slot in np.nonzero(self._occupied)[0]]
        self._occupied[:] = False
        self._tags = [None] * self.capacity
        return tags

    def run(self, quantum: int) -> list[RetiredQuery]:
        """One scheduling quantum: at most ``quantum`` rounds, then retire.

        Returns the slots that finished this quantum (first convergence, or
        the ``max_rounds`` budget exhausted — at quantum granularity).  No-op
        on an empty batch.
        """
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        occ = self._occupied
        if not occ.any():
            return []
        # chaos hook before any state mutates: a kernel fault here leaves the
        # stepper untouched, so the scheduler can evict + retry its riders
        fire("kernel.dispatch", backend=self.backend, frontier=self.frontier)
        sr = self._sr
        t0 = time.perf_counter()
        X_ext = jnp.asarray(self._X)
        qb = jax.tree_util.tree_map(jnp.asarray, self._qb)
        conv0 = jnp.asarray(~occ)
        tol_a = jnp.asarray(self.tol, jnp.float32)
        rounds_a = jnp.asarray(quantum, jnp.int32)
        fn = self._compiled_loop(X_ext, qb, conv0, tol_a, rounds_a)
        X_new, res, r, conv, rpq = fn(X_ext, qb, conv0, tol_a, rounds_a)
        X_new.block_until_ready()
        r = int(r)
        # np.array (copy), not np.asarray: device buffers are read-only and
        # the next admit() writes into this array in place
        self._X = np.array(X_new)
        conv_np, res_np, rpq_np = np.asarray(conv), np.asarray(res), np.asarray(rpq)
        before = self._rounds_in.copy()
        self._rounds_in[occ] += r
        self.rounds_executed += r
        self.quanta += 1
        self.flushes += r * self.sched.S
        F = int(np.prod(self._feat, dtype=np.int64)) if self._feat else 1
        bytes_per = np.dtype(sr.dtype).itemsize * F
        per_round = self.sched.S * self.sched.P * self.sched.delta * bytes_per
        self.flush_bytes += r * per_round * self.capacity
        n = self.solver.graph.n
        retired: list[RetiredQuery] = []
        for slot in np.nonzero(occ)[0]:
            done = bool(conv_np[slot])
            if not done and self._rounds_in[slot] < self.max_rounds:
                continue
            if done:
                rounds = int(before[slot] + rpq_np[slot])
            else:
                rounds = int(self._rounds_in[slot])
            retired.append(
                RetiredQuery(
                    tag=self._tags[slot],
                    x=self._X[slot, :n].copy(),
                    rounds=rounds,
                    converged=done,
                    residual=float(res_np[slot]),
                )
            )
            self._occupied[slot] = False
            self._tags[slot] = None
        self.solver.stats["solves"] += len(retired)
        finished = [q.rounds for q in retired if q.converged]
        if finished:
            # one (δ, rounds) datapoint per quantum-with-retirees, max over
            # the finishers — same conservative convention as solve_batch
            self.solver._record_observation(
                self.sched.delta,
                max(finished),
                time.perf_counter() - t0,
                self.backend,
                kind="batch",
            )
        return retired


def solve_batch(
    solver,
    x0_batch,
    *,
    q=None,
    delta=None,
    backend: str | None = None,
    frontier: str | None = None,
    tol=None,
    max_rounds=None,
    compact_every: int | None = None,
) -> BatchResult:
    """Solve Q queries of ``solver.problem`` in one compiled device loop.

    * ``x0_batch``      — (Q, n) initial states (e.g. :func:`multi_source_x0`),
      or (Q, n, F) for matrix-frontier problems (e.g. batched RWR embeddings).
    * ``q``             — for query problems, a pytree whose leaves have a
      leading Q axis (e.g. :func:`ppr_teleport`); must be ``None`` otherwise.
    * ``backend``       — ``"jit"`` (default: vmapped single-device round),
      ``"pallas"`` (vmapped fused one-kernel round — the whole batch shares
      the VMEM-resident commit pipeline), or ``"sharded"`` (vmapped
      ``shard_map`` round spanning the worker mesh); ``frontier`` picks
      replicated vs halo for the sharded round.
    * ``compact_every`` — shrink the active batch to the unconverged subset
      every this many rounds (straggler-aware batching); ``None`` runs one
      fused loop until the slowest query converges, bit-for-bit as before.

    ``solve_batch`` with ``Q == 1`` is bit-identical to the unbatched
    ``backend="jit"`` path: same round function, same residual rule, same
    stopping round.  The compiled loop is cached on the solver keyed by
    ``(backend, frontier, δ, Q)``; repeated batches of the same shape never
    retrace.
    """
    problem = solver.problem
    sr = problem.semiring
    backend = backend or (
        solver.default_backend if solver.default_backend != "host" else "jit"
    )
    frontier = solver.resolve_frontier(frontier, backend)
    sched = solver.schedule(delta)
    tol = solver.tol if tol is None else tol
    max_rounds = solver.max_rounds if max_rounds is None else max_rounds
    if compact_every is not None and compact_every < 1:
        raise ValueError(f"compact_every must be >= 1, got {compact_every}")

    X = jnp.asarray(x0_batch, dtype=sr.dtype)
    if X.ndim not in (2, 3) or X.shape[1] != solver.graph.n:
        raise ValueError(
            f"x0_batch must be (Q, {solver.graph.n}) or "
            f"(Q, {solver.graph.n}, F), got {X.shape}"
        )
    Q = X.shape[0]
    feat = X.shape[2:]
    F = int(np.prod(feat, dtype=np.int64)) if feat else 1
    fk: tuple = ("F", F) if feat else ()
    X_ext = jnp.concatenate(
        [X, jnp.full((Q, 1) + feat, sr.zero, dtype=sr.dtype)], axis=1
    )

    if problem.takes_query:
        if q is None:
            raise ValueError(f"problem {problem.name!r} needs a batched q=")
        qb = jax.tree_util.tree_map(jnp.asarray, q)
        lead = jax.tree_util.tree_leaves(qb)[0].shape[0]
        if lead != Q:
            raise ValueError(f"q leading axis {lead} != Q {Q}")
    else:
        if q is not None:
            raise ValueError(f"problem {problem.name!r} takes no query")
        qb = jnp.zeros((Q,), jnp.int32)

    tol_a = jnp.asarray(tol, jnp.float32)
    bytes_per = np.dtype(sr.dtype).itemsize * F

    # Sharded loops are additionally keyed by mesh width: a persisted
    # executable exported by a 1-device process must never satisfy an
    # 8-device one (single-device exports are the only ones persisted).
    key_tail: tuple = ()
    if backend == "sharded":
        from repro.dist.compat import mesh_axis_sizes

        key_tail = (mesh_axis_sizes(solver._default_mesh())[solver.mesh_axis],)

    def compiled_loop(X_cur, qb_cur):
        """The fused loop for the current active size (cached per size)."""
        return solver.compile_cached(
            ("batch", backend, frontier, sched.delta, X_cur.shape[0])
            + key_tail
            + fk,
            _make_batch_solve_fn(
                _batched_round(solver, sched, backend, frontier, len(feat)),
                problem.residual,
            ),
            X_cur,
            qb_cur,
            tol_a,
            jnp.asarray(max_rounds, jnp.int32),
            # a >1-device shard_map export pins its device assignment and
            # could never load — skip the store instead of exporting to waste
            portable=key_tail in ((), (1,)),
        )

    solver.stats["solves"] += 1
    x_out = np.empty((Q, solver.graph.n) + feat, dtype=sr.dtype)
    rpq_all = np.zeros(Q, np.int32)
    conv_all = np.zeros(Q, bool)
    res_all = np.full(Q, np.inf, np.float32)
    active = np.arange(Q)
    rounds_done = 0
    flushes = 0
    flush_bytes = 0
    compile_time_s = 0.0
    compactions = 0
    t0 = time.perf_counter()
    while active.size:
        chunk = max_rounds - rounds_done
        if compact_every is not None:
            chunk = min(chunk, compact_every)
        fn = compiled_loop(X_ext, qb)
        compile_time_s += solver._last_compile_s
        X_new, res, r, conv, rpq = fn(X_ext, qb, tol_a, jnp.asarray(chunk, jnp.int32))
        X_new.block_until_ready()
        r = int(r)
        rounds_done += r
        flushes += r * sched.S
        flush_bytes += r * sched.S * sched.P * sched.delta * bytes_per * active.size
        conv_np = np.asarray(conv)
        rpq_np = np.asarray(rpq)
        rpq_all[active] = np.where(rpq_np > 0, rounds_done - r + rpq_np, 0)
        conv_all[active] = conv_np
        res_all[active] = np.asarray(res)
        if conv_np.all() or rounds_done >= max_rounds:
            x_out[active] = np.asarray(X_new[:, :-1])
            break
        # Straggler compaction: keep only converged rows' states on the host
        # (their final answers) and continue on the unconverged subset.
        if conv_np.any():
            done = jnp.asarray(np.nonzero(conv_np)[0])
            x_out[active[conv_np]] = np.asarray(X_new[done, :-1])
            keep = jnp.asarray(np.nonzero(~conv_np)[0])
            active = active[~conv_np]
            X_new = X_new[keep]
            qb = jax.tree_util.tree_map(lambda a: a[keep], qb)
            compactions += 1
        X_ext = X_new
    total = time.perf_counter() - t0

    # Batch rounds are max-over-queries (tagged "batch" so the refit can tell)
    # — routed through the solver so served traffic advances reprobe_every's
    # counter: in a serving process, batches ARE the production observations.
    solver._record_observation(
        sched.delta, rounds_done, total, backend, kind="batch"
    )

    return BatchResult(
        x=x_out,
        rounds=rounds_done,
        rounds_per_query=rpq_all,
        converged=conv_all,
        residuals=res_all,
        flushes=flushes,
        flush_bytes=flush_bytes,
        delta=sched.delta,
        P=sched.P,
        Q=Q,
        compile_time_s=compile_time_s,
        total_time_s=total,
        compactions=compactions,
    )
