"""Batched multi-query solving: Q queries, one schedule, one lowering.

``solve_batch`` vmaps the solver's round function over a batch of initial
states (and, for query-parameterized problems, a batch of query params) and
runs one fused ``lax.while_loop`` until *every* query converges.  This is the
serving-scale scenario: multi-source SSSP or personalized PageRank answered
as a single device program against a warm schedule — no per-query stripe
builds, no per-query retraces, one commit collective per flush shared by the
whole batch.

Converged queries keep iterating (at their fixed point for idempotent
semirings like min-plus) until the stragglers finish; ``rounds_per_query``
records when each one first converged.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import round_fn_q

__all__ = ["BatchResult", "solve_batch"]


@dataclasses.dataclass
class BatchResult:
    """Result of one batched solve (Q queries sharing one schedule)."""

    x: np.ndarray  # (Q, n) per-query converged states
    rounds: int  # rounds executed by the shared loop (= max over queries)
    rounds_per_query: np.ndarray  # (Q,) round of first convergence (0 = never)
    converged: np.ndarray  # (Q,) bool
    residuals: np.ndarray  # (Q,) final per-query residuals
    flushes: int  # schedule commits executed (shared by the batch)
    flush_bytes: int  # bytes published across the whole batch
    delta: int
    P: int
    Q: int
    compile_time_s: float = 0.0  # 0 on a warm cache
    total_time_s: float = 0.0


def _make_batch_solve_fn(sched, semiring, row_update_q, residual_fn):
    """``(X_ext, Q, tol, max_rounds) -> carry`` running all queries together."""
    rnd = jax.vmap(round_fn_q(sched, semiring, row_update_q), in_axes=(0, 0))
    res_fn = jax.vmap(residual_fn, in_axes=(0, 0))

    def solve_loop(X_ext, q, tol, max_rounds):
        def cond(carry):
            _, _, rounds, converged, _ = carry
            return jnp.logical_and(rounds < max_rounds, ~jnp.all(converged))

        def body(carry):
            X, _, rounds, converged, rpq = carry
            X_new = rnd(X, q)
            res = res_fn(X[:, :-1], X_new[:, :-1]).astype(jnp.float32)
            # stamp only at first convergence; never-converged queries keep 0
            just_converged = jnp.logical_and(~converged, res <= tol)
            rpq = jnp.where(just_converged, rounds + 1, rpq)
            return X_new, res, rounds + 1, converged | (res <= tol), rpq

        Q = X_ext.shape[0]
        init = (
            X_ext,
            jnp.full((Q,), np.inf, jnp.float32),
            jnp.asarray(0, jnp.int32),
            jnp.zeros((Q,), bool),
            jnp.zeros((Q,), jnp.int32),
        )
        return jax.lax.while_loop(cond, body, init)

    return solve_loop


def solve_batch(
    solver, x0_batch, *, q=None, delta=None, tol=None, max_rounds=None
) -> BatchResult:
    """Solve Q queries of ``solver.problem`` in one compiled device loop.

    * ``x0_batch`` — (Q, n) initial states (e.g. :func:`multi_source_x0`).
    * ``q``        — for query problems, a pytree whose leaves have a leading
      Q axis (e.g. :func:`ppr_teleport`); must be ``None`` otherwise.

    ``solve_batch`` with ``Q == 1`` is bit-identical to the unbatched
    ``backend="jit"`` path: same round function, same residual rule, same
    stopping round.  The compiled loop is cached on the solver keyed by
    ``(δ, Q)``; repeated batches of the same shape never retrace.
    """
    problem = solver.problem
    sr = problem.semiring
    sched = solver.schedule(delta)
    tol = solver.tol if tol is None else tol
    max_rounds = solver.max_rounds if max_rounds is None else max_rounds

    X = jnp.asarray(x0_batch, dtype=sr.dtype)
    if X.ndim != 2 or X.shape[1] != solver.graph.n:
        raise ValueError(f"x0_batch must be (Q, {solver.graph.n}), got {X.shape}")
    Q = X.shape[0]
    X_ext = jnp.concatenate([X, jnp.full((Q, 1), sr.zero, dtype=sr.dtype)], axis=1)

    if problem.takes_query:
        if q is None:
            raise ValueError(f"problem {problem.name!r} needs a batched q=")
        qb = jax.tree_util.tree_map(jnp.asarray, q)
        lead = jax.tree_util.tree_leaves(qb)[0].shape[0]
        if lead != Q:
            raise ValueError(f"q leading axis {lead} != Q {Q}")
    else:
        if q is not None:
            raise ValueError(f"problem {problem.name!r} takes no query")
        qb = jnp.zeros((Q,), jnp.int32)

    tol_a = jnp.asarray(tol, jnp.float32)
    mr_a = jnp.asarray(max_rounds, jnp.int32)
    fn = solver.compile_cached(
        ("batch", sched.delta, Q),
        _make_batch_solve_fn(sched, sr, solver._row_update_q, problem.residual),
        X_ext,
        qb,
        tol_a,
        mr_a,
    )
    compile_time_s = solver._last_compile_s
    solver.stats["solves"] += 1
    t0 = time.perf_counter()
    X_out, res, rounds, converged, rpq = fn(X_ext, qb, tol_a, mr_a)
    X_out.block_until_ready()
    total = time.perf_counter() - t0

    rounds = int(rounds)
    bytes_per = np.dtype(sr.dtype).itemsize
    flushes = rounds * sched.S
    return BatchResult(
        x=np.asarray(X_out[:, :-1]),
        rounds=rounds,
        rounds_per_query=np.asarray(rpq),
        converged=np.asarray(converged),
        residuals=np.asarray(res),
        flushes=flushes,
        flush_bytes=flushes * sched.P * sched.delta * bytes_per * Q,
        delta=sched.delta,
        P=sched.P,
        Q=Q,
        compile_time_s=compile_time_s,
        total_time_s=total,
    )
