"""The :class:`Solver` — one entry point over every backend, frontier, and δ.

A solver binds ``(graph, problem, n_workers)`` and owns two caches:

* **schedule cache** — :class:`DeviceSchedule` per resolved δ, so repeated
  queries never rebuild stripes;
* **compile cache**  — AOT-compiled round / fused-loop executables per
  ``(backend, frontier, δ)``, so repeated queries never retrace.

``delta`` accepts the paper's three disciplines by name (``"sync"``,
``"async"``), an explicit integer (``"delayed"``), or ``"auto"``, which probes
the sync/async round counts and asks the analytic δ cost model
(:mod:`repro.core.delta_model`) for δ*.  ``backend`` selects host-driven
rounds (instrumented, per-round residuals), the fused ``lax.while_loop``
device path (``"jit"`` iterates the XLA round; ``"pallas"`` iterates the
one-kernel fused round from :mod:`repro.kernels.round_block`, which keeps
the frontier VMEM-resident across all S commit steps), or the ``shard_map``
multi-device engine from :mod:`repro.dist.engine_sharded`; ``frontier``
selects between the replicated frontier (exactness-first, O(P·δ) wire per
commit) and the owner-computes sharded frontier with halo exchange
(O(boundary) wire, graphs larger than one device).  Valid combinations are
the table :data:`BACKEND_FRONTIERS`; the fastest multi-device path is
``backend="pallas", frontier="halo"`` — per-shard fused kernels under
``shard_map`` — optionally with ``halo_dtype ∈ {"f32", "int8", "fp8"}``
shrinking the per-commit halo wire ~4× via error-feedback quantization.
"""

from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.delta_model import fit_delta_model, refit_delta_models
from repro.core.engine import (
    MIN_CHUNK,
    DeviceSchedule,
    EngineResult,
    execute_solve_fn,
    extend_frontier,
    host_loop,
    make_schedule,
    make_solve_fn_q,
    make_solve_fn_q_dyn,
    round_fn_pallas_q,
    round_fn_q,
    round_fn_q_dyn,
    schedule_args,
)
from repro.ft.degrade import Degradation, degradation_ladder
from repro.ft.inject import fire
from repro.graphs.formats import (
    CSRGraph,
    assemble_stripe_schedule,
    build_worker_stripe,
)
from repro.graphs.partition import PARTITION_METHODS, Partition
from repro.solve.problem import Problem

__all__ = ["Solver", "BACKENDS", "BACKEND_FRONTIERS", "FRONTIERS", "HALO_DTYPES"]

BACKENDS = ("host", "jit", "pallas", "sharded")
FRONTIERS = ("replicated", "halo")

#: The single source of truth for which frontier each backend supports.
#: host/jit iterate single-device rounds and never shard the frontier;
#: pallas runs halo via per-shard fused kernels under shard_map; sharded
#: runs either discipline in plain XLA.
BACKEND_FRONTIERS = {
    "host": ("replicated",),
    "jit": ("replicated",),
    "pallas": ("replicated", "halo"),
    "sharded": ("replicated", "halo"),
}

#: Wire dtypes for the fused halo exchange (pallas + halo only).
HALO_DTYPES = ("f32", "int8", "fp8")

# Round builders for the two fused-loop backends: same while-loop, same
# convergence/residual/counter semantics — only the round implementation
# differs (XLA commit steps vs the one-kernel VMEM-resident round).
_FUSED_ROUND_BUILDERS = {"jit": round_fn_q, "pallas": round_fn_pallas_q}

_NO_QUERY = np.zeros((), dtype=np.int32)  # dummy q for query-free problems


class Solver:
    """Reusable solver for one ``(graph, problem)`` pair.

    ``solve()`` answers a query; ``delta=`` / ``backend=`` / ``frontier=``
    per call override the construction defaults.  All schedules, halo plans,
    and compiled executables are cached on the instance — a second ``solve()``
    with the same ``(δ, backend, frontier)`` performs zero schedule builds and
    zero retraces (see ``stats``).

    ``cache_dir=`` extends both caches across *processes*: schedules, halo
    plans, the fitted δ-model, and AOT-exported executables persist to a
    content-addressed store (:mod:`repro.persist`), so a second process
    pointed at the same directory constructs warm — zero stripe builds, zero
    retraces, results bit-identical to cold.  Every solve also logs its
    ``(δ, rounds, time)`` to the store; ``reprobe_every=N`` refits the
    δ-model from those observations every N solves and migrates
    ``delta="auto"`` to the new δ* (see :meth:`reprobe_delta`).
    """

    def __init__(
        self,
        graph: CSRGraph,
        problem: Problem,
        n_workers: int = 8,
        delta="auto",
        backend: str = "jit",
        frontier: str = "replicated",
        halo_dtype: str = "f32",
        partition_method: str = "balanced",
        min_chunk: int = MIN_CHUNK,
        mesh=None,
        mesh_axis: str = "data",
        tol: float | None = None,
        max_rounds: int | None = None,
        cache_dir=None,
        reprobe_every: int | None = None,
        degrade: bool = False,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        self._check_frontier(frontier)
        self._check_halo_dtype(halo_dtype)
        if partition_method not in PARTITION_METHODS:
            raise ValueError(
                f"partition_method must be one of {sorted(PARTITION_METHODS)}, "
                f"got {partition_method!r}"
            )
        self._check_delta(delta)
        self.graph = graph
        self.problem = problem
        self.n_workers = n_workers
        self.default_delta = delta
        self.default_backend = backend
        self.default_frontier = frontier
        self.default_halo_dtype = halo_dtype
        self.partition_method = partition_method
        self.min_chunk = min_chunk
        self.mesh_axis = mesh_axis
        self.tol = problem.tol if tol is None else tol
        self.max_rounds = problem.max_rounds if max_rounds is None else max_rounds
        # degrade=True climbs down repro.ft.degrade.degradation_ladder on
        # kernel/backend faults instead of raising; off by default so tests
        # and benchmarks never mask a real bug behind a silent fallback.
        self.degrade = degrade
        self.degradations: list[Degradation] = []
        self.delta_model = None  # set by the first δ="auto" probe
        self.delta_model_incremental = None  # per-regime fit (evolving graphs)

        self._mesh = mesh
        sr = problem.semiring
        self._sched_graph = (
            graph.with_values(problem.edge_values(graph))
            if problem.edge_values is not None
            else graph
        )
        self._row_update = problem.make_row_update(graph)
        if problem.takes_query:
            self._row_update_q = self._row_update
        else:
            base = self._row_update

            def _row_update_q(old, reduced, rows, q):
                return base(old, reduced, rows)

            self._row_update_q = _row_update_q
        self._bounds = None
        self._partition = None
        self._auto_delta = None
        self._auto_delta_incremental = None
        self._schedules: dict[int, DeviceSchedule] = {}
        self._plans: dict[tuple, object] = {}
        self._compiled: dict[tuple, object] = {}
        self._last_compile_s = 0.0
        self._last_x = None  # fixed point of the most recent solve (host copy)
        self._last_report = None  # UpdateReport of the most recent apply_updates
        self.stats = {
            "solves": 0,
            "schedule_builds": 0,
            "plan_builds": 0,
            "stripe_builds": 0,
            "stripe_loads": 0,
            "plan_shard_builds": 0,
            "plan_shard_loads": 0,
            "traces": 0,
            "compiles": 0,
            "compile_time_s": 0.0,
            "cache_loads": 0,
            "degradations": 0,
        }
        self.reprobe_every = reprobe_every
        self._obs_since_refit = 0
        self._reprobing = False
        self._cache_dir = cache_dir
        if problem.takes_query:
            self._q_template = (
                problem.default_query(graph)
                if problem.default_query is not None
                else np.zeros((graph.n,), dtype=sr.dtype)
            )
        else:
            self._q_template = _NO_QUERY
        self.persist = None
        if cache_dir is not None:
            self.persist = self._make_persist()
            self._warm_from_persist()

    def _make_persist(self):
        """The content-addressed store namespace for the *current* graph."""
        from repro.persist import SolverCache

        return SolverCache.for_solver(
            self._cache_dir,
            self._sched_graph,
            self.problem,
            self._row_update_q,
            self._q_template,
            self.n_workers,
            self.partition_method,
            self.min_chunk,
            self.tol,
            self.max_rounds,
        )

    def _warm_from_persist(self):
        """Load the δ-model eagerly — the one entry with no lazy fallback.

        ``delta="auto"`` then resolves to the persisted (possibly migrated)
        δ* without running a single probe solve.  Schedules, halo plans, and
        executables stay lazy: :meth:`schedule`, :meth:`frontier_plan`, and
        :meth:`compile_cached` each consult the store on an in-memory miss,
        so a warm process deserializes only the δ it actually serves (the
        probe-δ schedules on disk never cost startup time or device memory).
        """
        loaded = self.persist.load_delta_model()
        if loaded is not None:
            self.delta_model, best = loaded
            self._auto_delta = int(min(best, self.block_size))
            self.stats["cache_loads"] += 1
        loaded_inc = self.persist.load_delta_model(regime="incremental")
        if loaded_inc is not None:
            self.delta_model_incremental, best_inc = loaded_inc
            self._auto_delta_incremental = int(min(best_inc, self.block_size))

    # ------------------------------------------------------------------ #
    # δ resolution + schedule/plan caches
    # ------------------------------------------------------------------ #
    @property
    def bounds(self) -> np.ndarray:
        """The (P + 1,) contiguous block bounds of ``partition_method``."""
        if self._bounds is None:
            self._bounds = PARTITION_METHODS[self.partition_method](
                self._sched_graph, self.n_workers
            )
        return self._bounds

    @property
    def block_size(self) -> int:
        """Max worker block size B — the sync δ and the upper clamp."""
        return int(np.diff(self.bounds).max())

    def partition(self) -> Partition:
        """The cached :class:`Partition` (owner map, halo sets, edge cut)."""
        if self._partition is None:
            self._partition = Partition.from_bounds(self._sched_graph, self.bounds)
        return self._partition

    @staticmethod
    def _check_delta(delta):
        if isinstance(delta, str) and delta not in ("sync", "async", "auto"):
            raise ValueError(
                f"delta must be 'sync', 'async', 'auto', or an int, got {delta!r}"
            )

    @staticmethod
    def _check_frontier(frontier):
        if frontier not in FRONTIERS:
            raise ValueError(f"frontier must be one of {FRONTIERS}, got {frontier!r}")

    @staticmethod
    def _check_halo_dtype(halo_dtype):
        if halo_dtype not in HALO_DTYPES:
            raise ValueError(
                f"halo_dtype must be one of {HALO_DTYPES}, got {halo_dtype!r}"
            )

    def resolve_delta(self, delta=None) -> int:
        """Normalize ``delta ∈ {None, 'sync', 'async', 'auto', int}`` to rows."""
        if delta is None:
            delta = self.default_delta
        self._check_delta(delta)
        B = self.block_size
        if delta == "sync":
            return B
        if delta == "async":
            return min(self.min_chunk, B)
        if delta == "auto":
            if self._auto_delta is None:
                self._auto_delta = self._probe_auto_delta()
            return self._auto_delta
        return int(min(max(int(delta), 1), B))

    def resolve_frontier(self, frontier=None, backend: str | None = None) -> str:
        """Normalize the frontier knob against :data:`BACKEND_FRONTIERS`.

        An *explicit* ``frontier`` a backend does not support is an error
        naming the backends that do; an unsupported construction default
        silently falls back to ``"replicated"`` (every backend's first entry)
        so δ="auto" host probes keep working on halo solvers.
        """
        explicit = frontier is not None
        if frontier is None:
            frontier = self.default_frontier
        self._check_frontier(frontier)
        if backend is not None and frontier not in BACKEND_FRONTIERS[backend]:
            if explicit:
                supported = [
                    b for b in reversed(BACKENDS) if frontier in BACKEND_FRONTIERS[b]
                ]
                wants = " or ".join(f"backend={b!r}" for b in supported)
                raise ValueError(
                    f"frontier={frontier!r} requires {wants}, got {backend!r}"
                )
            return "replicated"
        return frontier

    def resolve_halo_dtype(
        self, halo_dtype=None, backend: str | None = None, frontier: str | None = None
    ) -> str:
        """Normalize the halo wire dtype; quantization is pallas+halo only.

        The quantized exchange lives in the fused halo round, so an
        *explicit* low-precision ``halo_dtype`` on any other (backend,
        frontier) pair is an error; a low-precision construction default
        silently resolves to ``"f32"`` there (exact paths stay exact).
        """
        explicit = halo_dtype is not None
        if halo_dtype is None:
            halo_dtype = self.default_halo_dtype
        self._check_halo_dtype(halo_dtype)
        if halo_dtype != "f32" and not (backend == "pallas" and frontier == "halo"):
            if explicit:
                raise ValueError(
                    f"halo_dtype={halo_dtype!r} requires backend='pallas', "
                    f"frontier='halo'; got backend={backend!r}, "
                    f"frontier={frontier!r}"
                )
            return "f32"
        return halo_dtype

    def _probe_auto_delta(self) -> int:
        """Fit the δ cost model from two measured probes (sync + finest δ)."""
        r_sync = self.solve(delta="sync", backend="host")
        r_async = self.solve(delta="async", backend="host")
        self.delta_model = fit_delta_model(
            self._sched_graph,
            self.n_workers,
            r_sync.rounds,
            r_async.rounds,
            delta_min=min(self.min_chunk, self.block_size),
            bytes_per_elem=np.dtype(self.problem.semiring.dtype).itemsize,
        )
        best = min(self.delta_model.best_delta(), self.block_size)
        if self.persist is not None:
            self.persist.save_delta_model(self.delta_model, best)
        return best

    def reprobe_delta(self) -> tuple[int, int]:
        """Refit the δ-model from logged observations and migrate δ*.

        Pulls every production ``(δ, rounds)`` datapoint accumulated in the
        persistent store — unbatched solves and batched ones alike (batch
        round counts are max-over-queries, a conservative upper bound that
        still orders δ correctly, and in a serving process they are the only
        traffic there is) — refits via
        :func:`repro.core.delta_model.refit_delta_model`, and repoints
        ``delta="auto"`` at the new δ*.  Nothing is dropped:
        schedules and compiled executables are keyed by *numeric* δ, so the
        old δ*'s entries (and any explicit-δ neighbors) stay warm in memory
        and on disk — migration only changes what ``"auto"`` resolves to.
        Returns ``(old_delta_star, new_delta_star)``.
        """
        if self.persist is None:
            raise ValueError("reprobe_delta requires a Solver(cache_dir=...)")
        self._reprobing = True
        try:
            old = self.resolve_delta("auto")  # probes or loads the base model
            obs = self.persist.load_observations()
            models = refit_delta_models(self.delta_model, obs)
            self.delta_model = models.get("cold", self.delta_model)
            new = int(min(self.delta_model.best_delta(), self.block_size))
            self._auto_delta = new
            self._obs_since_refit = 0
            self.persist.save_delta_model(self.delta_model, new)
            if "incremental" in models:
                inc = models["incremental"]
                self.delta_model_incremental = inc
                inc_best = int(min(inc.best_delta(), self.block_size))
                self._auto_delta_incremental = inc_best
                self.persist.save_delta_model(inc, inc_best, regime="incremental")
            return old, new
        finally:
            self._reprobing = False

    def _record_observation(
        self, delta: int, rounds: int, total_time_s: float, backend: str,
        kind: str = "solve", regime: str = "cold",
    ):
        """Log one observed (δ, rounds, time); maybe trigger a refit."""
        if self.persist is None:
            return
        self.persist.record_observation(
            delta, rounds, total_time_s, backend=backend, kind=kind, regime=regime
        )
        self._obs_since_refit += 1
        if (
            self.reprobe_every is not None
            and self.default_delta == "auto"
            and self._obs_since_refit >= self.reprobe_every
            # never recurse out of the δ="auto" probe solves (no fitted model
            # yet) or out of a refit already in flight
            and self._auto_delta is not None
            and not self._reprobing
        ):
            self.reprobe_delta()

    def schedule(self, delta=None) -> DeviceSchedule:
        """The cached device schedule for ``delta`` (build on first use).

        Resolution order: in-memory → whole-schedule npz → **per-worker
        stripes** from the shared content-addressed store (evolving-graph
        path: after a mutation the namespace changes, so the whole-schedule
        entry misses, but every stripe whose block the batch didn't touch
        still hits by content digest — only the touched stripes build cold).
        ``schedule_builds`` counts schedules with ≥ 1 cold stripe, preserving
        the warm-start gate's "zero builds" meaning; ``stripe_builds`` /
        ``stripe_loads`` break the same event down per worker.
        """
        delta_eff = self.resolve_delta(delta)
        sched = self._schedules.get(delta_eff)
        if sched is None and self.persist is not None:
            sched = self.persist.load_schedule(delta_eff)
            if sched is not None:
                self._schedules[delta_eff] = sched
                self.stats["cache_loads"] += 1
        if sched is None and self.persist is not None:
            sched = self._schedule_from_stripes(delta_eff)
        if sched is None:
            sched = make_schedule(
                self._sched_graph,
                self.n_workers,
                delta_eff,
                self.problem.semiring,
                mode="delayed",
                min_chunk=self.min_chunk,
                bounds=self.bounds,
            )
            self._schedules[delta_eff] = sched
            self.stats["schedule_builds"] += 1
            if self.persist is not None:
                self.persist.save_schedule(sched)
        return sched

    def _schedule_from_stripes(self, delta_eff: int) -> DeviceSchedule:
        """Assemble the schedule stripe-by-stripe through the shared store."""
        from repro.persist.keys import stripe_fingerprint

        bounds = self.bounds
        pad_val = self.problem.semiring.pad_edge_val
        B = self.block_size
        delta_eff = int(min(delta_eff, B))
        S = -(-B // delta_eff)  # ceil — same clamp as build_stripe_schedule
        stripes, built = [], 0
        for w in range(self.n_workers):
            lo, hi = int(bounds[w]), int(bounds[w + 1])
            digest = stripe_fingerprint(
                self._sched_graph, lo, hi, S, delta_eff, pad_val
            )
            stripe = self.persist.load_stripe(digest)
            if stripe is None:
                stripe = build_worker_stripe(
                    self._sched_graph, lo, hi, S, delta_eff, pad_val
                )
                self.persist.save_stripe(digest, stripe)
                self.stats["stripe_builds"] += 1
                built += 1
            else:
                self.stats["stripe_loads"] += 1
            stripes.append(stripe)
        host = assemble_stripe_schedule(
            self._sched_graph, bounds, delta_eff, pad_val, stripes
        )
        sched = DeviceSchedule(
            n=host.n,
            P=host.P,
            delta=host.delta,
            S=host.S,
            M=host.M,
            src=jnp.asarray(host.src),
            val=jnp.asarray(host.val),
            dst_local=jnp.asarray(host.dst_local),
            rows=jnp.asarray(host.rows),
            edges=host.edges,
            padding_overhead=host.padding_overhead,
            block_bounds=np.asarray(host.block_bounds),
        )
        self._schedules[delta_eff] = sched
        if built:
            self.stats["schedule_builds"] += 1
        else:
            self.stats["cache_loads"] += 1
        self.persist.save_schedule(sched)
        return sched

    def frontier_plan(self, sched: DeviceSchedule):
        """The cached owner-computes halo plan for ``sched`` on this mesh.

        Mirrors :meth:`schedule`'s tiers: in-memory → whole-plan npz →
        per-shard pieces from the shared content-addressed store (only the
        shards whose workers a mutation touched rebuild; the global assembly
        — exchange indices, gather maps — is recomputed cheaply either way).
        ``plan_builds`` counts plans with ≥ 1 cold shard.
        """
        from repro.dist.compat import mesh_axis_sizes
        from repro.dist.engine_sharded import (
            assemble_frontier_plan,
            build_plan_shard,
            make_frontier_plan,
            plan_shard_bounds,
        )

        D = mesh_axis_sizes(self._default_mesh())[self.mesh_axis]
        key = (sched.delta, D)
        plan = self._plans.get(key)
        if plan is None and self.persist is not None:
            plan = self.persist.load_plan(sched.delta, D)
            if plan is not None:
                self._plans[key] = plan
                self.stats["cache_loads"] += 1
        if plan is None and self.persist is not None and sched.P % D == 0:
            from repro.persist.keys import plan_shard_fingerprint

            vb = plan_shard_bounds(sched, D)
            P_loc = sched.P // D
            pieces, built = [], 0
            for d in range(D):
                w0, w1 = d * P_loc, (d + 1) * P_loc
                digest = plan_shard_fingerprint(
                    sched, int(vb[d]), int(vb[d + 1]), w0, w1
                )
                piece = self.persist.load_plan_shard(digest)
                if piece is None:
                    piece = build_plan_shard(
                        sched, int(vb[d]), int(vb[d + 1]), w0, w1
                    )
                    self.persist.save_plan_shard(digest, piece)
                    self.stats["plan_shard_builds"] += 1
                    built += 1
                else:
                    self.stats["plan_shard_loads"] += 1
                pieces.append(piece)
            plan = assemble_frontier_plan(sched, D, pieces)
            self._plans[key] = plan
            if built:
                self.stats["plan_builds"] += 1
            else:
                self.stats["cache_loads"] += 1
            self.persist.save_plan(plan)
        if plan is None:
            plan = make_frontier_plan(sched, D)
            self._plans[key] = plan
            self.stats["plan_builds"] += 1
            if self.persist is not None:
                self.persist.save_plan(plan)
        return plan

    # ------------------------------------------------------------------ #
    # compile cache
    # ------------------------------------------------------------------ #
    def _traced(self, fn):
        """Wrap ``fn`` so executions of its *trace* are counted in stats."""

        def wrapped(*args):
            self.stats["traces"] += 1
            return fn(*args)

        return wrapped

    def compile_cached(self, key: tuple, fn, *args, portable: bool = True):
        """AOT-lower + compile ``fn`` for ``args``' shapes, once per ``key``.

        Resolution order: in-memory executable → persistent store (a
        deserialized :mod:`jax.export` blob — compiling it replays StableHLO
        and never re-traces ``fn``, so warm processes stay at zero ``traces``)
        → fresh trace+compile, which is then exported back to the store
        (best-effort; the export re-traces once, a one-time cold cost that
        buys every later process a zero-trace start).  Callers compiling
        shard_map programs pass ``portable=False``: a multi-device export
        pins its device assignment and could never be loaded, so the store
        is skipped entirely instead of computing an export to discard.
        """
        cached = self._compiled.get(key)
        if cached is not None:
            self._last_compile_s = 0.0
            return cached
        t0 = time.perf_counter()
        if self.persist is not None and portable:
            loaded = self.persist.load_executable(key, args)
            if loaded is not None:
                try:
                    cached = jax.jit(loaded).lower(*args).compile()
                except Exception:
                    # a blob can deserialize yet refuse to lower (jax.export
                    # checks platform here, not at deserialize) — e.g. a
                    # CPU-built cache shared to a TPU host.  A miss, not an
                    # error: fall through to the fresh trace below.
                    cached = None
                if cached is not None:
                    self._last_compile_s = time.perf_counter() - t0
                    self._compiled[key] = cached
                    self.stats["cache_loads"] += 1
                    self.stats["compile_time_s"] += self._last_compile_s
                    return cached
        cached = jax.jit(self._traced(fn)).lower(*args).compile()
        self._last_compile_s = time.perf_counter() - t0
        self._compiled[key] = cached
        self.stats["compiles"] += 1
        self.stats["compile_time_s"] += self._last_compile_s
        if self.persist is not None and portable:
            self.persist.save_executable(key, fn, args)
        return cached

    # ------------------------------------------------------------------ #
    # inputs
    # ------------------------------------------------------------------ #
    def _x_ext(self, x0):
        """Append the dump slot to ``x0`` — vector ``(n,)`` or matrix ``(n, F)``.

        A 1-D frontier takes the historical vector path bit-for-bit; a 2-D
        frontier threads its trailing feature axis through every backend.
        ``(n, 1)`` is accepted even for scalar problems — the degenerate
        matrix engine is the bit-identity test surface.
        """
        sr = self.problem.semiring
        if x0 is None:
            x0 = self.problem.x0(self.graph)
        x0 = jnp.asarray(x0, dtype=sr.dtype)
        n = self.graph.n
        if not (x0.shape == (n,) or (x0.ndim == 2 and x0.shape[0] == n)):
            raise ValueError(
                f"x0 must have shape ({n},) or ({n}, F), got {x0.shape}"
            )
        return extend_frontier(x0, sr)

    @staticmethod
    def _fkey(x_ext) -> tuple:
        """Compile-key suffix for the frontier's feature shape.

        ``()`` for vector frontiers keeps every pre-existing cache key —
        and every persisted executable keyed by it — byte-identical;
        matrix frontiers append ``("F", F)`` so a ``(n,)`` and ``(n, F)``
        solve never share an executable.
        """
        return () if x_ext.ndim == 1 else ("F", int(x_ext.shape[-1]))

    def resolve_query(self, q):
        """Normalize the per-query parameter pytree (dummy for query-free)."""
        if not self.problem.takes_query:
            if q is not None:
                raise ValueError(f"problem {self.problem.name!r} takes no query")
            return jnp.asarray(_NO_QUERY)
        if q is None:
            if self.problem.default_query is None:
                raise ValueError(f"problem {self.problem.name!r} needs q=")
            q = self.problem.default_query(self.graph)
        return jax.tree_util.tree_map(jnp.asarray, q)

    # ------------------------------------------------------------------ #
    # solve
    # ------------------------------------------------------------------ #
    def solve(
        self,
        x0=None,
        *,
        q=None,
        delta=None,
        backend: str | None = None,
        frontier: str | None = None,
        halo_dtype: str | None = None,
        tol: float | None = None,
        max_rounds: int | None = None,
        regime: str = "cold",
    ) -> EngineResult:
        """Run to convergence; returns the engine's instrumented result.

        ``regime`` tags the persisted observation row (``"cold"`` for from-
        scratch solves, ``"incremental"`` when :meth:`resolve` seeds from a
        prior fixed point) so the δ-model learns each curve separately.

        With ``degrade=True`` (constructor knob) a kernel/backend fault does
        not propagate: the solve retries one rung down the degradation
        ladder (halo → replicated, then pallas/sharded → jit → host),
        recording a :class:`repro.ft.degrade.Degradation` per fallback in
        ``self.degradations``.  Because every backend computes bit-identical
        rounds, a degraded solve returns the same answer, only slower.
        """
        backend = backend or self.default_backend
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        frontier = self.resolve_frontier(frontier, backend)
        halo_dtype = self.resolve_halo_dtype(halo_dtype, backend, frontier)
        tol = self.tol if tol is None else tol
        max_rounds = self.max_rounds if max_rounds is None else max_rounds
        sched = self.schedule(delta)
        x_ext = self._x_ext(x0)
        q = self.resolve_query(q)
        self.stats["solves"] += 1
        attempts = (
            degradation_ladder(backend, frontier)
            if self.degrade
            else [(backend, frontier)]
        )
        result = None
        for rung, (b, f) in enumerate(attempts):
            hd = halo_dtype if rung == 0 else self.resolve_halo_dtype(None, b, f)
            try:
                result = self._solve_once(b, f, hd, sched, x_ext, q, tol, max_rounds)
                break
            except (ValueError, TypeError):
                raise  # caller errors — never mask these behind a fallback
            except Exception as err:
                if rung + 1 == len(attempts):
                    raise
                nb, nf = attempts[rung + 1]
                self.degradations.append(
                    Degradation(
                        site="solve",
                        from_backend=b,
                        from_frontier=f,
                        to_backend=nb,
                        to_frontier=nf,
                        error=repr(err),
                        rung=rung + 1,
                    )
                )
                self.stats["degradations"] += 1
        self._last_x = np.asarray(result.x)
        self._record_observation(
            sched.delta, result.rounds, result.total_time_s, backend, regime=regime
        )
        return result

    def _solve_once(
        self, backend, frontier, halo_dtype, sched, x_ext, q, tol, max_rounds
    ) -> EngineResult:
        """One dispatch at a fixed (backend, frontier) rung — the fault domain
        the degradation ladder retries."""
        fire("kernel.dispatch", backend=backend, frontier=frontier)
        if backend in _FUSED_ROUND_BUILDERS and frontier != "halo":
            return self._solve_fused(backend, sched, x_ext, q, tol, max_rounds)
        if backend == "host":
            rnd = self._compiled_round(sched, x_ext, q, "host")
        else:
            rnd = self._compiled_round(sched, x_ext, q, backend, frontier, halo_dtype)
        return self._host_loop(sched, rnd, x_ext, tol, max_rounds)

    def _solve_fused(self, backend, sched, x_ext, q, tol, max_rounds) -> EngineResult:
        """The fused ``lax.while_loop`` path: ``backend ∈ {"jit", "pallas"}``.

        The jit backend compiles the *dynamic-schedule* loop — schedule
        arrays are call arguments, keyed by their shape class ``(δ, S, M)``
        — so an :meth:`apply_updates` that patches stripes in place replays
        the same executable with the new arrays, zero retraces.  The pallas
        kernel bakes the schedule into its grid, so it keeps the closure
        form (mutation drops its cache entry).
        """
        sr = self.problem.semiring
        fk = self._fkey(x_ext)
        if backend == "jit":
            sargs = schedule_args(sched)
            fn = self.compile_cached(
                ("dyn", backend, sched.delta, sched.S, sched.M) + fk,
                make_solve_fn_q_dyn(
                    sched, sr, self._row_update_q, self.problem.residual
                ),
                x_ext,
                q,
                *sargs,
                jnp.asarray(tol, jnp.float32),
                jnp.asarray(max_rounds, jnp.int32),
            )
            compiled = fn

            def fn(x, qq, t, m):
                return compiled(x, qq, *sargs, t, m)

        else:
            fn = self.compile_cached(
                (backend, sched.delta) + fk,
                make_solve_fn_q(
                    sched,
                    sr,
                    self._row_update_q,
                    self.problem.residual,
                    round_builder=_FUSED_ROUND_BUILDERS[backend],
                ),
                x_ext,
                q,
                jnp.asarray(tol, jnp.float32),
                jnp.asarray(max_rounds, jnp.int32),
            )
        return execute_solve_fn(
            fn,
            sched,
            sr,
            x_ext,
            q,
            tol,
            max_rounds,
            compile_time_s=self._last_compile_s,
        )

    def _compiled_round(
        self, sched, x_ext, q, backend, frontier="replicated", halo_dtype="f32"
    ):
        """Cached compiled one-round ``x_ext -> x_ext`` for host/pallas/sharded."""
        sr = self.problem.semiring
        fk = self._fkey(x_ext)
        if backend == "pallas" and frontier == "halo":
            return self._pallas_halo_round(sched, x_ext, q, halo_dtype)
        if backend == "host":
            # dynamic form: survives same-shape schedule mutations, like jit
            sargs = schedule_args(sched)
            rnd = self.compile_cached(
                ("dyn", "host", "round", sched.delta, sched.S, sched.M) + fk,
                round_fn_q_dyn(sched, sr, self._row_update_q),
                x_ext,
                q,
                *sargs,
            )
            return lambda x: rnd(x, q, *sargs)
        if backend == "pallas":
            rnd = self.compile_cached(
                ("pallas", "round", sched.delta) + fk,
                round_fn_pallas_q(sched, sr, self._row_update_q),
                x_ext,
                q,
            )
            return lambda x: rnd(x, q)
        if backend != "sharded":
            raise ValueError(
                f"round backend must be 'host', 'pallas', or 'sharded': {backend!r}"
            )
        mesh = self._default_mesh()
        from repro.dist.compat import mesh_axis_sizes

        D = mesh_axis_sizes(mesh)[self.mesh_axis]
        if frontier == "replicated":
            from repro.dist.engine_sharded import sharded_round_fn_q

            fn = sharded_round_fn_q(
                sched, sr, self._row_update_q, mesh, axis=self.mesh_axis,
                feature_dims=x_ext.ndim - 1,
            )
            args = (sched.src, sched.val, sched.dst_local, sched.rows)
            compiled = self.compile_cached(
                ("sharded", "replicated", sched.delta, D) + fk,
                fn,
                x_ext,
                *args,
                q,
                portable=D == 1,
            )
            return lambda x: compiled(x, *args, q)
        from repro.dist.engine_sharded import frontier_plan_args, frontier_round_ext_fn

        plan = self.frontier_plan(sched)
        fn = frontier_round_ext_fn(
            sched, plan, sr, self._row_update_q, mesh, axis=self.mesh_axis,
            feature_dims=x_ext.ndim - 1,
        )
        args = frontier_plan_args(sched, plan)
        compiled = self.compile_cached(
            ("sharded", "halo", sched.delta, D) + fk, fn, x_ext, q, *args,
            portable=D == 1,
        )
        return lambda x: compiled(x, q, *args)

    def _pallas_halo_round(self, sched, x_ext, q, halo_dtype):
        """The fused halo round: per-shard Pallas kernels under shard_map.

        The error-feedback residuals are loop state, not a function of ``x``,
        so the returned callable carries them across rounds in a closure —
        fresh zeros per call to :meth:`_compiled_round` (i.e. per solve), the
        same lifetime a quantized iterative solve expects.  Cache key
        ``("pallas-halo", δ, dtype, D)``; dropped (not dyn-keyed) on
        :meth:`apply_updates`, exactly like the other baked-plan executables.
        """
        from repro.dist.compat import mesh_axis_sizes
        from repro.dist.engine_sharded import (
            frontier_ef_init,
            frontier_pallas_round_ext_fn,
            frontier_plan_args,
            resolve_halo_dtype,
        )

        sr = self.problem.semiring
        resolve_halo_dtype(halo_dtype, sr)
        mesh = self._default_mesh()
        D = mesh_axis_sizes(mesh)[self.mesh_axis]
        plan = self.frontier_plan(sched)
        fn = frontier_pallas_round_ext_fn(
            sched,
            plan,
            sr,
            self._row_update_q,
            mesh,
            axis=self.mesh_axis,
            halo_dtype=halo_dtype,
            feature_dims=x_ext.ndim - 1,
        )
        args = frontier_plan_args(sched, plan)
        ef0 = frontier_ef_init(plan, x_ext.shape[1:])
        compiled = self.compile_cached(
            ("pallas-halo", sched.delta, halo_dtype, D) + self._fkey(x_ext),
            fn,
            x_ext,
            ef0,
            q,
            *args,
            portable=D == 1,
        )
        state = {"ef": ef0}

        def rnd(x):
            x, state["ef"] = compiled(x, state["ef"], q, *args)
            return x

        # expose the loop-carried error-feedback residuals so checkpointing
        # (repro.ft.elastic) can snapshot/restore/reset them between rounds
        rnd.ef_state = state
        rnd.ef_init = ef0
        return rnd

    def _host_loop(self, sched, rnd, x_ext, tol, max_rounds) -> EngineResult:
        return host_loop(
            rnd,
            sched,
            self.problem.semiring,
            x_ext,
            self.problem.residual,
            tol,
            max_rounds,
            compile_time_s=self._last_compile_s,
        )

    # ------------------------------------------------------------------ #
    # evolving graphs: apply_updates + incremental resolve
    # ------------------------------------------------------------------ #
    def apply_updates(self, batch):
        """Mutate the bound graph in place; returns the ``UpdateReport``.

        Rebinds the problem's row update and edge values to the new graph and
        invalidates **only** what the batch touched: cached schedules keep
        every stripe whose worker block the affected rows miss (patched in
        place, same shapes — the dyn-keyed executables replay without a
        retrace); halo plans and non-dyn executables drop (their index
        arrays / baked constants are stale); the persist namespace re-derives
        from the new graph content, carrying the fitted δ-models over and
        pushing the rebuilt stripes into the shared store so a restarted
        process stays warm everywhere the batch didn't reach.

        The partition bounds are **pinned** across updates: recomputing a
        degree-sensitive partition on the mutated graph would shift every
        block boundary and invalidate all stripes for a one-row change.
        """
        bounds = self.bounds  # pin pre-mutation bounds before swapping graphs
        new_graph, report = self.graph.apply_updates(batch)
        self.graph = new_graph
        problem = self.problem
        self._sched_graph = (
            new_graph.with_values(problem.edge_values(new_graph))
            if problem.edge_values is not None
            else new_graph
        )
        self._row_update = problem.make_row_update(new_graph)
        if problem.takes_query:
            self._row_update_q = self._row_update
        else:
            base = self._row_update

            def _row_update_q(old, reduced, rows, q):
                return base(old, reduced, rows)

            self._row_update_q = _row_update_q
        self._bounds = bounds
        self._partition = None
        self._plans = {}
        self._compiled = {
            k: v for k, v in self._compiled.items() if k and k[0] == "dyn"
        }
        if self.persist is not None:
            old_persist = self.persist
            self.persist = self._make_persist()
            # The observation log follows the *logical* graph across
            # mutations: reprobe_delta needs rounds-vs-δ data accumulated
            # over many small batches, each of which re-derives the
            # namespace but barely moves the curve being fitted.
            old_obs = old_persist.dir / "observations.jsonl"
            new_obs = self.persist.dir / "observations.jsonl"
            if old_obs.exists() and not new_obs.exists():
                try:
                    new_obs.write_bytes(old_obs.read_bytes())
                except OSError:
                    pass
            if self.delta_model is not None and self._auto_delta is not None:
                self.persist.save_delta_model(self.delta_model, self._auto_delta)
            if (
                self.delta_model_incremental is not None
                and self._auto_delta_incremental is not None
            ):
                self.persist.save_delta_model(
                    self.delta_model_incremental,
                    self._auto_delta_incremental,
                    regime="incremental",
                )
        self._patch_schedules(report)
        self._last_report = report
        return report

    def _touched_workers(self, affected_rows) -> np.ndarray:
        """Worker blocks containing any affected destination row."""
        affected = np.asarray(affected_rows, dtype=np.int64)
        if affected.size == 0:
            return np.zeros(0, dtype=np.int64)
        return np.unique(np.searchsorted(self.bounds, affected, side="right") - 1)

    def _patch_schedules(self, report):
        """Rebuild only the touched workers' stripes of every cached schedule.

        A stripe that outgrows the schedule's padded width ``M`` forces that
        δ's schedule to drop for a lazy full rebuild (global re-padding would
        touch every worker anyway); otherwise the patched arrays keep their
        shapes, which is what lets the dyn executables replay compile-free.
        """
        from repro.persist.keys import stripe_fingerprint

        bounds = self.bounds
        pad_val = self.problem.semiring.pad_edge_val
        touched = self._touched_workers(report.affected_rows)
        for delta_eff, sched in list(self._schedules.items()):
            stripes, fits = {}, True
            for w in touched:
                lo, hi = int(bounds[w]), int(bounds[w + 1])
                st = build_worker_stripe(
                    self._sched_graph, lo, hi, sched.S, delta_eff, pad_val
                )
                if st["src"].shape[1] > sched.M:
                    fits = False
                    break
                stripes[int(w)] = st
            if not fits:
                del self._schedules[delta_eff]
                continue
            src = np.asarray(sched.src).copy()
            val = np.asarray(sched.val).copy()
            dst_local = np.asarray(sched.dst_local).copy()
            for w, st in stripes.items():
                m = st["src"].shape[1]
                src[:, w, :] = 0
                src[:, w, :m] = st["src"]
                val[:, w, :] = pad_val
                val[:, w, :m] = st["val"]
                dst_local[:, w, :] = delta_eff
                dst_local[:, w, :m] = st["dst_local"]
                # rows[:, w] is untouched: it depends only on (lo, hi, δ, n)
            self._schedules[delta_eff] = dataclasses.replace(
                sched,
                src=jnp.asarray(src),
                val=jnp.asarray(val),
                dst_local=jnp.asarray(dst_local),
                edges=self._sched_graph.nnz,
                padding_overhead=src.size / max(self._sched_graph.nnz, 1),
            )
            if self.persist is not None:
                for w, st in stripes.items():
                    digest = stripe_fingerprint(
                        self._sched_graph,
                        int(bounds[w]),
                        int(bounds[w + 1]),
                        sched.S,
                        delta_eff,
                        pad_val,
                    )
                    self.persist.save_stripe(digest, st)

    def resolve(
        self,
        updates=None,
        *,
        x0=None,
        q=None,
        delta=None,
        backend: str | None = None,
        frontier: str | None = None,
        tol: float | None = None,
        max_rounds: int | None = None,
    ) -> EngineResult:
        """Incremental re-solve after ``updates`` (an ``EdgeBatch``), seeded
        from the previous fixed point.

        Applies the batch via :meth:`apply_updates`, repairs the prior fixed
        point into a valid warm state (:mod:`repro.evolve.restart` — the
        delete-edge invalidation cone is re-raised for min-plus problems
        before any re-lowering), and converges on the mutated graph.  The
        result equals a cold :meth:`solve` on the mutated graph within tol
        (bit-exact labels for min-plus) in typically far fewer rounds.

        ``x0=`` overrides the warm seed (defaults to this solver's last
        solve's fixed point).  With ``updates=None`` this is a plain warm
        re-solve.  ``delta=None``/``"auto"`` prefers the incremental-regime
        δ* once :meth:`reprobe_delta` has fitted one.
        """
        if x0 is None and self._last_x is None:
            raise ValueError(
                "resolve() warm-starts from the previous fixed point — "
                "call solve() first or pass x0="
            )
        report = None
        if updates is not None:
            report = self.apply_updates(updates)
        x_prev = np.asarray(x0) if x0 is not None else self._last_x
        from repro.evolve.restart import warm_start_state

        y = warm_start_state(
            self.problem,
            self.graph,
            self._sched_graph,
            x_prev,
            batch=updates,
            report=report,
        )
        if (delta is None and self.default_delta == "auto") or delta == "auto":
            if self._auto_delta_incremental is not None:
                delta = self._auto_delta_incremental
        return self.solve(
            y,
            q=q,
            delta=delta,
            backend=backend,
            frontier=frontier,
            tol=tol,
            max_rounds=max_rounds,
            regime="incremental",
        )

    def solve_batch(
        self,
        x0_batch,
        *,
        q=None,
        delta=None,
        backend: str | None = None,
        frontier: str | None = None,
        tol=None,
        max_rounds=None,
        compact_every: int | None = None,
    ):
        """Batched multi-query solve — see :func:`repro.solve.batch.solve_batch`."""
        from repro.solve.batch import solve_batch

        return solve_batch(
            self,
            x0_batch,
            q=q,
            delta=delta,
            backend=backend,
            frontier=frontier,
            tol=tol,
            max_rounds=max_rounds,
            compact_every=compact_every,
        )

    # ------------------------------------------------------------------ #
    # sharded plumbing + introspection
    # ------------------------------------------------------------------ #
    def _default_mesh(self):
        if self._mesh is None:
            from repro.dist.compat import AxisType, make_mesh

            ndev = len(jax.devices())
            size = math.gcd(self.n_workers, ndev)
            self._mesh = make_mesh(
                (size,),
                (self.mesh_axis,),
                axis_types=(AxisType.Auto,),
                devices=jax.devices()[:size],
            )
        return self._mesh

    def round_callable(
        self,
        delta=None,
        backend: str = "host",
        frontier: str | None = None,
        q=None,
        halo_dtype: str | None = None,
    ):
        """The cached compiled one-round ``x_ext -> x_ext`` (tests/benchmarks).

        ``backend`` is ``"host"`` (the single-device XLA round — also what
        the jit backend's fused loop iterates), ``"pallas"`` (the fused
        one-kernel round the pallas backend iterates; with
        ``frontier="halo"`` the per-shard fused halo round), or
        ``"sharded"``; ``frontier`` picks replicated vs halo per
        :data:`BACKEND_FRONTIERS`.
        """
        frontier = self.resolve_frontier(frontier, backend)
        halo_dtype = self.resolve_halo_dtype(halo_dtype, backend, frontier)
        sched = self.schedule(delta)
        return self._compiled_round(
            sched,
            self._x_ext(None),
            self.resolve_query(q),
            backend,
            frontier,
            halo_dtype,
        )
