"""Optimizers (from scratch — no optax in this container).

AdamW and Adafactor over arbitrary pytrees, plus LR schedules including the
WSD (warmup-stable-decay) schedule MiniCPM trains with [arXiv:2404.06395].
States are pytrees mirroring the parameters, so they inherit parameter
sharding under pjit (ZeRO by construction).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


# --------------------------------------------------------------------------- #
# Schedules
# --------------------------------------------------------------------------- #


def linear_warmup_cosine(peak_lr, warmup, total, final_frac=0.1):
    def f(step):
        step = step.astype(F32)
        warm = peak_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(np.pi * prog))
        return jnp.where(step < warmup, warm, peak_lr * cos)

    return f


def wsd(peak_lr, warmup, stable, decay, final_frac=0.01):
    """Warmup-Stable-Decay (MiniCPM): flat plateau, fast exponential tail."""

    def f(step):
        step = step.astype(F32)
        warm = peak_lr * step / max(warmup, 1)
        in_decay = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = peak_lr * jnp.exp(jnp.log(final_frac) * in_decay)
        return jnp.where(
            step < warmup, warm, jnp.where(step < warmup + stable, peak_lr, dec)
        )

    return f


def constant(lr):
    return lambda step: jnp.asarray(lr, F32)


# --------------------------------------------------------------------------- #
# Gradient utilities
# --------------------------------------------------------------------------- #


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(F32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype), tree), norm


# --------------------------------------------------------------------------- #
# AdamW
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class AdamW:
    schedule: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, F32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        lr = self.schedule(step)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(F32), state["m"], grads
        )
        v = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(F32)),
            state["v"],
            grads,
        )
        bc1 = 1 - b1**step.astype(F32)
        bc2 = 1 - b2**step.astype(F32)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            wd = self.weight_decay * p.astype(F32) if p.ndim >= 2 else 0.0
            return (p.astype(F32) - lr * (u + wd)).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return (
            new_params,
            {"m": m, "v": v, "step": step},
            {"lr": lr, "grad_norm": gnorm},
        )


# --------------------------------------------------------------------------- #
# Mixed precision wrapper (§Perf): bf16 working params + f32 master copy
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class MixedPrecision:
    """Store working params in bf16, master + moments in f32.

    Gradients then flow (and reduce-scatter) in bf16 — half the gradient
    collective bytes — and no full-matrix f32 temps appear at the FSDP
    gather boundary (the cast lives on the stored copy, not per-use).
    """

    inner: object  # AdamW / Adafactor
    compute_dtype: object = jnp.bfloat16

    def init(self, params):
        master = jax.tree.map(
            lambda p: p.astype(F32) if jnp.issubdtype(p.dtype, jnp.floating) else p,
            params,
        )
        return {"master": master, "inner": self.inner.init(master)}

    def update(self, grads, state, params):
        del params  # the bf16 working copy is derived, not the source of truth
        new_master, inner_state, metrics = self.inner.update(
            grads, state["inner"], state["master"]
        )
        new_params = jax.tree.map(
            lambda p: p.astype(self.compute_dtype)
            if jnp.issubdtype(p.dtype, jnp.floating)
            else p,
            new_master,
        )
        return new_params, {"master": new_master, "inner": inner_state}, metrics


# --------------------------------------------------------------------------- #
# Adafactor (factored second moment — memory-lean option for ≥100B params)
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class Adafactor:
    schedule: Callable
    decay: float = 0.99
    eps: float = 1e-30
    clip_norm: float = 1.0
    weight_decay: float = 0.0

    def init(self, params):
        def factored(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], F32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], F32),
                }
            return {"v": jnp.zeros(p.shape, F32)}

        return {
            "f": jax.tree.map(factored, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        lr = self.schedule(step)
        d = self.decay

        def upd(p, g, f):
            g = g.astype(F32)
            if p.ndim >= 2:
                vr = d * f["vr"] + (1 - d) * jnp.mean(jnp.square(g), axis=-1)
                vc = d * f["vc"] + (1 - d) * jnp.mean(jnp.square(g), axis=-2)
                denom = jnp.sqrt(
                    vr[..., None]
                    * vc[..., None, :]
                    / jnp.maximum(
                        jnp.mean(vr, axis=-1, keepdims=True)[..., None], self.eps
                    )
                    + self.eps
                )
                u = g / denom
                nf = {"vr": vr, "vc": vc}
            else:
                v = d * f["v"] + (1 - d) * jnp.square(g)
                u = g / (jnp.sqrt(v) + 1e-8)
                nf = {"v": v}
            wd = self.weight_decay * p.astype(F32) if p.ndim >= 2 else 0.0
            return (p.astype(F32) - lr * (u + wd)).astype(p.dtype), nf

        is_fac = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_f = jax.tree.flatten(state["f"], is_leaf=is_fac)[0]
        out = [upd(p, g, f) for p, g, f in zip(flat_p, flat_g, flat_f)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_f = tdef.unflatten([o[1] for o in out])
        return new_params, {"f": new_f, "step": step}, {"lr": lr, "grad_norm": gnorm}
