"""Training step: loss → grad → optimizer, with optional delayed commit.

Parameters are stored in f32 (master) and cast to the model compute dtype for
the forward/backward pass.  The delayed-commit variant (the paper's technique
at training scale, DESIGN.md §3) is in :mod:`repro.dist.delayed_commit` and
wraps this step.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import train_loss
from repro.models.config import ModelConfig

F32 = jnp.float32


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: dict
    opt_state: dict
    step: jnp.ndarray


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p,
        tree,
    )


def init_train_state(cfg: ModelConfig, optimizer, key) -> TrainState:
    from repro.models import init_params
    from repro.train.optimizer import MixedPrecision

    if isinstance(optimizer, MixedPrecision):
        # bf16 working params; the f32 master lives in opt_state["master"]
        params = cast_tree(init_params(cfg, key), jnp.dtype(cfg.dtype))
    else:
        params = cast_tree(init_params(cfg, key), F32)  # f32 masters
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        step=jnp.zeros((), jnp.int32),
    )


def make_train_step(cfg: ModelConfig, optimizer, accum_steps: int = 1,
                    param_specs=None):
    """Returns jit-able ``(state, batch) -> (state, metrics)``.

    ``accum_steps`` > 1 splits the batch into microbatches scanned
    sequentially with f32 gradient accumulation — the activation working set
    shrinks by the same factor (how the 123B config fits HBM at 4k × 256).

    ``param_specs`` (a PartitionSpec tree mirroring params) pins gradients to
    the parameter sharding — without it XLA may leave scan-carried grads
    partially replicated on the model axis (§Perf: −8.7 GiB/dev at 123B).
    """
    compute_dtype = jnp.dtype(cfg.dtype)

    def constrain(tree):
        if param_specs is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, param_specs
        )

    def loss_fn(params, batch):
        fparams = cast_tree(params, compute_dtype)
        return train_loss(fparams, cfg, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(state: TrainState, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
            grads = constrain(grads)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(
                    (accum_steps, x.shape[0] // accum_steps) + x.shape[1:]
                ),
                batch,
            )

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(state.params, mb)
                g = constrain(g)
                g_acc = constrain(
                    jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                )
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (grads, loss), metrics = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
        new_params, new_opt, opt_metrics = optimizer.update(
            grads, state.opt_state, state.params
        )
        metrics = dict(metrics, **opt_metrics, total_loss=loss)
        return (
            TrainState(params=new_params, opt_state=new_opt, step=state.step + 1),
            metrics,
        )

    return step
