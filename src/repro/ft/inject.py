"""Typed, deterministic chaos injection — one harness for every fault site.

A :class:`FaultPlan` is a list of :class:`FaultSpec` rules evaluated at
instrumented *sites* across the stack.  Sites call :func:`fire` with a site
name and context kwargs; when no plan is active the call is a near-free
no-op (one global read), so production paths carry the hooks permanently.

Instrumented site classes (context keys in parentheses):

==================  =========================================================
``solver.round``    engine host loop / checkpointed solve, once per round
                    boundary (``round`` — rounds already executed, 0-based)
``kernel.dispatch`` ``Solver`` backend dispatch and ``BatchStepper.run``
                    (``backend``, ``frontier``)
``persist.write``   persist-store atomic writes (``key``); I/O kinds
                    ``torn`` / ``corrupt`` / ``eio`` emulate partial, flipped
                    and failed writes
``persist.read``    persist-store loads (``key``)
``ckpt.write``      checkpoint commit (``step``); ``torn`` kills the writer
                    before the ``_COMMITTED`` marker lands
``scheduler.lane``  ``ContinuousScheduler.pump`` per lane quantum
                    (``graph``, ``algo``, ``request_class``)
``train.step``      ``run_training`` step boundary (``step``)
==================  =========================================================

Determinism: specs fire by *visit count* (``at`` / ``every``) or by a seeded
per-visit coin (``p``); both are pure functions of the call sequence, so a
replayed run fires identically.  Every fire is appended to ``plan.events``
— the chaos trace — and plans round-trip through JSON so traces can be
committed (``benchmarks/traces/chaos_smoke.json``).

Faults manifest two ways: ``kind="error"`` raises :class:`InjectedFault`
(a ``RuntimeError`` — recovery machinery must not special-case it), while
the I/O kinds are *returned* to the site, which emulates the corruption
itself (a torn write really leaves truncated bytes on disk).
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import random
import threading

__all__ = [
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "active_plan",
    "fire",
    "inject",
]

#: Fault kinds a spec may carry.  "error" raises; the rest are returned to
#: the site for it to emulate (only meaningful at I/O sites).
KINDS = ("error", "torn", "corrupt", "eio")


class InjectedFault(RuntimeError):
    """Raised by a firing ``kind="error"`` spec.

    Subclasses ``RuntimeError`` deliberately: recovery paths (degradation
    ladder, scheduler retry, runner restart) handle it through the same
    ``except Exception`` arms a real kernel/node fault would take.
    """

    def __init__(self, site: str, kind: str = "error", detail: str = ""):
        self.site = site
        self.kind = kind
        msg = f"injected {kind} fault at {site}"
        super().__init__(msg + (f" ({detail})" if detail else ""))


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injection rule: *where* (site + match) and *when* (at/every/p).

    ``at``     fire on the ``at``-th matching visit (0-based) and the next
               ``times - 1`` matching visits after it.
    ``every``  fire on every ``every``-th matching visit (1-based phase:
               visits ``every-1``, ``2*every-1``, ...), still capped by
               ``times`` unless ``times < 0`` (unlimited).
    ``p``      seeded per-visit probability; combined with the plan seed and
               the spec index so two specs never share a coin stream.
    ``match``  equality filters on the site's context kwargs; a context key
               absent from the call never matches.
    """

    site: str
    kind: str = "error"
    at: int | None = None
    every: int | None = None
    p: float = 0.0
    times: int = 1
    match: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {self.kind!r}")
        if self.every is not None and self.every < 1:
            raise ValueError(f"every must be >= 1, got {self.every}")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"p must be in [0, 1], got {self.p}")
        if self.at is None and self.every is None and self.p == 0.0:
            # bare spec: fire on the first matching visit
            object.__setattr__(self, "at", 0)

    def to_dict(self) -> dict:
        out = {"site": self.site, "kind": self.kind, "times": self.times}
        if self.at is not None:
            out["at"] = self.at
        if self.every is not None:
            out["every"] = self.every
        if self.p:
            out["p"] = self.p
        if self.match:
            out["match"] = dict(self.match)
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "FaultSpec":
        return cls(
            site=d["site"],
            kind=d.get("kind", "error"),
            at=d.get("at"),
            every=d.get("every"),
            p=float(d.get("p", 0.0)),
            times=int(d.get("times", 1)),
            match=dict(d.get("match", {})),
        )


class _SpecState:
    __slots__ = ("visits", "fires", "rng")

    def __init__(self, seed: int):
        self.visits = 0
        self.fires = 0
        self.rng = random.Random(seed)


class FaultPlan:
    """An ordered set of fault specs with deterministic per-spec counters.

    ``fire(site, **ctx)`` counts the visit on *every* matching spec, then
    fires the first spec that is due: ``kind="error"`` raises
    :class:`InjectedFault`, I/O kinds are returned as a string (``None``
    means no fault).  Thread-safe; counters are plan-local, so a fresh plan
    replays a committed trace from zero.
    """

    def __init__(self, specs=(), seed: int = 0):
        self.specs = [
            s if isinstance(s, FaultSpec) else FaultSpec.from_dict(s) for s in specs
        ]
        self.seed = int(seed)
        self.events: list[dict] = []
        self._lock = threading.Lock()
        self._state = [
            _SpecState(hash((self.seed, i)) & 0x7FFFFFFF)
            for i in range(len(self.specs))
        ]

    def fire(self, site: str, **ctx):
        due = None
        with self._lock:
            for i, spec in enumerate(self.specs):
                if spec.site != site:
                    continue
                if any(ctx.get(k, _MISS) != v for k, v in spec.match.items()):
                    continue
                st = self._state[i]
                visit = st.visits
                st.visits += 1
                if due is not None:
                    continue  # keep counting visits on later specs
                if spec.times >= 0 and st.fires >= spec.times:
                    continue
                hit = False
                if spec.at is not None:
                    hit = visit >= spec.at
                elif spec.every is not None:
                    hit = (visit + 1) % spec.every == 0
                if spec.p > 0.0 and not hit:
                    hit = st.rng.random() < spec.p
                if hit:
                    st.fires += 1
                    due = (i, spec, visit)
            if due is not None:
                i, spec, visit = due
                self.events.append(
                    {
                        "site": site,
                        "kind": spec.kind,
                        "spec": i,
                        "visit": visit,
                        **{
                            k: v
                            for k, v in ctx.items()
                            if isinstance(v, (str, int, float, bool))
                        },
                    }
                )
        if due is None:
            return None
        _, spec, _ = due
        detail = ", ".join(f"{k}={v!r}" for k, v in sorted(ctx.items()))
        if spec.kind == "error":
            raise InjectedFault(site, spec.kind, detail)
        return spec.kind

    @property
    def fired(self) -> int:
        return len(self.events)

    def sites_fired(self) -> list[str]:
        return sorted({e["site"] for e in self.events})

    def to_json(self) -> dict:
        return {"seed": self.seed, "specs": [s.to_dict() for s in self.specs]}

    @classmethod
    def from_json(cls, d: dict) -> "FaultPlan":
        return cls(d.get("specs", ()), seed=int(d.get("seed", 0)))

    def dumps(self) -> str:
        return json.dumps(self.to_json(), indent=2, sort_keys=True)

    @classmethod
    def loads(cls, s: str) -> "FaultPlan":
        return cls.from_json(json.loads(s))


_MISS = object()
_ACTIVE: FaultPlan | None = None


def active_plan() -> FaultPlan | None:
    return _ACTIVE


def fire(site: str, **ctx):
    """Site hook: evaluate the active plan (no-op when none is installed)."""
    plan = _ACTIVE
    if plan is None:
        return None
    return plan.fire(site, **ctx)


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Install ``plan`` as the active plan for the dynamic extent."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = prev
