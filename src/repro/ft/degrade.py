"""Graceful-degradation ladder: which (backend, frontier) to fall back to.

All backends compute bit-identical rounds for a given schedule (the repo's
core invariant), so degrading trades *performance*, never *answers*: a
solve that falls from ``pallas`` to ``host`` returns the same fixed point
it would have returned fault-free.  The ladder first drops the halo
frontier exchange (``halo`` → ``replicated`` on the same backend), then
steps down backends ``pallas``/``sharded`` → ``jit`` → ``host``; the host
rung has no dependencies beyond numpy and is the floor.
"""

from __future__ import annotations

import dataclasses

__all__ = ["BACKEND_LADDER", "Degradation", "degradation_ladder"]

#: Next backend to try after a fault; ``None`` terminates the ladder.
BACKEND_LADDER = {"pallas": "jit", "sharded": "jit", "jit": "host", "host": None}


@dataclasses.dataclass(frozen=True)
class Degradation:
    """One recorded fallback: where the fault hit and where execution moved."""

    site: str  # "solve" (Solver ladder) or "lane" (scheduler)
    from_backend: str
    from_frontier: str
    to_backend: str
    to_frontier: str
    error: str  # repr of the triggering exception
    rung: int  # 1 = first fallback, 2 = second, ...


def degradation_ladder(backend: str, frontier: str) -> list[tuple[str, str]]:
    """``[(backend, frontier), ...]`` from the requested pair down to host.

    The first element is the requested pair itself; each later element is
    one rung down.  E.g. ``("pallas", "halo")`` →
    ``[("pallas", "halo"), ("pallas", "replicated"), ("jit", "replicated"),
    ("host", "replicated")]``.
    """
    if backend not in BACKEND_LADDER:
        raise ValueError(f"unknown backend {backend!r}")
    steps = [(backend, frontier)]
    if frontier == "halo":
        steps.append((backend, "replicated"))
    b = backend
    while BACKEND_LADDER[b] is not None:
        b = BACKEND_LADDER[b]
        steps.append((b, "replicated"))
    return steps
