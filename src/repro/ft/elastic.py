"""Elastic checkpoints of in-flight solves and delayed-commit training state.

Two restore guarantees, per discipline (see ``solve/README.md``):

* **bit-identical** — deterministic rounds (every backend, fixed schedule)
  replay the exact trajectory from the snapshot: resuming at round *k*
  produces the same ``x`` per round as the uninterrupted run, even on a
  different mesh width (the round is width-invariant for a fixed worker
  count ``P``).
* **fixed-point-identical** — state the snapshot cannot carry across a
  topology change (per-shard error-feedback residuals at a new mesh width,
  per-pod deltas at a new ``n_pods``) is folded or reset; the iteration
  still converges to the same fixed point, exactly the slack δ-buffered
  asynchrony guarantees (Maiter's restart-from-any-intermediate-state).

Snapshots ride :mod:`repro.ckpt.checkpoint`'s manifest machinery, so they
are atomic (``_COMMITTED`` rename), async (background thread), and elastic
(the manifest stores the global layout; :func:`load_latest_flat` needs no
like-tree at all — shapes come from the manifest).
"""

from __future__ import annotations

import dataclasses
import json
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import (
    CheckpointManager,
    _flatten_with_names,
    latest_step,
)
from repro.ft.inject import fire

__all__ = [
    "CheckpointedSolve",
    "SolveCheckpointer",
    "checkpointed_solve",
    "load_latest_flat",
    "restore_delayed_state",
]

_KEYSTR = re.compile(r"^\['([^']*)'\]$")


def load_latest_flat(directory):
    """``(step, {name: ndarray})`` of the newest committed checkpoint.

    Manifest-driven: no like-tree needed — leaf names, shapes, and dtypes
    come from ``manifest.json``, shards are concatenated elastically.
    Returns ``None`` when the directory holds no committed step.
    """
    step = latest_step(directory)
    if step is None:
        return None
    step_dir = Path(directory) / f"step_{step:09d}"
    manifest = json.loads((step_dir / "manifest.json").read_text())
    shards = [
        np.load(step_dir / f"shard_{h:05d}.npz") for h in range(manifest["n_hosts"])
    ]
    flat = {}
    for name, info in manifest["leaves"].items():
        key = name.replace("/", "|")
        if info["axis"] == 0:
            arr = np.concatenate([s[key] for s in shards], axis=0)
        else:
            arr = shards[0][key]
        flat[name] = np.asarray(arr).reshape(info["shape"]).astype(info["dtype"])
    return step, flat


class SolveCheckpointer:
    """Round-indexed snapshots of an in-flight solve (flat dict trees)."""

    def __init__(self, directory, every: int = 8, keep: int = 3):
        self.every = int(every)
        self.mgr = CheckpointManager(directory, keep=keep)

    def save(self, rounds: int, tree: dict, block: bool = False):
        self.mgr.save(rounds, tree, block=block)

    def wait(self):
        self.mgr.wait()

    def restore_latest(self):
        """``(rounds, {key: ndarray})`` of the newest snapshot, or ``None``.

        Any torn/corrupt snapshot reads as absent (cold start), never as an
        exception — the restore path must survive the fault that created it.
        """
        try:
            got = load_latest_flat(self.mgr.directory)
        except Exception:
            return None
        if got is None:
            return None
        step, flat = got
        out = {}
        for name, arr in flat.items():
            m = _KEYSTR.match(name)
            out[m.group(1) if m else name] = arr
        return step, out


@dataclasses.dataclass
class CheckpointedSolve:
    """A fault-tolerant solve's result plus its recovery accounting."""

    result: object  # EngineResult
    rounds_executed: int  # physical rounds run in this call (replays included)
    restores: int  # restore-from-snapshot events in this call
    resumed_at: int | None  # round of the snapshot this call started from


def _snapshot_tree(x_ext, residuals, rnd) -> dict:
    tree = {
        "x_ext": np.asarray(x_ext),
        # the whole residual trajectory rides along, so a resumed solve
        # reports the same per-round history as the uninterrupted one
        "residuals": np.asarray(residuals, np.float32),
    }
    ef_state = getattr(rnd, "ef_state", None)
    if ef_state is not None:
        for i, leaf in enumerate(jax.tree_util.tree_leaves(ef_state["ef"])):
            tree[f"ef_{i}"] = np.asarray(leaf)
    return tree


def _restore_ef(rnd, tree: dict):
    """Put snapshotted error-feedback residuals back into the round closure.

    On any mismatch (no EF in the snapshot, or shapes changed because the
    mesh width did) the residuals reset to zeros: EF only accelerates
    convergence, so zeros preserve the fixed point — this is exactly the
    fixed-point-identical half of the restore contract.
    """
    state = getattr(rnd, "ef_state", None)
    if state is None:
        return
    leaves, treedef = jax.tree_util.tree_flatten(rnd.ef_init)
    restored = []
    for i, leaf in enumerate(leaves):
        arr = tree.get(f"ef_{i}")
        if arr is None or tuple(np.shape(arr)) != tuple(leaf.shape):
            state["ef"] = rnd.ef_init
            return
        restored.append(jnp.asarray(np.asarray(arr), dtype=leaf.dtype))
    state["ef"] = jax.tree_util.tree_unflatten(treedef, restored)


def checkpointed_solve(
    solver,
    x0=None,
    *,
    q=None,
    delta=None,
    backend: str | None = None,
    frontier: str | None = None,
    halo_dtype: str | None = None,
    tol: float | None = None,
    max_rounds: int | None = None,
    ckpt_dir,
    every: int = 8,
    keep: int = 3,
    resume: bool = True,
    max_restores: int = 8,
) -> CheckpointedSolve:
    """Host-driven solve with periodic async snapshots and restore-on-fault.

    Every ``every`` rounds the engine state — extended frontier ``x_ext``,
    residual, round counter, and (pallas+halo) per-shard error-feedback
    residuals — is snapshotted in the background.  A fault mid-solve
    restores the newest committed snapshot and replays from there (at most
    ``every - 1`` recomputed rounds per fault); with ``resume=True`` a fresh
    process — including one on a **different mesh width** — picks up the
    same way instead of restarting cold.

    The loop is host-driven, so ``backend="jit"`` runs the host round (the
    same XLA round, bit-identical); pallas/sharded backends step their own
    compiled rounds.  Raises after ``max_restores`` consecutive-run faults.
    """
    backend = backend or solver.default_backend
    frontier = solver.resolve_frontier(frontier, backend)
    round_backend = "host" if backend == "jit" else backend
    if round_backend == "host":
        frontier = "replicated"
    halo_dtype = solver.resolve_halo_dtype(halo_dtype, round_backend, frontier)
    tol = solver.tol if tol is None else tol
    max_rounds = solver.max_rounds if max_rounds is None else max_rounds
    sr = solver.problem.semiring
    sched = solver.schedule(delta)
    x_ext0 = solver._x_ext(x0)
    q = solver.resolve_query(q)
    rnd = solver._compiled_round(sched, x_ext0, q, round_backend, frontier, halo_dtype)
    ck = SolveCheckpointer(ckpt_dir, every=every, keep=keep)

    x_ext = x_ext0
    rounds = 0
    resumed_at = None
    residuals: list[float] = []
    if resume:
        got = ck.restore_latest()
        if got is not None:
            step, tree = got
            arr = np.asarray(tree["x_ext"])
            if arr.shape == tuple(np.shape(x_ext0)):
                x_ext = jnp.asarray(arr, dtype=sr.dtype)
                rounds = resumed_at = step
                residuals = [float(v) for v in tree.get("residuals", ())]
                _restore_ef(rnd, tree)

    times: list[float] = []
    executed = 0
    restores = 0
    converged = False
    res = float("inf")
    while rounds < max_rounds and not converged:
        try:
            fire("solver.round", round=rounds)
            t0 = time.perf_counter()
            x_new = rnd(x_ext)
            x_new.block_until_ready()
            times.append(time.perf_counter() - t0)
            executed += 1
            res = float(solver.problem.residual(x_ext[:-1], x_new[:-1]))
            residuals.append(res)
            x_ext = x_new
            rounds += 1
            if res <= tol:
                converged = True
            elif rounds % every == 0:
                ck.save(rounds, _snapshot_tree(x_ext, residuals, rnd), block=False)
        except (ValueError, TypeError):
            raise
        except Exception:
            restores += 1
            if restores > max_restores:
                raise
            ck.wait()
            got = ck.restore_latest()
            if got is not None:
                step, tree = got
                x_ext = jnp.asarray(np.asarray(tree["x_ext"]), dtype=sr.dtype)
                rounds = step
                residuals = [float(v) for v in tree.get("residuals", ())]
                _restore_ef(rnd, tree)
            else:  # nothing committed yet: cold restart
                x_ext = x_ext0
                rounds = 0
                residuals = []
                if getattr(rnd, "ef_state", None) is not None:
                    rnd.ef_state["ef"] = rnd.ef_init
    ck.save(rounds, _snapshot_tree(x_ext, residuals, rnd), block=True)
    from repro.core.engine import EngineResult

    result = EngineResult.from_run(
        sched,
        sr,
        x_ext,
        rounds=rounds,
        converged=converged,
        residuals=residuals,
        round_times_s=times,
        compile_time_s=solver._last_compile_s,
    )
    solver._last_x = np.asarray(result.x)
    return CheckpointedSolve(
        result=result,
        rounds_executed=executed,
        restores=restores,
        resumed_at=resumed_at,
    )


def restore_delayed_state(directory, like, n_pods: int):
    """Restore the newest :class:`DelayedCommitState` snapshot, elastically.

    ``like`` supplies the tree *structure* only (any pod width); leaf values
    and shapes come from the checkpoint, then
    :func:`repro.dist.delayed_commit.reshard_delayed_state` re-partitions
    onto ``n_pods``.  Same width → bit-identical resume; different width →
    buffered deltas fold into the global store (fixed-point-identical).
    Returns ``(step, state)`` or ``(None, None)``.
    """
    from repro.dist.delayed_commit import reshard_delayed_state

    got = load_latest_flat(directory)
    if got is None:
        return None, None
    step, flat = got
    names, _, treedef = _flatten_with_names(like)
    if any(n not in flat for n in names):
        return None, None  # structure changed — not our snapshot
    state = jax.tree_util.tree_unflatten(treedef, [flat[n] for n in names])
    return step, reshard_delayed_state(state, n_pods)
