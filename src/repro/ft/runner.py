"""Fault-tolerant training runner: checkpoint/restart, straggler detection,
simulated failures.

At 1000+ nodes the mean time between node failures is minutes; the runner's
contract is:

* **checkpoint/restart** — periodic async sharded checkpoints
  (:mod:`repro.ckpt.checkpoint`); on (re)start the newest committed step is
  discovered and restored, elastically re-sharding if the device count
  changed.
* **failure handling** — any step exception triggers restore-from-latest and
  replay; the data pipeline is stateless in ``step`` so replayed batches are
  bit-identical.  ``FailureInjector`` exercises this in tests/examples.
* **straggler detection** — per-step wall times feed an EWMA z-score; steps
  slower than ``z_thresh`` raise a counter, and with delayed commit enabled
  a straggling pod only delays its own flush (δ-bounded staleness) instead of
  stalling the collective every step — the paper's buffering as a
  fault-tolerance mechanism (DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.ft.inject import FaultPlan, FaultSpec, fire

__all__ = ["RunnerConfig", "StragglerMonitor", "FailureInjector", "run_training"]


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    z_thresh: float = 3.0
    max_restarts: int = 10


class StragglerMonitor:
    """EWMA mean/variance of step time; flags z-score outliers."""

    def __init__(self, alpha: float = 0.1, z_thresh: float = 3.0):
        self.alpha = alpha
        self.z_thresh = z_thresh
        self.mean = None
        self.var = 0.0
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        if self.mean is None:
            self.mean = dt
            return False
        z = (dt - self.mean) / max(np.sqrt(self.var), 1e-6)
        slow = z > self.z_thresh
        if slow:
            self.flagged += 1
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return slow


class FailureInjector:
    """Deprecated shim over :class:`repro.ft.inject.FaultPlan`.

    Keeps the original contract — raise at each listed step value, once —
    by compiling ``fail_at`` into one ``train.step`` spec per step.  New
    code should build a :class:`FaultPlan` directly (any site, I/O kinds,
    probabilistic firing) and pass it to :func:`run_training` or activate
    it with :func:`repro.ft.inject.inject`.
    """

    def __init__(self, fail_at=()):
        self.fail_at = sorted({int(s) for s in fail_at})
        self.plan = FaultPlan(
            [FaultSpec(site="train.step", match={"step": s}) for s in self.fail_at]
        )

    def maybe_fail(self, step: int):
        self.plan.fire("train.step", step=int(step))


def run_training(
    state,
    step_fn,
    batch_fn,
    cfg: RunnerConfig,
    injector: FailureInjector | FaultPlan | None = None,
    log_every: int = 10,
    on_metrics=None,
):
    """Drive ``state = step_fn(state, batch_fn(step))`` with FT semantics.

    ``injector`` accepts the legacy :class:`FailureInjector` or a
    :class:`repro.ft.inject.FaultPlan` (fired at site ``"train.step"`` with
    ``step=<step>``); a globally active plan (``inject(...)``) fires too.
    Returns (state, history dict).
    """
    mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
    monitor = StragglerMonitor(z_thresh=cfg.z_thresh)
    state0 = state  # pristine entry state: a cold restart replays from here
    restored_step, restored = mgr.restore_latest(state)
    start = 0
    if restored is not None:
        state = restored
        start = restored_step
    restarts = 0
    history = {"loss": [], "restarts": 0, "stragglers": 0, "ckpts": 0}
    # history["loss"][i] is the loss of step ``base + i``; replay after a
    # restore truncates back to the restored step so no step is counted twice
    base = start

    step = start
    while step < cfg.total_steps:
        try:
            fire("train.step", step=step)
            if isinstance(injector, FaultPlan):
                injector.fire("train.step", step=step)
            elif injector is not None:
                injector.maybe_fail(step)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch_fn(step))
            jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0
            if monitor.observe(dt):
                history["stragglers"] += 1
            loss = float(metrics.get("total_loss", metrics.get("loss", np.nan)))
            history["loss"].append(loss)
            if on_metrics is not None and step % log_every == 0:
                on_metrics(step, metrics, dt)
            step += 1
            if step % cfg.ckpt_every == 0:
                mgr.save(step, state, block=False)
                history["ckpts"] += 1
        except Exception:
            restarts += 1
            history["restarts"] = restarts
            if restarts > cfg.max_restarts:
                raise
            mgr.wait()
            restored_step, restored = mgr.restore_latest(state)
            if restored is not None:
                state = restored
                step = restored_step
            else:
                state = state0  # cold restart: nothing committed yet
                step = 0
                base = 0
            del history["loss"][max(0, step - base) :]
    mgr.save(cfg.total_steps, state, block=True)
    history["ckpts"] += 1
    return state, history
