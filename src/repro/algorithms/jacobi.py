"""Jacobi / block Gauss-Seidel linear solver on the delayed-async engine.

Demonstrates that the engine generalises beyond the paper's two workloads to
any fixed-point iteration ``x' = M x + c`` (here: solving ``A x = b`` for
diagonally dominant ``A`` via the splitting ``x'_i = (b_i − Σ_{j≠i} A_ij x_j)
/ A_ii``).  δ interpolates Jacobi (sync) → Gauss-Seidel (async), which is the
numerical-analysis view of the paper's hybrid (§II-A cites exactly this
Jacobi/Gauss-Seidel contrast for PageRank).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineResult, make_schedule, run_host, run_jit
from repro.core.semiring import PLUS_TIMES
from repro.graphs.formats import CSRGraph

__all__ = ["jacobi_solve"]


def jacobi_solve(
    n: int,
    offdiag_rows: np.ndarray,
    offdiag_cols: np.ndarray,
    offdiag_vals: np.ndarray,
    diag: np.ndarray,
    b: np.ndarray,
    P: int = 8,
    mode: str = "delayed",
    delta: int | None = None,
    tol: float = 1e-6,
    max_rounds: int = 5000,
    host_loop: bool = True,
    min_chunk: int | None = None,
) -> EngineResult:
    """Solve ``A x = b``; A given as off-diagonal COO + diagonal vector."""
    # Pull formulation: edge (col -> row) with value -A_ij / A_ii.
    values = (-offdiag_vals / diag[offdiag_rows]).astype(np.float32)
    graph = CSRGraph.from_edges(
        n, src=offdiag_cols, dst=offdiag_rows, values=values, name="jacobi", dedup=False
    )
    kwargs = {} if min_chunk is None else {"min_chunk": min_chunk}
    sched = make_schedule(graph, P, delta, PLUS_TIMES, mode=mode, **kwargs)

    # b / diag gathered per row; padded slot (row == n) contributes 0.
    b_over_diag_ext = jnp.asarray(
        np.concatenate([(b / diag).astype(np.float32), [0.0]])
    )

    def row_update(old, reduced, rows):
        return b_over_diag_ext[rows] + reduced

    def residual(x_prev, x_new):
        return jnp.sum(jnp.abs(x_new - x_prev))

    x0 = np.zeros(n, dtype=np.float32)
    runner = run_host if host_loop else run_jit
    return runner(sched, PLUS_TIMES, x0, row_update, residual, tol, max_rounds)
