"""Jacobi / block Gauss-Seidel linear solver on the delayed-async engine.

Demonstrates that the engine generalises beyond the paper's two workloads to
any fixed-point iteration ``x' = M x + c`` (here: solving ``A x = b`` for
diagonally dominant ``A`` via the splitting ``x'_i = (b_i − Σ_{j≠i} A_ij x_j)
/ A_ii``).  δ interpolates Jacobi (sync) → Gauss-Seidel (async), which is the
numerical-analysis view of the paper's hybrid (§II-A cites exactly this
Jacobi/Gauss-Seidel contrast for PageRank).

The problem spec lives in :func:`repro.solve.jacobi_problem`;
:func:`jacobi_graph` builds the pull-formulation graph from the COO matrix,
and this wrapper is back-compat sugar over :class:`repro.solve.Solver`.
"""

from __future__ import annotations

import numpy as np

from repro.core.engine import MIN_CHUNK, EngineResult
from repro.graphs.formats import CSRGraph
from repro.solve import Solver, jacobi_problem

__all__ = ["jacobi_solve", "jacobi_graph", "jacobi_problem"]


def jacobi_graph(
    n: int,
    offdiag_rows: np.ndarray,
    offdiag_cols: np.ndarray,
    offdiag_vals: np.ndarray,
    diag: np.ndarray,
) -> CSRGraph:
    """Pull formulation of the Jacobi splitting: edge ``(col -> row)`` with
    value ``-A_ij / A_ii``."""
    values = (-offdiag_vals / diag[offdiag_rows]).astype(np.float32)
    return CSRGraph.from_edges(
        n, src=offdiag_cols, dst=offdiag_rows, values=values, name="jacobi", dedup=False
    )


def jacobi_solve(
    n: int,
    offdiag_rows: np.ndarray,
    offdiag_cols: np.ndarray,
    offdiag_vals: np.ndarray,
    diag: np.ndarray,
    b: np.ndarray,
    P: int = 8,
    delta="auto",
    tol: float = 1e-6,
    max_rounds: int = 5000,
    min_chunk: int | None = None,
    backend: str | None = None,
) -> EngineResult:
    """Solve ``A x = b``; A given as off-diagonal COO + diagonal vector."""
    graph = jacobi_graph(n, offdiag_rows, offdiag_cols, offdiag_vals, diag)
    solver = Solver(
        graph,
        jacobi_problem(diag, b, tol=tol, max_rounds=max_rounds),
        n_workers=P,
        delta=delta,
        backend=backend or "host",
        min_chunk=MIN_CHUNK if min_chunk is None else min_chunk,
    )
    return solver.solve()
