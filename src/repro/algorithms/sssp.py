"""Bellman-Ford SSSP on the delayed-async engine (paper §IV-D).

min-plus pull relaxation with 32-bit integer distances (as in the paper):

``x'[u] = min(x[u], min_{v ∈ in(u)} x[v] + w(v, u))``

Stopping criterion per the paper: no update generated in the last round.

The problem spec lives in :func:`repro.solve.sssp_problem` (the min-label
kernel is shared with connected components); this wrapper is back-compat
sugar over :class:`repro.solve.Solver`.  For multi-source SSSP in one
lowering, use ``solver.solve_batch(multi_source_x0(graph, sources))``.
"""

from __future__ import annotations

from repro.core.engine import MIN_CHUNK, EngineResult
from repro.graphs.formats import CSRGraph
from repro.solve import Solver, sssp_problem

__all__ = ["sssp", "sssp_problem"]


def sssp(
    graph: CSRGraph,
    source: int = 0,
    P: int = 8,
    delta="auto",
    max_rounds: int = 10_000,
    min_chunk: int | None = None,
    backend: str | None = None,
) -> EngineResult:
    """Bellman-Ford from ``source`` with ``P`` workers and commit period δ."""
    solver = Solver(
        graph,
        sssp_problem(source=source, max_rounds=max_rounds),
        n_workers=P,
        delta=delta,
        backend=backend or "host",
        min_chunk=MIN_CHUNK if min_chunk is None else min_chunk,
    )
    return solver.solve()
