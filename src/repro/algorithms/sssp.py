"""Bellman-Ford SSSP on the delayed-async engine (paper §IV-D).

min-plus pull relaxation with 32-bit integer distances (as in the paper):

``x'[u] = min(x[u], min_{v ∈ in(u)} x[v] + w(v, u))``

Stopping criterion per the paper: no update generated in the last round.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineResult, make_schedule, run_host, run_jit
from repro.core.semiring import INT_INF, MIN_PLUS
from repro.graphs.formats import CSRGraph

__all__ = ["sssp"]


def sssp(
    graph: CSRGraph,
    source: int = 0,
    P: int = 8,
    mode: str = "delayed",
    delta: int | None = None,
    max_rounds: int = 10_000,
    host_loop: bool = True,
    min_chunk: int | None = None,
) -> EngineResult:
    """Bellman-Ford from ``source`` in ``mode`` ∈ {sync, async, delayed}."""
    kwargs = {} if min_chunk is None else {"min_chunk": min_chunk}
    sched = make_schedule(graph, P, delta, MIN_PLUS, mode=mode, **kwargs)

    def row_update(old, reduced, rows):
        return jnp.minimum(old, reduced)

    def residual(x_prev, x_new):
        # number of vertices whose distance improved this round
        return jnp.sum((x_prev != x_new).astype(jnp.float32))

    x0 = np.full(graph.n, INT_INF, dtype=np.int32)
    x0[source] = 0
    runner = run_host if host_loop else run_jit
    return runner(sched, MIN_PLUS, x0, row_update, residual, tol=0.5, max_rounds=max_rounds)
