"""Pull-style PageRank on the delayed-async engine (paper §IV-A).

``x'[u] = (1 - d) / n + Σ_{v ∈ in(u)} x[v] · d / outdeg(v)``

Edge values hold ``d / outdeg(v)`` (precomputed by the graph generators), so
the semiring reduction yields the damped sum and ``row_update`` adds the
teleport term.  Convergence follows the paper: total absolute score change
across vertices ≤ 1e-4.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineResult, make_schedule, run_host, run_jit
from repro.core.semiring import PLUS_TIMES
from repro.graphs.formats import CSRGraph

__all__ = ["pagerank"]


def pagerank(
    graph: CSRGraph,
    P: int = 8,
    mode: str = "delayed",
    delta: int | None = None,
    damping: float = 0.85,
    tol: float = 1e-4,
    max_rounds: int = 1000,
    host_loop: bool = True,
    min_chunk: int | None = None,
) -> EngineResult:
    """Run PageRank in ``mode`` ∈ {sync, async, delayed} with ``P`` workers."""
    kwargs = {} if min_chunk is None else {"min_chunk": min_chunk}
    sched = make_schedule(graph, P, delta, PLUS_TIMES, mode=mode, **kwargs)
    teleport = np.float32((1.0 - damping) / graph.n)

    def row_update(old, reduced, rows):
        return teleport + reduced

    def residual(x_prev, x_new):
        return jnp.sum(jnp.abs(x_new - x_prev))

    x0 = np.full(graph.n, 1.0 / graph.n, dtype=np.float32)
    runner = run_host if host_loop else run_jit
    return runner(sched, PLUS_TIMES, x0, row_update, residual, tol, max_rounds)
