"""Pull-style PageRank on the delayed-async engine (paper §IV-A).

``x'[u] = (1 - d) / n + Σ_{v ∈ in(u)} x[v] · d / outdeg(v)``

Edge values hold ``d / outdeg(v)`` (precomputed by the graph generators), so
the semiring reduction yields the damped sum and ``row_update`` adds the
teleport term.  Convergence follows the paper: total absolute score change
across vertices ≤ 1e-4.

The problem spec lives in :func:`repro.solve.pagerank_problem`; this wrapper
is back-compat sugar over :class:`repro.solve.Solver`.  Pass
``delta='sync'|'async'|'auto'|<int>`` and
``backend='host'|'jit'|'sharded'`` to pick the schedule and execution path.
"""

from __future__ import annotations

from repro.core.engine import MIN_CHUNK, EngineResult
from repro.graphs.formats import CSRGraph
from repro.solve import Solver, pagerank_problem

__all__ = ["pagerank", "pagerank_problem"]


def pagerank(
    graph: CSRGraph,
    P: int = 8,
    delta="auto",
    damping: float = 0.85,
    tol: float = 1e-4,
    max_rounds: int = 1000,
    min_chunk: int | None = None,
    backend: str | None = None,
) -> EngineResult:
    """Run PageRank with ``P`` workers and commit period ``delta``."""
    solver = Solver(
        graph,
        pagerank_problem(damping=damping, tol=tol, max_rounds=max_rounds),
        n_workers=P,
        delta=delta,
        backend=backend or "host",
        min_chunk=MIN_CHUNK if min_chunk is None else min_chunk,
    )
    return solver.solve()
