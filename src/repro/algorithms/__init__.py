# Back-compat algorithm wrappers over the unified repro.solve API.
# New code should use repro.solve.Solver with the *_problem factories.
from repro.algorithms.cc import cc_problem, connected_components
from repro.algorithms.jacobi import jacobi_graph, jacobi_problem, jacobi_solve
from repro.algorithms.pagerank import pagerank, pagerank_problem
from repro.algorithms.sssp import sssp, sssp_problem

__all__ = [
    "pagerank",
    "pagerank_problem",
    "sssp",
    "sssp_problem",
    "connected_components",
    "cc_problem",
    "jacobi_solve",
    "jacobi_graph",
    "jacobi_problem",
]
