from repro.algorithms.pagerank import pagerank
from repro.algorithms.sssp import sssp
from repro.algorithms.cc import connected_components
from repro.algorithms.jacobi import jacobi_solve

__all__ = ["pagerank", "sssp", "connected_components", "jacobi_solve"]
