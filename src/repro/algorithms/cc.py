"""Connected components via min-label propagation on the delayed-async engine.

min-plus semiring with all-zero edge weights: the reduction is simply
``min over in-neighbour labels``; ``row_update`` keeps the vertex's own label
in the running min.  Converges when no label changes (same criterion family
as SSSP).  Intended for symmetric graphs.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineResult, make_schedule, run_host, run_jit
from repro.core.semiring import MIN_PLUS
from repro.graphs.formats import CSRGraph

__all__ = ["connected_components"]


def connected_components(
    graph: CSRGraph,
    P: int = 8,
    mode: str = "delayed",
    delta: int | None = None,
    max_rounds: int = 10_000,
    host_loop: bool = True,
    min_chunk: int | None = None,
) -> EngineResult:
    zero_w = graph.with_values(np.zeros(graph.nnz, dtype=np.int32), name=graph.name)
    kwargs = {} if min_chunk is None else {"min_chunk": min_chunk}
    sched = make_schedule(zero_w, P, delta, MIN_PLUS, mode=mode, **kwargs)

    def row_update(old, reduced, rows):
        return jnp.minimum(old, reduced)

    def residual(x_prev, x_new):
        return jnp.sum((x_prev != x_new).astype(jnp.float32))

    x0 = np.arange(graph.n, dtype=np.int32)
    runner = run_host if host_loop else run_jit
    return runner(sched, MIN_PLUS, x0, row_update, residual, tol=0.5, max_rounds=max_rounds)
