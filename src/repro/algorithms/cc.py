"""Connected components via min-label propagation on the delayed-async engine.

min-plus semiring with all-zero edge weights: the reduction is simply
``min over in-neighbour labels``; ``row_update`` keeps the vertex's own label
in the running min.  Converges when no label changes (same criterion family
as SSSP — the two share one kernel pair in :mod:`repro.solve.problem`).
Intended for symmetric graphs.

The problem spec lives in :func:`repro.solve.cc_problem` (its
``edge_values`` hook zeroes the weights, so callers pass the graph as-is);
this wrapper is back-compat sugar over :class:`repro.solve.Solver`.
"""

from __future__ import annotations

from repro.core.engine import MIN_CHUNK, EngineResult
from repro.graphs.formats import CSRGraph
from repro.solve import Solver, cc_problem

__all__ = ["connected_components", "cc_problem"]


def connected_components(
    graph: CSRGraph,
    P: int = 8,
    delta="auto",
    max_rounds: int = 10_000,
    min_chunk: int | None = None,
    backend: str | None = None,
) -> EngineResult:
    """Label propagation with ``P`` workers and commit period ``delta``."""
    solver = Solver(
        graph,
        cc_problem(max_rounds=max_rounds),
        n_workers=P,
        delta=delta,
        backend=backend or "host",
        min_chunk=MIN_CHUNK if min_chunk is None else min_chunk,
    )
    return solver.solve()
