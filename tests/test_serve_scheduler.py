"""Serving tier: BatchStepper, ContinuousScheduler, load generator.

Acceptance-criteria coverage for the continuous-batching tier:

* slot-in bit-identity — a query admitted mid-flight into an open batch
  retires with exactly the state a fresh ``solve_batch`` of that query alone
  returns (the freeze-at-convergence guarantee), for min-plus SSSP *and*
  plus-times PPR (where frozen vs kept-iterating genuinely differ);
* queue invariants — no accepted request is ever dropped, FIFO holds within
  a request class, and backpressure rejects are deterministic in the submit
  sequence;
* the seeded Poisson load generator and both replay disciplines are
  bit-deterministic (same seed → same trace; same trace → same report).
"""

import numpy as np
import pytest

from repro.evolve import EdgeBatch
from repro.graphs.generators import make_graph
from repro.launch.serve_graph import GraphService
from repro.launch.service import (
    ClassPolicy,
    ContinuousScheduler,
    QueryRequest,
    UpdateRequest,
    load_traces,
    poisson_trace,
    replay_continuous,
    replay_fixed,
    save_traces,
)
from repro.solve import (
    BatchStepper,
    Solver,
    multi_source_x0,
    ppr_problem,
    ppr_teleport,
    solve_batch,
    sssp_problem,
)

GRAPH_S = make_graph("kron", scale=8, efactor=8, kind="sssp")
GRAPH_PR = make_graph("twitter", scale=8, efactor=8, kind="pagerank")


def sssp_service(**kw):
    kw.setdefault("n_workers", 4)
    kw.setdefault("delta", 32)
    kw.setdefault("batch_size", 4)
    kw.setdefault("min_chunk", 8)
    kw.setdefault("algos", ("sssp",))
    return GraphService(GRAPH_S, **kw)


def ppr_service(**kw):
    kw.setdefault("n_workers", 4)
    kw.setdefault("delta", 32)
    kw.setdefault("batch_size", 4)
    kw.setdefault("min_chunk", 8)
    kw.setdefault("algos", ("ppr",))
    return GraphService(GRAPH_PR, **kw)


class TestBatchStepper:
    def test_lone_query_matches_solve_batch(self):
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=4, delta=32, min_chunk=8)
        ref = solve_batch(solver, multi_source_x0(GRAPH_S, [0]))
        st = BatchStepper(solver, capacity=4)
        st.admit(multi_source_x0(GRAPH_S, [0])[0], tag="a")
        retired = []
        while not retired:
            retired = st.run(4)
        (row,) = retired
        assert row.converged and row.rounds == ref.rounds
        np.testing.assert_array_equal(row.x, ref.x[0])

    def test_free_slots_ride_along_preconverged(self):
        """Occupancy 1 of 4: empty slots must not block retirement."""
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=4, delta=32, min_chunk=8)
        st = BatchStepper(solver, capacity=4)
        assert st.free_slots == 4
        st.admit(multi_source_x0(GRAPH_S, [7])[0], tag="x")
        retired = st.run(1000)
        assert len(retired) == 1 and retired[0].converged
        assert st.occupancy == 0 and st.free_slots == 4

    def test_admit_full_raises(self):
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=4, delta=32, min_chunk=8)
        st = BatchStepper(solver, capacity=2)
        for s in (0, 1):
            st.admit(multi_source_x0(GRAPH_S, [s])[0], tag=s)
        with pytest.raises(ValueError, match="no free slots"):
            st.admit(multi_source_x0(GRAPH_S, [2])[0], tag=2)

    def test_budget_exhausted_retires_unconverged(self):
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=4, delta=32, min_chunk=8)
        st = BatchStepper(solver, capacity=2, max_rounds=1)
        st.admit(multi_source_x0(GRAPH_S, [0])[0], tag="t")
        retired = st.run(1)
        assert len(retired) == 1 and not retired[0].converged
        assert retired[0].rounds == 1


class TestSlotInBitIdentity:
    """The tentpole guarantee: mid-flight admission never changes answers."""

    def test_sssp_staggered_admissions(self):
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=4, delta=32, min_chunk=8)
        sources = [0, 7, 33]
        refs = {s: solve_batch(solver, multi_source_x0(GRAPH_S, [s])) for s in sources}
        st = BatchStepper(solver, capacity=4)
        done = {}
        for s in sources:  # admit one new query per quantum, mid-flight
            st.admit(multi_source_x0(GRAPH_S, [s])[0], tag=s)
            for row in st.run(2):
                done[row.tag] = row
        while st.occupancy:
            for row in st.run(2):
                done[row.tag] = row
        assert set(done) == set(sources)
        for s in sources:
            assert done[s].converged
            assert done[s].rounds == refs[s].rounds
            np.testing.assert_array_equal(done[s].x, refs[s].x[0])

    def test_ppr_staggered_admissions(self):
        """plus-times is where freeze-at-convergence is load-bearing: without
        it, a retired row would keep refining while its batchmates run."""
        solver = Solver(GRAPH_PR, ppr_problem(), n_workers=4, delta=32, min_chunk=8)
        seeds = [3, 11, 40]
        x0 = np.full((1, GRAPH_PR.n), 1.0 / GRAPH_PR.n, np.float32)
        refs = {
            s: solve_batch(solver, x0, q=ppr_teleport(GRAPH_PR, [s]))
            for s in seeds
        }
        st = BatchStepper(solver, capacity=4)
        done = {}
        for s in seeds:
            st.admit(x0[0], q=ppr_teleport(GRAPH_PR, [s])[0], tag=s)
            for row in st.run(3):
                done[row.tag] = row
        while st.occupancy:
            for row in st.run(3):
                done[row.tag] = row
        for s in seeds:
            assert done[s].converged
            assert done[s].rounds == refs[s].rounds
            np.testing.assert_array_equal(done[s].x, refs[s].x[0])


class TestSchedulerInvariants:
    def test_no_request_dropped(self):
        svc = sssp_service(batch_size=2, queue_capacity=32)
        ids = []
        for v in range(11):
            adm = svc.submit(QueryRequest(algo="sssp", payload=v))
            assert adm.accepted
            ids.append(adm.request_id)
        results = svc.drain()
        assert sorted(r.request_id for r in results) == sorted(ids)
        assert all(r.converged for r in results)
        st = svc.scheduler.stats()
        assert st["counters"]["accepted"] == st["counters"]["completed"] == 11
        assert st["queue_depth"] == 0 and st["in_flight"] == 0

    def test_fifo_within_class(self):
        svc = sssp_service(batch_size=2, queue_capacity=32)
        ids = [
            svc.submit(QueryRequest(algo="sssp", payload=v)).request_id
            for v in range(9)
        ]
        results = svc.drain()
        by_seq = [r.request_id for r in sorted(results, key=lambda r: r.admit_seq)]
        assert by_seq == ids  # one class, one lane: admission order = FIFO

    def test_backpressure_deterministic(self):
        svc = sssp_service(batch_size=2, queue_capacity=3)
        outcomes = [
            svc.submit(QueryRequest(algo="sssp", payload=v)).accepted
            for v in range(8)
        ]
        # queue bounds admission before any pump: exactly capacity accepted
        assert outcomes == [True] * 3 + [False] * 5
        assert svc.scheduler.rejections == {"queue_full": 5}
        assert len(svc.drain()) == 3

    def test_rejection_reasons(self):
        svc = sssp_service()
        sched = ContinuousScheduler({"road": svc}, queue_capacity=4)
        cases = [
            (QueryRequest(algo="sssp", payload=0, graph="nope"), "unknown_graph"),
            (QueryRequest(algo="ppr", payload=0, graph="road"), "unsupported_algo"),
            (
                QueryRequest(algo="sssp", payload=0, graph="road", request_class="vip"),
                "unknown_class",
            ),
            (
                QueryRequest(algo="sssp", payload=GRAPH_S.n, graph="road"),
                "payload_out_of_range",
            ),
        ]
        for req, reason in cases:
            adm = sched.submit(req)
            assert not adm.accepted and adm.reason == reason

    def test_results_bit_identical_to_fresh_solve(self):
        svc = sssp_service(batch_size=2)
        for v in (0, 5, 9):
            assert svc.submit(QueryRequest(algo="sssp", payload=v)).accepted
        for r in svc.drain():
            ref = solve_batch(svc.solver("sssp"), multi_source_x0(GRAPH_S, [r.payload]))
            assert r.rounds == ref.rounds
            np.testing.assert_array_equal(r.x, ref.x[0])

    def test_class_policy_routing(self):
        classes = {
            "cheap": ClassPolicy(name="cheap", slot_rounds=2, delta=16),
            "deep": ClassPolicy(name="deep", slot_rounds=8, delta=64),
        }
        road = sssp_service(classes=classes)
        social = ppr_service(classes=classes)
        sched = ContinuousScheduler(
            {"road": road, "social": social}, classes=classes, queue_capacity=8
        )
        sched.submit(QueryRequest(algo="sssp", payload=1, graph="road"))
        sched.submit(QueryRequest(algo="ppr", payload=1, graph="social"))
        results = {r.algo: r for r in sched.drain()}
        assert results["sssp"].request_class == "deep"
        assert results["ppr"].request_class == "cheap"
        assert results["sssp"].delta == 64  # class δ overrides the service's
        assert results["ppr"].delta == 16
        assert set(sched.stats()["lanes"]) == {
            "road/sssp/deep",
            "social/ppr/cheap",
        }

    def test_clock_fields_consistent(self):
        svc = sssp_service(batch_size=2)
        for v in range(5):
            svc.submit(QueryRequest(algo="sssp", payload=v))
        for r in svc.drain():
            assert 0 <= r.submitted_clock <= r.admitted_clock <= r.finished_clock
            assert r.queue_rounds >= 0 and r.service_rounds >= 1


class TestLoadgen:
    def test_seeded_trace_deterministic(self):
        kw = dict(seed=3, graph_for={"sssp": ("road",), "ppr": ("social",)})
        t1 = poisson_trace(0.2, 100, 256, **kw)
        t2 = poisson_trace(0.2, 100, 256, **kw)
        assert t1 == t2
        assert t1 != poisson_trace(0.2, 100, 256, seed=4, graph_for=kw["graph_for"])
        assert all((e.graph == "road") == (e.algo == "sssp") for e in t1.events)

    def test_trace_roundtrip(self, tmp_path):
        tr = poisson_trace(0.3, 50, 256, seed=1)
        path = save_traces(tmp_path / "t.json", [tr])
        (back,) = load_traces(path)
        assert back == tr

    def test_replay_continuous_deterministic(self):
        tr = poisson_trace(
            0.15, 80, 256, seed=5, graph_for={"sssp": ("default",)}, mix=(("sssp", 1),)
        )

        def run():
            sched = ContinuousScheduler(
                {"default": sssp_service(batch_size=2)}, queue_capacity=8
            )
            rep = dict(replay_continuous(sched, tr)["report"])
            rep.pop("wall_s")
            return rep

        assert run() == run()

    def test_fixed_vs_continuous_same_offered_load(self):
        tr = poisson_trace(
            0.15, 80, 256, seed=5, graph_for={"sssp": ("default",)}, mix=(("sssp", 1),)
        )
        sched = ContinuousScheduler(
            {"default": sssp_service(batch_size=2)}, queue_capacity=8
        )
        cont = replay_continuous(sched, tr)["report"]
        fixed = replay_fixed(
            {"default": sssp_service(batch_size=2)},
            tr,
            batch_size=2,
            queue_capacity=8,
        )["report"]
        assert cont["offered"] == fixed["offered"] == len(tr.events)
        assert cont["completed"] + cont["rejected"] == cont["offered"]
        assert fixed["completed"] + fixed["rejected"] == fixed["offered"]
        assert cont["unconverged"] == 0


def _delete_batch(g, k=1, seed=0):
    """k existing edges of ``g`` as a delete batch."""
    rng = np.random.default_rng(seed)
    dst = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(g.indptr))
    pick = rng.choice(g.nnz, size=k, replace=False)
    return EdgeBatch.from_ops(
        deletes=[(int(g.indices[e]), int(dst[e])) for e in pick]
    )


class TestUpdateLifecycle:
    def test_update_applies_at_idle_round_boundary(self):
        service = sssp_service()
        g = service.graph
        v = int(np.argmax(g.out_degree))
        batch = _delete_batch(g)
        adm = service.submit_update(UpdateRequest(batch=batch))
        assert adm.accepted and adm.request_id.startswith("u")
        assert not service.scheduler.idle  # pending update counts
        service.submit(QueryRequest(algo="sssp", payload=v))
        results = service.drain()
        (ur,) = service.take_update_results()
        assert (ur.inserted, ur.deleted, ur.reweighted) == (0, 1, 0)
        assert ur.affected_rows == 1
        assert ur.applied_clock >= ur.submitted_clock
        g2, _ = g.apply_updates(batch)
        ref = solve_batch(
            Solver(g2, sssp_problem(), n_workers=4, delta=32, min_chunk=8),
            multi_source_x0(g2, [v]),
        )
        np.testing.assert_array_equal(results[0].x, ref.x[0])
        assert service.scheduler.idle
        assert service.take_update_results() == []  # cleared on read

    def test_inflight_queries_retire_on_pre_update_snapshot(self):
        # 2-round quanta keep the first query in flight across several pumps
        service = sssp_service(compact_every=2)
        g = service.graph
        v = int(np.argmax(g.out_degree))
        a1 = service.submit(QueryRequest(algo="sssp", payload=v))
        early = service.pump()
        assert service.scheduler.in_flight == 1
        batch = _delete_batch(g)
        service.submit_update(UpdateRequest(batch=batch))
        a2 = service.submit(QueryRequest(algo="sssp", payload=v))
        results = {r.request_id: r for r in early + service.drain()}
        (ur,) = service.take_update_results()
        old_ref = solve_batch(
            Solver(g, sssp_problem(), n_workers=4, delta=32, min_chunk=8),
            multi_source_x0(g, [v]),
        )
        g2, _ = g.apply_updates(batch)
        new_ref = solve_batch(
            Solver(g2, sssp_problem(), n_workers=4, delta=32, min_chunk=8),
            multi_source_x0(g2, [v]),
        )
        np.testing.assert_array_equal(results[a1.request_id].x, old_ref.x[0])
        np.testing.assert_array_equal(results[a2.request_id].x, new_ref.x[0])
        # the barrier is visible in the round clock: the update waited for
        # the in-flight query to retire before applying
        assert ur.applied_clock >= results[a1.request_id].finished_clock
        assert ur.barrier_rounds > 0

    def test_update_rejection_reasons(self):
        service = sssp_service()
        g = service.graph
        sched = service.scheduler
        bad_graph = sched.submit_update(
            UpdateRequest(batch=_delete_batch(g), graph="nope")
        )
        assert (bad_graph.accepted, bad_graph.reason) == (False, "unknown_graph")
        oob = sched.submit_update(
            UpdateRequest(batch=EdgeBatch.from_ops(deletes=[(0, g.n + 3)]))
        )
        assert (oob.accepted, oob.reason) == (False, "payload_out_of_range")
        assert sched.rejections == {"unknown_graph": 1, "payload_out_of_range": 1}

    def test_per_graph_quota_spans_queries_and_updates(self):
        service = sssp_service(queue_capacity=64, per_graph_quota=3)
        g = service.graph
        v = int(np.argmax(g.out_degree))
        adms = [
            service.submit(QueryRequest(algo="sssp", payload=v)) for _ in range(5)
        ]
        assert [a.accepted for a in adms] == [True] * 3 + [False] * 2
        assert {a.reason for a in adms[3:]} == {"quota_exceeded"}
        over = service.submit_update(UpdateRequest(batch=_delete_batch(g)))
        assert (over.accepted, over.reason) == (False, "quota_exceeded")
        service.drain()  # quota frees as queued work is admitted
        again = service.submit_update(UpdateRequest(batch=_delete_batch(g)))
        assert again.accepted
        service.drain()
        assert len(service.take_update_results()) == 1

    def test_updates_fifo_per_graph(self):
        service = sssp_service()
        g = service.graph
        b1 = _delete_batch(g, k=1, seed=0)
        g2, _ = g.apply_updates(b1)
        b2 = _delete_batch(g2, k=2, seed=1)
        u1 = service.submit_update(UpdateRequest(batch=b1))
        u2 = service.submit_update(UpdateRequest(batch=b2))
        service.drain()
        ur = service.take_update_results()
        assert [r.request_id for r in ur] == [u1.request_id, u2.request_id]
        assert [r.deleted for r in ur] == [1, 2]
        assert service.graph.nnz == g.nnz - 3

    def test_counters_track_update_lifecycle(self):
        service = sssp_service()
        service.submit_update(UpdateRequest(batch=_delete_batch(service.graph)))
        c = service.scheduler.counters
        assert c["updates_submitted"] == 1 and c["updates_applied"] == 0
        service.drain()
        c = service.scheduler.counters
        assert c["updates_applied"] == 1
