"""backend="pallas": the fused one-kernel round in the production hot path.

Acceptance coverage for the Pallas fused-round backend:

* ``Solver(backend="pallas")`` is bit-identical to ``backend="jit"`` for
  pagerank / sssp / cc / jacobi, in every discipline (sync / async /
  delayed) — fixed point AND per round (the house parity bar: the kernel
  runs the same semiring ops in the same commit-step order, interpret mode
  on CPU CI);
* query-parameterized PPR runs on the kernel, unbatched and batched
  (``solve_batch(backend="pallas")`` vmaps the fused round);
* a hypothesis property test drives random graphs × P × δ × semiring
  through the fused round against the engine's XLA reference round
  (mirroring ``tests/test_frontier_sharded.py``);
* the solver caches pallas executables under their own key — switching
  backends never recompiles the other, and a second solve is warm.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms.jacobi import jacobi_graph
from repro.core.engine import (
    make_schedule,
    round_fn,
    round_fn_pallas,
    round_fn_pallas_q,
)
from repro.core.semiring import INT_INF, MIN_PLUS, PLUS_TIMES
from repro.graphs.formats import CSRGraph
from repro.graphs.generators import make_graph
from repro.solve import (
    Solver,
    cc_problem,
    jacobi_problem,
    multi_source_x0,
    pagerank_problem,
    ppr_problem,
    ppr_teleport,
    solve_batch,
    sssp_problem,
)

N_WORKERS = 8

GRAPH_PR = make_graph("twitter", scale=9, efactor=8, kind="pagerank")
GRAPH_S = make_graph("kron", scale=8, efactor=8, kind="sssp")
GRAPH_U = make_graph("road", scale=8, kind="unit")


def _jacobi_case():
    rng = np.random.default_rng(0)
    n = 256
    rows = np.repeat(np.arange(n), 4)
    cols = (rows + rng.integers(1, n, rows.shape[0])) % n
    vals = rng.normal(size=rows.shape[0]).astype(np.float32) * 0.1
    diag = np.full(n, 4.0, np.float32)
    b = rng.normal(size=n).astype(np.float32)
    return jacobi_graph(n, rows, cols, vals, diag), jacobi_problem(diag, b)


CASES = {
    "pagerank": lambda: (GRAPH_PR, pagerank_problem()),
    "sssp": lambda: (GRAPH_S, sssp_problem()),
    "cc": lambda: (GRAPH_U, cc_problem()),
    "jacobi": _jacobi_case,
}

# The paper's three disciplines, as Solver δ arguments.
MODES = {"sync": "sync", "async": "async", "delayed": 48}


class TestFourProblemParity:
    @pytest.mark.parametrize("mode", sorted(MODES))
    @pytest.mark.parametrize("name", sorted(CASES))
    def test_fixed_point_bit_identical_to_jit(self, name, mode):
        graph, problem = CASES[name]()
        solver = Solver(
            graph, problem, n_workers=N_WORKERS, delta=MODES[mode], min_chunk=16
        )
        r_jit = solver.solve(backend="jit")
        r_pal = solver.solve(backend="pallas")
        assert r_pal.rounds == r_jit.rounds
        assert r_pal.converged == r_jit.converged
        np.testing.assert_array_equal(r_pal.x, r_jit.x)

    @pytest.mark.parametrize("name", sorted(CASES))
    def test_per_round_bit_identical(self, name):
        graph, problem = CASES[name]()
        solver = Solver(graph, problem, n_workers=N_WORKERS, delta=48, min_chunk=16)
        rnd_host = solver.round_callable(backend="host")
        rnd_pal = solver.round_callable(backend="pallas")
        x_h = x_p = solver._x_ext(None)
        for _ in range(3):
            x_h, x_p = rnd_host(x_h), rnd_pal(x_p)
            # owned frontier identical; the dump slot sees different (but
            # equally meaningless) last-writer races between the paths
            np.testing.assert_array_equal(np.asarray(x_h[:-1]), np.asarray(x_p[:-1]))

    def test_counter_semantics_match_jit(self):
        """Same while-loop, same EngineResult authority: flush counters and
        timing normalization are untouched by the round swap."""
        solver = Solver(
            GRAPH_PR, pagerank_problem(), n_workers=N_WORKERS, delta=64, min_chunk=16
        )
        r_jit = solver.solve(backend="jit")
        r_pal = solver.solve(backend="pallas")
        assert r_pal.flushes == r_jit.flushes
        assert r_pal.flush_bytes == r_jit.flush_bytes
        assert r_pal.delta == r_jit.delta and r_pal.P == r_jit.P
        assert r_pal.total_time_s > 0


class TestQueryThreading:
    def test_ppr_unbatched_matches_jit(self):
        solver = Solver(
            GRAPH_PR, ppr_problem(), n_workers=N_WORKERS, delta=64, min_chunk=16
        )
        q = ppr_teleport(GRAPH_PR, [5])[0]
        r_jit = solver.solve(q=q, backend="jit")
        r_pal = solver.solve(q=q, backend="pallas")
        assert r_pal.rounds == r_jit.rounds
        np.testing.assert_array_equal(r_pal.x, r_jit.x)

    def test_ppr_default_query_matches_pagerank(self):
        r_pr = Solver(
            GRAPH_PR, pagerank_problem(), n_workers=N_WORKERS, delta=64, min_chunk=16
        ).solve(backend="pallas")
        r_ppr = Solver(
            GRAPH_PR, ppr_problem(), n_workers=N_WORKERS, delta=64, min_chunk=16
        ).solve(backend="pallas")
        np.testing.assert_array_equal(r_pr.x, r_ppr.x)

    def test_ppr_batch_matches_jit_batch(self):
        solver = Solver(
            GRAPH_PR, ppr_problem(), n_workers=N_WORKERS, delta=64, min_chunk=16
        )
        seeds = [3, 11]
        q = ppr_teleport(GRAPH_PR, seeds)
        x0 = np.tile(np.full(GRAPH_PR.n, 1.0 / GRAPH_PR.n, np.float32), (2, 1))
        b_jit = solve_batch(solver, x0, q=q)
        b_pal = solve_batch(solver, x0, q=q, backend="pallas")
        assert b_pal.rounds == b_jit.rounds
        np.testing.assert_array_equal(b_pal.x, b_jit.x)
        for i, s in enumerate(seeds):
            assert b_pal.x[i].argmax() == s


class TestBatch:
    def test_multi_source_sssp_matches_jit_batch(self):
        solver = Solver(
            GRAPH_S, sssp_problem(), n_workers=N_WORKERS, delta=32, min_chunk=8
        )
        x0 = multi_source_x0(GRAPH_S, [0, 7, 33])
        b_jit = solve_batch(solver, x0)
        b_pal = solve_batch(solver, x0, backend="pallas")
        assert b_pal.rounds == b_jit.rounds
        np.testing.assert_array_equal(b_pal.x, b_jit.x)
        np.testing.assert_array_equal(b_pal.rounds_per_query, b_jit.rounds_per_query)

    def test_q1_bit_identical_to_unbatched(self):
        solver = Solver(
            GRAPH_S, sssp_problem(), n_workers=N_WORKERS, delta=32, min_chunk=8
        )
        r = solver.solve(backend="pallas")
        b = solve_batch(solver, multi_source_x0(GRAPH_S, [0]), backend="pallas")
        assert b.rounds == r.rounds and b.Q == 1
        np.testing.assert_array_equal(b.x[0], r.x)

    def test_pallas_default_backend_routes_batches(self):
        """A pallas-default solver batches on the fused kernel without an
        explicit backend= at the call site."""
        solver = Solver(
            GRAPH_S,
            sssp_problem(),
            n_workers=N_WORKERS,
            delta=32,
            backend="pallas",
            min_chunk=8,
        )
        x0 = multi_source_x0(GRAPH_S, [0, 7])
        b = solve_batch(solver, x0)
        ref = solve_batch(solver, x0, backend="jit")
        np.testing.assert_array_equal(b.x, ref.x)
        assert ("batch", "pallas", "replicated", 32, 2) in solver._compiled

    def test_compaction_on_pallas(self):
        solver = Solver(
            GRAPH_S, sssp_problem(), n_workers=N_WORKERS, delta=32, min_chunk=8
        )
        x0 = multi_source_x0(GRAPH_S, list(range(6)))
        full = solve_batch(solver, x0, backend="pallas")
        comp = solve_batch(solver, x0, backend="pallas", compact_every=2)
        np.testing.assert_array_equal(comp.x, full.x)
        np.testing.assert_array_equal(comp.rounds_per_query, full.rounds_per_query)


class TestCache:
    def test_second_solve_warm(self):
        solver = Solver(
            GRAPH_PR, pagerank_problem(), n_workers=N_WORKERS, delta=128, min_chunk=16
        )
        r1 = solver.solve(backend="pallas")
        snap = dict(solver.stats)
        r2 = solver.solve(backend="pallas")
        assert solver.stats["traces"] == snap["traces"]
        assert solver.stats["compiles"] == snap["compiles"]
        assert r1.compile_time_s > 0.0 and r2.compile_time_s == 0.0
        np.testing.assert_array_equal(r1.x, r2.x)

    def test_pallas_key_distinct_from_jit(self):
        solver = Solver(
            GRAPH_PR, pagerank_problem(), n_workers=N_WORKERS, delta=128, min_chunk=16
        )
        solver.solve(backend="jit")
        solver.solve(backend="pallas")
        d = solver.schedule().delta
        # jit compiles the shape-polymorphic dynamic-schedule loop (survives
        # apply_updates); pallas keys on the concrete schedule
        assert any(k[0] == "dyn" and k[1] == "jit" for k in solver._compiled)
        assert ("pallas", d) in solver._compiled
        assert solver.stats["compiles"] == 2
        # schedule is shared: one stripe build serves both round flavours
        assert solver.stats["schedule_builds"] == 1

    def test_validation(self):
        with pytest.raises(ValueError, match="backend must be one of"):
            Solver(GRAPH_S, sssp_problem(), backend="mosaic")
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=N_WORKERS, delta=32)
        # halo now composes with pallas (the fused sharded round) — only the
        # single-device backends reject it
        with pytest.raises(ValueError, match="requires backend='sharded'"):
            solver.solve(backend="jit", frontier="halo")
        r_halo = solver.solve(backend="pallas", frontier="halo")
        r_jit = solver.solve(backend="jit")
        np.testing.assert_array_equal(r_halo.x, r_jit.x)
        # low-precision halo needs a floating semiring; sssp is min-plus int32
        with pytest.raises(ValueError, match="floating-point semiring"):
            solver.solve(backend="pallas", frontier="halo", halo_dtype="int8")


class TestServeGraphPallas:
    def test_service_on_pallas_matches_jit(self):
        from repro.launch.serve_graph import GraphService

        from repro.launch.service import QueryRequest

        kwargs = dict(n_workers=N_WORKERS, delta=32, batch_size=2, min_chunk=8)
        base = GraphService(GRAPH_S, **kwargs)
        pallas = GraphService(GRAPH_S, backend="pallas", **kwargs)
        for svc in (base, pallas):
            for s in (0, 7):
                assert svc.submit(QueryRequest(algo="sssp", payload=s)).accepted
        d_base = {r.payload: r.x for r in base.drain()}
        d_pallas = {r.payload: r.x for r in pallas.drain()}
        for s in (0, 7):
            np.testing.assert_array_equal(d_base[s], d_pallas[s])

    def test_cli_accepts_pallas(self):
        from repro.launch.serve_graph import main

        argv = (
            "--graph kron --scale 8 --queries 2 --repeats 2 --delta 32 "
            "--backend pallas --algo sssp"
        )
        report = main(argv.split())
        stats = report["stats"]["sssp"]
        assert stats["schedule_builds"] == 1 and stats["compiles"] == 1


# --------------------------------------------------------------------------- #
# Property test: fused pallas round ≡ XLA round on random graphs × P × δ
# --------------------------------------------------------------------------- #
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    SETTINGS = dict(
        deadline=None,
        max_examples=15,
        suppress_health_check=[HealthCheck.too_slow],
    )

    @st.composite
    def random_case(draw):
        n = draw(st.integers(min_value=8, max_value=96))
        m = draw(st.integers(min_value=1, max_value=5 * n))
        seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        semiring = draw(st.sampled_from(["plus_times", "min_plus"]))
        P = draw(st.integers(min_value=1, max_value=6))
        delta = draw(st.integers(min_value=1, max_value=24))
        rng = np.random.default_rng(seed)
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)
        if semiring == "min_plus":
            vals = rng.integers(1, 64, m).astype(np.int32)
        else:
            vals = (rng.random(m) * 0.2).astype(np.float32)
        g = CSRGraph.from_edges(n, src, dst, vals, name=f"p{seed}")
        return g, semiring, P, delta, seed

    @given(random_case())
    @settings(**SETTINGS)
    def test_pallas_round_bit_identical_property(case):
        g, sr_name, P, delta, seed = case
        sr = MIN_PLUS if sr_name == "min_plus" else PLUS_TIMES
        sched = make_schedule(g, P, delta, sr)
        rng = np.random.default_rng(seed)
        if sr_name == "min_plus":
            row_update = lambda o, r, w: jnp.minimum(o, r)
            x0 = rng.integers(0, INT_INF, g.n, dtype=np.int32)
        else:
            row_update = lambda o, r, w: jnp.float32(0.01) + r
            x0 = rng.random(g.n).astype(np.float32)
        ref = jax.jit(round_fn(sched, sr, row_update))
        pal = jax.jit(round_fn_pallas(sched, sr, row_update))
        x = jnp.concatenate(
            [jnp.asarray(x0, sr.dtype), jnp.asarray([sr.zero], sr.dtype)]
        )
        x_ref = x_pal = x
        for _ in range(3):
            x_ref = ref(x_ref)
            x_pal = pal(x_pal)
            np.testing.assert_array_equal(
                np.asarray(x_ref[:-1]), np.asarray(x_pal[:-1])
            )

    @given(random_case())
    @settings(**SETTINGS)
    def test_pallas_round_q_threads_query_property(case):
        """The q-threaded fused round matches the XLA q round on random
        teleport vectors (the PPR shape, any graph)."""
        g, sr_name, P, delta, seed = case
        if sr_name == "min_plus":
            return  # q threading is a plus-times (teleport) concern
        from repro.core.engine import round_fn_q

        sr = PLUS_TIMES
        sched = make_schedule(g, P, delta, sr)
        rng = np.random.default_rng(seed)
        row_update_q = lambda o, r, w, q: q[w] + r
        q = jnp.asarray(rng.random(g.n).astype(np.float32))
        x = jnp.concatenate(
            [jnp.asarray(rng.random(g.n).astype(np.float32)), jnp.zeros(1, jnp.float32)]
        )
        ref = jax.jit(round_fn_q(sched, sr, row_update_q))
        pal = jax.jit(round_fn_pallas_q(sched, sr, row_update_q))
        x_ref, x_pal = ref(x, q), pal(x, q)
        np.testing.assert_array_equal(np.asarray(x_ref[:-1]), np.asarray(x_pal[:-1]))
