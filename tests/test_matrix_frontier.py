"""Matrix-valued frontiers: (n, F) state through every backend.

Acceptance coverage for the (N, F) engine generalization:

* **F=1 bit-identity** — a ``(n, 1)`` frontier produces bit-for-bit the same
  answer, round count, flush counters, and residual trajectory as the
  historical ``(n,)`` vector engine, on every (backend, frontier) pair in
  ``BACKEND_FRONTIERS`` (host / jit / pallas / sharded / sharded+halo /
  pallas+halo);
* ``rwr_embedding_problem(feature_dim=1)`` is bit-identical to
  :func:`ppr_problem` with the matching teleport vector (cross-factory
  parity), and each column of an F=4 RWR solve matches an independent
  per-column PPR solve at the convergence tolerance;
* ``label_propagation_problem`` converges under sync / async / delayed
  disciplines on the clustered ``"web"`` generator and recovers cluster
  structure (anchor purity);
* batched matrix solves (``solve_batch`` and :class:`BatchStepper`) carry the
  feature axis and scale ``flush_bytes`` by F;
* the serving tier answers ``"rwr"`` / ``"labelprop"`` requests with
  ``(n, F)`` results;
* a hypothesis property test drives random graphs × P × δ × F through the
  matrix round against F independent vector rounds.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import extend_frontier, make_schedule, round_fn
from repro.core.semiring import MIN_PLUS, PLUS_TIMES
from repro.graphs.formats import CSRGraph
from repro.graphs.generators import make_graph
from repro.launch.serve_graph import GraphService
from repro.launch.service.types import QueryRequest
from repro.solve import (
    BatchStepper,
    Solver,
    default_landmarks,
    label_propagation_problem,
    labelprop_anchors,
    multi_source_x0,
    pagerank_problem,
    ppr_problem,
    ppr_teleport,
    rwr_embedding_problem,
    rwr_restart,
    solve_batch,
    sssp_problem,
)

N_WORKERS = 8
DELTA = 16

GRAPH_PR = make_graph("twitter", scale=9, efactor=8, kind="pagerank")
GRAPH_S = make_graph("kron", scale=8, efactor=8, kind="sssp")
GRAPH_WEB = make_graph("web", scale=9, efactor=8, kind="pagerank")

# every (backend, frontier) pair of repro.solve.BACKEND_FRONTIERS
ALL_PATHS = [
    ("host", "replicated"),
    ("jit", "replicated"),
    ("pallas", "replicated"),
    ("sharded", "replicated"),
    ("sharded", "halo"),
    ("pallas", "halo"),
]


def _case(name):
    if name == "pagerank":
        return GRAPH_PR, pagerank_problem()
    return GRAPH_S, sssp_problem()


# --------------------------------------------------------------------- #
# F=1 bit-identity: the load-bearing invariant
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend,frontier", ALL_PATHS)
@pytest.mark.parametrize("problem_name", ["pagerank", "sssp"])
def test_f1_bit_identical_to_vector_engine(backend, frontier, problem_name):
    """(n, 1) must reproduce the vector engine exactly: values, rounds,
    flush counters, and the full residual trajectory."""
    g, prob = _case(problem_name)
    s = Solver(
        g, prob, n_workers=N_WORKERS, delta=DELTA, backend=backend,
        frontier=frontier,
    )
    r_vec = s.solve()
    x1 = np.asarray(prob.x0(g)).reshape(-1, 1)
    r_mat = s.solve(x1)
    assert r_mat.x.shape == (g.n, 1)
    assert np.array_equal(np.asarray(r_mat.x)[:, 0], np.asarray(r_vec.x))
    assert r_mat.rounds == r_vec.rounds
    assert r_mat.flushes == r_vec.flushes
    assert np.array_equal(
        np.asarray(r_mat.residuals, np.float32),
        np.asarray(r_vec.residuals, np.float32),
    )


def test_f1_flush_bytes_match_vector():
    g, prob = _case("pagerank")
    s = Solver(g, prob, n_workers=N_WORKERS, delta=DELTA, backend="host")
    r_vec = s.solve()
    r_mat = s.solve(np.asarray(prob.x0(g)).reshape(-1, 1))
    assert r_mat.flush_bytes == r_vec.flush_bytes


def test_matrix_flush_bytes_scale_with_f():
    g = GRAPH_PR
    s1 = Solver(
        g, rwr_embedding_problem(feature_dim=1), n_workers=N_WORKERS,
        delta=DELTA, backend="jit",
    )
    s4 = Solver(
        g, rwr_embedding_problem(feature_dim=4), n_workers=N_WORKERS,
        delta=DELTA, backend="jit",
    )
    r1, r4 = s1.solve(), s4.solve()
    per_round_1 = r1.flush_bytes / r1.rounds
    per_round_4 = r4.flush_bytes / r4.rounds
    assert per_round_4 == 4 * per_round_1


# --------------------------------------------------------------------- #
# round-level parity: matrix round == stacked vector rounds
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("semiring_name", ["plus_times", "min_plus"])
def test_matrix_round_equals_stacked_vector_rounds(semiring_name):
    if semiring_name == "plus_times":
        g, sr = GRAPH_PR, PLUS_TIMES
        cols = [np.asarray(pagerank_problem().x0(g)) for _ in range(3)]
        cols[1] = cols[1] * 2.0
        cols[2] = np.linspace(0.0, 1.0, g.n, dtype=np.float32)

        def row_update(old, reduced, rows):
            return reduced
    else:
        g, sr = GRAPH_S, MIN_PLUS
        cols = [
            np.asarray(multi_source_x0(g, [s])[0]) for s in (0, g.n // 2, g.n - 1)
        ]

        def row_update(old, reduced, rows):
            return jnp.minimum(old, reduced)

    sched = make_schedule(g, N_WORKERS, DELTA, sr, mode="delayed")
    rnd = round_fn(sched, sr, row_update)
    X = np.stack(cols, axis=1)
    out_mat = np.asarray(rnd(extend_frontier(X, sr)))
    for f, col in enumerate(cols):
        out_vec = np.asarray(rnd(extend_frontier(col, sr)))
        assert np.array_equal(out_mat[:, f], out_vec), f"column {f} diverged"


# --------------------------------------------------------------------- #
# the new problem factories
# --------------------------------------------------------------------- #
def test_rwr_f1_bit_identical_to_ppr():
    g = GRAPH_PR
    seed = int(default_landmarks(g.n, 1)[0])
    ppr = Solver(g, ppr_problem(), n_workers=N_WORKERS, delta=DELTA, backend="jit")
    r_ppr = ppr.solve(q=ppr_teleport(g, [seed], 0.85)[0])
    rwr = Solver(
        g, rwr_embedding_problem(feature_dim=1), n_workers=N_WORKERS,
        delta=DELTA, backend="jit",
    )
    r_rwr = rwr.solve()
    assert r_rwr.x.shape == (g.n, 1)
    assert np.array_equal(np.asarray(r_rwr.x)[:, 0], np.asarray(r_ppr.x))
    assert r_rwr.rounds == r_ppr.rounds


@pytest.mark.parametrize("backend", ["host", "jit", "pallas", "sharded"])
def test_rwr_columns_match_per_column_ppr(backend):
    g = GRAPH_PR
    F = 4
    tol = 1e-6
    rwr = Solver(
        g, rwr_embedding_problem(feature_dim=F, tol=tol), n_workers=N_WORKERS,
        delta=DELTA, backend=backend,
    )
    r = rwr.solve()
    assert r.converged and r.x.shape == (g.n, F)
    ppr = Solver(
        g, ppr_problem(tol=tol), n_workers=N_WORKERS, delta=DELTA, backend="jit"
    )
    for f, seed in enumerate(default_landmarks(g.n, F)):
        ref = ppr.solve(q=ppr_teleport(g, [int(seed)], 0.85)[0])
        np.testing.assert_allclose(
            np.asarray(r.x)[:, f], np.asarray(ref.x), atol=5e-6
        )


@pytest.mark.parametrize("delta", ["sync", "async", DELTA])
def test_labelprop_converges_and_recovers_clusters(delta):
    g = GRAPH_WEB  # block-diagonal clustered power-law (~95% intra-cluster)
    F = 4
    prob = label_propagation_problem(feature_dim=F)
    s = Solver(g, prob, n_workers=N_WORKERS, delta=delta, backend="jit")
    r = s.solve()
    assert r.converged
    lab = np.asarray(r.x)
    assert lab.shape == (g.n, F)
    # rows stay distributions over classes
    np.testing.assert_allclose(lab.sum(axis=1), 1.0, atol=1e-5)
    # anchors keep their one-hot labels (the clamp)
    anchors = default_landmarks(g.n, F)
    assert np.array_equal(np.argmax(lab[anchors], axis=1), np.arange(F))
    # labels are informative, not uniform: most rows have a clear winner
    frac_decided = float((lab.max(axis=1) > 1.5 / F).mean())
    assert frac_decided > 0.5, frac_decided


def test_labelprop_disciplines_agree_on_hard_labels():
    g = GRAPH_WEB
    prob = label_propagation_problem(feature_dim=4)
    hard = []
    for delta in ("sync", "async", 64):
        r = Solver(g, prob, n_workers=N_WORKERS, delta=delta, backend="jit").solve()
        hard.append(np.argmax(np.asarray(r.x), axis=1))
    agree = float((hard[0] == hard[1]).mean())
    assert agree > 0.95, agree
    agree = float((hard[0] == hard[2]).mean())
    assert agree > 0.95, agree


# --------------------------------------------------------------------- #
# batching
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ["jit", "sharded"])
def test_solve_batch_matrix(backend):
    g = GRAPH_PR
    F, Q = 4, 3
    prob = rwr_embedding_problem(feature_dim=F)
    s = Solver(g, prob, n_workers=N_WORKERS, delta=DELTA, backend="jit")
    seeds = default_landmarks(g.n, F)
    X = np.stack([np.asarray(prob.x0(g))] * Q)
    qs = np.stack(
        [np.asarray(rwr_restart(g, (seeds + i) % g.n)) for i in range(Q)]
    )
    br = solve_batch(s, X, q=qs, backend=backend)
    assert br.x.shape == (Q, g.n, F)
    assert br.converged.all()
    # each batch row equals its unbatched solve
    for i in range(Q):
        ref = s.solve(q=qs[i])
        np.testing.assert_allclose(br.x[i], np.asarray(ref.x), atol=1e-6)


def test_solve_batch_matrix_shape_validation():
    g = GRAPH_PR
    prob = rwr_embedding_problem(feature_dim=4)
    s = Solver(g, prob, n_workers=N_WORKERS, delta=DELTA, backend="jit")
    with pytest.raises(ValueError, match="x0_batch must be"):
        solve_batch(s, np.zeros((2, g.n + 1, 4), np.float32), q=np.zeros((2,)))


def test_batch_stepper_matrix_slots():
    g = GRAPH_PR
    F = 4
    prob = rwr_embedding_problem(feature_dim=F)
    s = Solver(g, prob, n_workers=N_WORKERS, delta=DELTA, backend="jit")
    stepper = BatchStepper(s, capacity=2)
    seeds = default_landmarks(g.n, F)
    q = rwr_restart(g, seeds)
    with pytest.raises(ValueError, match="x0 must have shape"):
        stepper.admit(np.asarray(prob.x0(g))[:, 0], q=q)  # (n,) into an F=4 lane
    stepper.admit(np.asarray(prob.x0(g)), q=q, tag="a")
    retired = []
    while not retired:
        retired = stepper.run(quantum=8)
    (row,) = retired
    assert row.converged and row.x.shape == (g.n, F)
    ref = s.solve(q=q)
    np.testing.assert_allclose(row.x, np.asarray(ref.x), atol=1e-6)


def test_solver_x0_shape_validation():
    g = GRAPH_PR
    s = Solver(g, pagerank_problem(), n_workers=N_WORKERS, delta=DELTA)
    with pytest.raises(ValueError, match="x0 must have shape"):
        s.solve(np.zeros(g.n + 1, np.float32))
    with pytest.raises(ValueError, match="x0 must have shape"):
        s.solve(np.zeros((g.n + 1, 2), np.float32))


# --------------------------------------------------------------------- #
# serving tier
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("algo", ["rwr", "labelprop"])
def test_service_matrix_algos(algo):
    g = GRAPH_WEB
    F = 3
    service = GraphService(
        g, n_workers=N_WORKERS, delta=DELTA, batch_size=2,
        algos=(algo,), feature_dim=F,
    )
    for payload in (1, g.n // 2):
        adm = service.submit(QueryRequest(algo=algo, payload=payload))
        assert adm.accepted, adm.reason
    out = service.drain()
    assert len(out) == 2
    for r in out:
        assert r.x.shape == (g.n, F)
        assert r.converged


# --------------------------------------------------------------------- #
# hypothesis property test: random graphs × P × δ × F
# --------------------------------------------------------------------- #
def _random_graph(rng, n, avg_deg):
    rows = np.repeat(np.arange(n), avg_deg)
    cols = rng.integers(0, n, rows.shape[0])
    vals = rng.random(rows.shape[0]).astype(np.float32)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRGraph(
        n=n,
        indptr=indptr,
        indices=cols.astype(np.int64),
        values=vals,
        name="rand",
    )


def test_property_matrix_round_matches_vector_columns():
    hyp = pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(
        deadline=None, max_examples=15,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        seed=st.integers(0, 2**16),
        n_workers=st.sampled_from([2, 4, 8]),
        delta=st.sampled_from([4, 16, 64]),
        F=st.integers(1, 3),
    )
    def inner(seed, n_workers, delta, F):
        rng = np.random.default_rng(seed)
        g = _random_graph(rng, n=128, avg_deg=4)
        sched = make_schedule(g, n_workers, delta, PLUS_TIMES, mode="delayed")
        rnd = round_fn(sched, PLUS_TIMES, lambda old, reduced, rows: reduced)
        X = rng.random((g.n, F)).astype(np.float32)
        out = np.asarray(rnd(extend_frontier(X, PLUS_TIMES)))
        for f in range(F):
            ref = np.asarray(rnd(extend_frontier(X[:, f], PLUS_TIMES)))
            assert np.array_equal(out[:, f], ref)

    del hyp
    inner()
