"""Delayed-commit ↔ synchronous equivalence and flush accounting.

The training-scale mirror of the engine invariants: δ=1 recovers the fully
synchronous step (as S==1 recovers Jacobi), and commits happen exactly every
δ steps — the flush counter is ``steps // δ``.
"""

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data.pipeline import SyntheticLM
from repro.dist.delayed_commit import (
    DelayedCommitConfig,
    init_delayed_state,
    make_delayed_commit_step,
)
from repro.train.optimizer import AdamW, constant
from repro.train.train_step import init_train_state, make_train_step

CFG = get_reduced("minicpm-2b")
KEY = jax.random.PRNGKey(1)


def pod_batch(step, n_pods, B=4, S=32):
    data = SyntheticLM(vocab=CFG.vocab, seq_len=S, global_batch=B)
    b = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
    return b, jax.tree.map(lambda x: jnp.stack([x] * n_pods), b)


def test_delta1_losses_match_sync_step():
    """δ=1 with identical pod batches tracks make_train_step loss-for-loss."""
    opt = AdamW(schedule=constant(1e-3))
    cc = DelayedCommitConfig(n_pods=2, delta=1)
    ds = init_delayed_state(CFG, opt, cc, KEY)
    ss = init_train_state(CFG, opt, KEY)
    dstep = jax.jit(make_delayed_commit_step(CFG, opt, cc))
    sstep = jax.jit(make_train_step(CFG, opt))
    for step in range(5):
        b, bp = pod_batch(step, 2)
        ds, dm = dstep(ds, bp)
        ss, sm = sstep(ss, b)
        assert abs(float(dm["total_loss"]) - float(sm["total_loss"])) < 1e-5


def test_flush_counter_is_steps_over_delta():
    opt = AdamW(schedule=constant(1e-3))
    for delta, steps in [(1, 4), (2, 5), (3, 9)]:
        cc = DelayedCommitConfig(n_pods=2, delta=delta)
        ds = init_delayed_state(CFG, opt, cc, KEY)
        dstep = jax.jit(make_delayed_commit_step(CFG, opt, cc))
        flushes = 0
        for step in range(steps):
            _, bp = pod_batch(step, 2)
            ds, m = dstep(ds, bp)
            flushes += int(m["committed"])
        assert flushes == steps // delta
