"""CSRGraph construction edge cases: from_edges, with_values, apply_updates.

The mutation layer (``repro.evolve``) leans on CSR canonical order — edges
sorted by ``dst * n + src``, stable — far harder than the static pipeline
ever did, so the constructors' corner semantics (duplicate edges, self
loops, isolated vertices, the empty graph) are pinned here, along with the
``apply_updates`` / ``inverse`` bit-identical round trip they enable.
"""

import numpy as np
import pytest

from repro.evolve import EdgeBatch
from repro.graphs.formats import CSRGraph
from repro.graphs.generators import make_graph


class TestFromEdges:
    def test_duplicate_edges_keep_first_occurrence(self):
        g = CSRGraph.from_edges(
            4,
            src=[0, 0, 1, 0],
            dst=[1, 1, 2, 1],
            values=np.array([10.0, 20.0, 30.0, 40.0], np.float32),
        )
        assert g.nnz == 2  # (0->1) deduped, (1->2) kept
        e = g.indptr[1]
        assert g.indices[e] == 0 and g.values[e] == 10.0  # first occurrence wins

    def test_dedup_false_keeps_parallel_edges(self):
        g = CSRGraph.from_edges(4, src=[0, 0], dst=[1, 1], dedup=False)
        assert g.nnz == 2
        assert np.array_equal(g.indices[g.indptr[1] : g.indptr[2]], [0, 0])

    def test_self_loops_preserved(self):
        g = CSRGraph.from_edges(3, src=[1, 0], dst=[1, 2])
        assert g.nnz == 2
        assert g.indices[g.indptr[1] : g.indptr[2]].tolist() == [1]

    def test_isolated_vertices_have_empty_rows(self):
        g = CSRGraph.from_edges(5, src=[0], dst=[4])
        assert g.n == 5 and g.nnz == 1
        assert np.array_equal(np.diff(g.indptr), [0, 0, 0, 0, 1])
        assert g.in_degree.tolist() == [0, 0, 0, 0, 1]
        assert g.out_degree.tolist() == [1, 0, 0, 0, 0]

    def test_empty_graph(self):
        g = CSRGraph.from_edges(3, src=[], dst=[])
        assert g.n == 3 and g.nnz == 0
        assert np.array_equal(g.indptr, np.zeros(4, dtype=np.int64))

    def test_default_values_are_unit_float32(self):
        g = CSRGraph.from_edges(3, src=[0, 1], dst=[1, 2])
        assert g.values.dtype == np.float32
        assert np.array_equal(g.values, np.ones(2, np.float32))

    def test_canonical_order_is_dst_major_src_minor(self):
        g = CSRGraph.from_edges(4, src=[3, 1, 2, 0], dst=[2, 2, 1, 1])
        # within each destination row, sources ascend
        for v in range(g.n):
            row = g.indices[g.indptr[v] : g.indptr[v + 1]]
            assert np.array_equal(row, np.sort(row))


class TestWithValues:
    def test_replaces_values_keeps_topology(self):
        g = CSRGraph.from_edges(3, src=[0, 1], dst=[1, 2])
        w = np.array([5, 7], np.int32)
        g2 = g.with_values(w, name="reweighted")
        assert g2.name == "reweighted"
        assert np.array_equal(g2.values, w)
        assert g2.indptr is g.indptr and g2.indices is g.indices

    def test_wrong_length_rejected(self):
        g = CSRGraph.from_edges(3, src=[0, 1], dst=[1, 2])
        with pytest.raises(AssertionError):
            g.with_values(np.ones(3, np.float32))


class TestApplyUpdatesRoundTrip:
    def test_inverse_restores_graph_bit_identically(self):
        g = make_graph("kron", scale=7, efactor=8, kind="sssp", seed=4)
        src = g.indices.astype(np.int64)
        dst = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(g.indptr))
        rng = np.random.default_rng(0)
        pick = rng.choice(g.nnz, size=6, replace=False)
        keys = set((dst * g.n + src).tolist())
        inserts = []
        while len(inserts) < 3:
            s, d = (int(v) for v in rng.integers(0, g.n, size=2))
            if s == d or d * g.n + s in keys:
                continue
            keys.add(d * g.n + s)
            inserts.append((s, d, int(rng.integers(1, 256))))
        batch = EdgeBatch.from_ops(
            inserts=inserts,
            deletes=[(int(src[e]), int(dst[e])) for e in pick[:3]],
            reweights=[
                (int(src[e]), int(dst[e]), int(rng.integers(1, 256))) for e in pick[3:]
            ],
        )
        g2, report = g.apply_updates(batch)
        assert g2.nnz == g.nnz  # +3 inserts, -3 deletes
        g3, _ = g2.apply_updates(batch.inverse(report))
        np.testing.assert_array_equal(g3.indptr, g.indptr)
        np.testing.assert_array_equal(g3.indices, g.indices)
        np.testing.assert_array_equal(g3.values, g.values)

    def test_strict_semantics_reject_bad_ops(self):
        g = CSRGraph.from_edges(3, src=[0], dst=[1], values=np.ones(1, np.float32))
        with pytest.raises(ValueError):
            g.apply_updates(EdgeBatch.from_ops(inserts=[(0, 1, 2.0)]))  # exists
        with pytest.raises(ValueError):
            g.apply_updates(EdgeBatch.from_ops(deletes=[(1, 2)]))  # missing
        with pytest.raises(ValueError):
            g.apply_updates(EdgeBatch.from_ops(reweights=[(2, 0, 1.0)]))  # missing

    def test_empty_graph_accepts_insert_only_batches(self):
        g = CSRGraph.from_edges(4, src=[], dst=[])
        g2, report = g.apply_updates(
            EdgeBatch.from_ops(inserts=[(0, 1, 1.0), (1, 2, 1.0)])
        )
        assert g2.nnz == 2 and report.inserted == 2
        with pytest.raises(ValueError):
            g.apply_updates(EdgeBatch.from_ops(deletes=[(0, 1)]))

    def test_affected_rows_are_exactly_the_touched_destinations(self):
        g = CSRGraph.from_edges(
            5, src=[0, 1, 2], dst=[1, 2, 3], values=np.ones(3, np.float32)
        )
        _, report = g.apply_updates(
            EdgeBatch.from_ops(
                inserts=[(3, 4, 1.0)], deletes=[(0, 1)], reweights=[(1, 2, 9.0)]
            )
        )
        assert report.affected_rows.tolist() == [1, 2, 4]
