"""Frontier-sharded (owner-computes + halo-exchange) engine: exactness first.

Acceptance coverage for the frontier="halo" distribution discipline:

* frontier-sharded rounds are bit-identical to ``backend="jit"`` for all
  four problems (pagerank / sssp / cc / jacobi) — fixed point AND per round;
* a hypothesis property test drives random graphs × P × δ through the halo
  round against the single-device reference round;
* :class:`FrontierPlan` invariants: scatter/gather roundtrip, halo wire
  accounting below the replicated flush;
* batched sharded solving (replicated + halo) matches the jit batch, and
  ``compact_every`` (straggler compaction) preserves results while shrinking
  flush traffic.

Device-count adaptive: with 1 local device the mesh is 1-wide (halo sets are
empty but the full exchange machinery still runs); under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI matrix entry)
the same tests exercise real 8-way sharding.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms.jacobi import jacobi_graph
from repro.core.engine import make_schedule, round_fn
from repro.core.semiring import INT_INF, MIN_PLUS, PLUS_TIMES
from repro.dist.compat import make_mesh
from repro.dist.engine_sharded import (
    frontier_plan_args,
    frontier_round_ext_fn,
    make_frontier_plan,
)
from repro.graphs.formats import CSRGraph
from repro.graphs.generators import make_graph
from repro.solve import (
    Solver,
    cc_problem,
    jacobi_problem,
    multi_source_x0,
    pagerank_problem,
    ppr_problem,
    ppr_teleport,
    solve_batch,
    sssp_problem,
)

N_WORKERS = 8


def mesh_width() -> int:
    """Largest power-of-two device count dividing N_WORKERS."""
    return math.gcd(N_WORKERS, len(jax.devices()))


GRAPH_PR = make_graph("twitter", scale=9, efactor=8, kind="pagerank")
GRAPH_S = make_graph("kron", scale=8, efactor=8, kind="sssp")
GRAPH_U = make_graph("road", scale=8, kind="unit")


def _jacobi_case():
    rng = np.random.default_rng(0)
    n = 256
    rows = np.repeat(np.arange(n), 4)
    cols = (rows + rng.integers(1, n, rows.shape[0])) % n
    vals = rng.normal(size=rows.shape[0]).astype(np.float32) * 0.1
    diag = np.full(n, 4.0, np.float32)
    b = rng.normal(size=n).astype(np.float32)
    return jacobi_graph(n, rows, cols, vals, diag), jacobi_problem(diag, b)


CASES = {
    "pagerank": lambda: (GRAPH_PR, pagerank_problem()),
    "sssp": lambda: (GRAPH_S, sssp_problem()),
    "cc": lambda: (GRAPH_U, cc_problem()),
    "jacobi": _jacobi_case,
}


class TestFourProblemParity:
    @pytest.mark.parametrize("name", sorted(CASES))
    def test_fixed_point_bit_identical_to_jit(self, name):
        graph, problem = CASES[name]()
        solver = Solver(graph, problem, n_workers=N_WORKERS, delta=48, min_chunk=16)
        r_jit = solver.solve(backend="jit")
        r_halo = solver.solve(backend="sharded", frontier="halo")
        assert r_halo.rounds == r_jit.rounds
        np.testing.assert_array_equal(r_halo.x, r_jit.x)

    @pytest.mark.parametrize("name", sorted(CASES))
    def test_per_round_bit_identical(self, name):
        graph, problem = CASES[name]()
        solver = Solver(graph, problem, n_workers=N_WORKERS, delta=48, min_chunk=16)
        rnd_host = solver.round_callable(backend="host")
        rnd_halo = solver.round_callable(backend="sharded", frontier="halo")
        x_h = x_s = solver._x_ext(None)
        for _ in range(3):
            x_h, x_s = rnd_host(x_h), rnd_halo(x_s)
            # owned frontier identical; the local dump slots differ by design
            np.testing.assert_array_equal(np.asarray(x_h[:-1]), np.asarray(x_s[:-1]))

    def test_ppr_query_threading_both_frontiers(self):
        solver = Solver(
            GRAPH_PR, ppr_problem(), n_workers=N_WORKERS, delta=64, min_chunk=16
        )
        q = ppr_teleport(GRAPH_PR, [5])[0]
        r_jit = solver.solve(q=q, backend="jit")
        r_rep = solver.solve(q=q, backend="sharded", frontier="replicated")
        r_halo = solver.solve(q=q, backend="sharded", frontier="halo")
        assert r_jit.rounds == r_rep.rounds == r_halo.rounds
        np.testing.assert_array_equal(r_jit.x, r_rep.x)
        np.testing.assert_array_equal(r_jit.x, r_halo.x)


class TestFrontierPlan:
    def _sched_plan(self, delta=32):
        sched = make_schedule(GRAPH_PR, N_WORKERS, delta, PLUS_TIMES)
        D = mesh_width()
        return sched, make_frontier_plan(sched, D), D

    def test_scatter_gather_roundtrip(self):
        sched, plan, _ = self._sched_plan()
        x_ext = jnp.concatenate(
            [jnp.arange(sched.n, dtype=jnp.float32), jnp.zeros((1,), jnp.float32)]
        )
        x_loc = plan.scatter_x(x_ext)
        assert x_loc.shape == (plan.D, plan.L)
        back = plan.gather_x(x_loc, dump=x_ext[-1:])
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x_ext))
        # halo slots hold their owners' values
        for d in range(plan.D):
            h = plan.halo_sizes[d]
            owned = plan.vertex_bounds[d + 1] - plan.vertex_bounds[d]
            if h:
                got = np.asarray(x_loc)[d, owned : owned + h]
                exp = np.asarray(x_ext)[
                    np.asarray(plan.gather_index)[d, owned : owned + h]
                ]
                np.testing.assert_array_equal(got, exp)

    def test_wire_accounting(self):
        sched, plan, D = self._sched_plan()
        assert plan.replicated_bytes_per_round(4) == sched.S * sched.P * sched.delta * 4
        assert plan.halo_bytes_per_round(4) == plan.S * plan.D * plan.H * 4
        if D > 1:
            # halo never ships more rows than the full flush publishes
            assert plan.boundary_entries_per_round <= sched.S * sched.P * sched.delta

    def test_plan_requires_divisible_workers(self):
        sched = make_schedule(GRAPH_PR, 6, 32, PLUS_TIMES)
        with pytest.raises(ValueError, match="not divisible"):
            make_frontier_plan(sched, 4)

    def test_plan_cached_on_solver(self):
        solver = Solver(
            GRAPH_PR, pagerank_problem(), n_workers=N_WORKERS, delta=64, min_chunk=16
        )
        solver.solve(backend="sharded", frontier="halo")
        snap = dict(solver.stats)
        assert snap["plan_builds"] == 1
        solver.solve(backend="sharded", frontier="halo")
        assert solver.stats["plan_builds"] == 1
        assert solver.stats["traces"] == snap["traces"]
        assert solver.stats["compiles"] == snap["compiles"]


class TestFrontierValidation:
    def test_explicit_halo_requires_sharded(self):
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=N_WORKERS, delta=32)
        with pytest.raises(ValueError, match="requires backend='sharded'"):
            solver.solve(backend="jit", frontier="halo")

    def test_unknown_frontier_rejected(self):
        with pytest.raises(ValueError, match="frontier must be one of"):
            Solver(GRAPH_S, sssp_problem(), frontier="mirrored")

    def test_halo_default_falls_back_for_host_probes(self):
        """δ='auto' probes run backend='host'; a halo-default solver must not
        reject its own probes."""
        solver = Solver(
            GRAPH_PR,
            pagerank_problem(),
            n_workers=N_WORKERS,
            delta="auto",
            backend="sharded",
            frontier="halo",
            min_chunk=16,
        )
        r = solver.solve()
        assert r.converged


class TestShardedBatch:
    def test_batch_matches_jit_batch_both_frontiers(self):
        solver = Solver(
            GRAPH_S, sssp_problem(), n_workers=N_WORKERS, delta=32, min_chunk=8
        )
        x0 = multi_source_x0(GRAPH_S, [0, 7, 33])
        b_jit = solve_batch(solver, x0)
        for frontier in ("replicated", "halo"):
            b = solve_batch(solver, x0, backend="sharded", frontier=frontier)
            assert b.rounds == b_jit.rounds, frontier
            np.testing.assert_array_equal(b.x, b_jit.x)
            np.testing.assert_array_equal(b.rounds_per_query, b_jit.rounds_per_query)

    def test_sharded_q1_matches_unbatched(self):
        solver = Solver(
            GRAPH_S, sssp_problem(), n_workers=N_WORKERS, delta=32, min_chunk=8
        )
        r = solver.solve(backend="sharded", frontier="halo")
        b = solve_batch(
            solver, multi_source_x0(GRAPH_S, [0]), backend="sharded", frontier="halo"
        )
        assert b.rounds == r.rounds
        np.testing.assert_array_equal(b.x[0], r.x)

    def test_ppr_batch_sharded(self):
        solver = Solver(
            GRAPH_PR, ppr_problem(), n_workers=N_WORKERS, delta=64, min_chunk=16
        )
        seeds = [3, 11]
        q = ppr_teleport(GRAPH_PR, seeds)
        x0 = np.tile(np.full(GRAPH_PR.n, 1.0 / GRAPH_PR.n, np.float32), (2, 1))
        b_jit = solve_batch(solver, x0, q=q)
        b_halo = solve_batch(solver, x0, q=q, backend="sharded", frontier="halo")
        np.testing.assert_array_equal(b_jit.x, b_halo.x)


class TestStragglerCompaction:
    def _spread_sources(self, solver):
        probe = solve_batch(solver, multi_source_x0(GRAPH_S, list(range(16))))
        lo = int(probe.rounds_per_query.argmin())
        hi = int(probe.rounds_per_query.argmax())
        assert probe.rounds_per_query[lo] < probe.rounds_per_query[hi]
        return [lo, hi, 3]

    def test_compact_none_is_default_bit_for_bit(self):
        solver = Solver(
            GRAPH_S, sssp_problem(), n_workers=N_WORKERS, delta=32, min_chunk=8
        )
        x0 = multi_source_x0(GRAPH_S, [0, 7])
        a = solve_batch(solver, x0)
        b = solve_batch(solver, x0, compact_every=None)
        assert a.compactions == b.compactions == 0
        np.testing.assert_array_equal(a.x, b.x)
        assert a.rounds == b.rounds and a.flush_bytes == b.flush_bytes

    def test_compact_exact_and_cheaper_minplus(self):
        solver = Solver(
            GRAPH_S, sssp_problem(), n_workers=N_WORKERS, delta=32, min_chunk=8
        )
        x0 = multi_source_x0(GRAPH_S, self._spread_sources(solver))
        full = solve_batch(solver, x0)
        comp = solve_batch(solver, x0, compact_every=2)
        # min-plus is idempotent: compacted answers are exactly the full run's
        np.testing.assert_array_equal(comp.x, full.x)
        np.testing.assert_array_equal(comp.rounds_per_query, full.rounds_per_query)
        assert comp.converged.all()
        assert comp.compactions > 0
        assert comp.flush_bytes < full.flush_bytes
        assert comp.rounds == full.rounds

    def test_compact_with_sharded_backend(self):
        solver = Solver(
            GRAPH_S, sssp_problem(), n_workers=N_WORKERS, delta=32, min_chunk=8
        )
        x0 = multi_source_x0(GRAPH_S, self._spread_sources(solver))
        full = solve_batch(solver, x0)
        comp = solve_batch(
            solver, x0, backend="sharded", frontier="halo", compact_every=2
        )
        np.testing.assert_array_equal(comp.x, full.x)
        np.testing.assert_array_equal(comp.rounds_per_query, full.rounds_per_query)

    def test_compact_rejects_nonpositive(self):
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=N_WORKERS, delta=32)
        with pytest.raises(ValueError, match="compact_every"):
            solve_batch(solver, multi_source_x0(GRAPH_S, [0]), compact_every=0)

    def test_compact_respects_max_rounds(self):
        solver = Solver(
            GRAPH_S, sssp_problem(), n_workers=N_WORKERS, delta=32, min_chunk=8
        )
        x0 = multi_source_x0(GRAPH_S, [0, 7])
        b = solve_batch(solver, x0, compact_every=2, max_rounds=3)
        assert b.rounds <= 3


class TestShardedService:
    def test_serve_graph_sharded_halo_matches_jit(self):
        from repro.launch.serve_graph import GraphService

        from repro.launch.service import QueryRequest

        kwargs = dict(n_workers=N_WORKERS, delta=32, batch_size=2, min_chunk=8)
        base = GraphService(GRAPH_S, **kwargs)
        sharded = GraphService(
            GRAPH_S, backend="sharded", frontier="halo", compact_every=4, **kwargs
        )
        for svc in (base, sharded):
            for s in (0, 7):
                assert svc.submit(QueryRequest(algo="sssp", payload=s)).accepted
        d_base = {r.payload: r.x for r in base.drain()}
        d_shard = {r.payload: r.x for r in sharded.drain()}
        for s in (0, 7):
            np.testing.assert_array_equal(d_base[s], d_shard[s])


# --------------------------------------------------------------------------- #
# Property test: halo round ≡ reference round on random graphs × P × δ
# --------------------------------------------------------------------------- #
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    SETTINGS = dict(
        deadline=None,
        max_examples=15,
        suppress_health_check=[HealthCheck.too_slow],
    )

    @st.composite
    def random_case(draw):
        n = draw(st.integers(min_value=8, max_value=96))
        m = draw(st.integers(min_value=1, max_value=5 * n))
        seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        semiring = draw(st.sampled_from(["plus_times", "min_plus"]))
        p_loc = draw(st.integers(min_value=1, max_value=3))
        delta = draw(st.integers(min_value=1, max_value=24))
        rng = np.random.default_rng(seed)
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)
        if semiring == "min_plus":
            vals = rng.integers(1, 64, m).astype(np.int32)
        else:
            vals = (rng.random(m) * 0.2).astype(np.float32)
        g = CSRGraph.from_edges(n, src, dst, vals, name=f"h{seed}")
        return g, semiring, p_loc, delta, seed

    @given(random_case())
    @settings(**SETTINGS)
    def test_halo_round_bit_identical_property(case):
        g, sr_name, p_loc, delta, seed = case
        D = mesh_width()
        P = D * p_loc
        sr = MIN_PLUS if sr_name == "min_plus" else PLUS_TIMES
        sched = make_schedule(g, P, delta, sr)
        plan = make_frontier_plan(sched, D)
        mesh = make_mesh((D,), ("data",), devices=jax.devices()[:D])
        if sr_name == "min_plus":
            row_update_q = lambda o, r, w, q: jnp.minimum(o, r)
            rng = np.random.default_rng(seed)
            x0 = rng.integers(0, INT_INF, g.n, dtype=np.int32)
        else:
            row_update_q = lambda o, r, w, q: jnp.float32(0.01) + r
            rng = np.random.default_rng(seed)
            x0 = rng.random(g.n).astype(np.float32)
        row_update = lambda o, r, w: row_update_q(o, r, w, None)
        ref = jax.jit(round_fn(sched, sr, row_update))
        ext = jax.jit(frontier_round_ext_fn(sched, plan, sr, row_update_q, mesh))
        args = frontier_plan_args(sched, plan)
        x = jnp.concatenate(
            [jnp.asarray(x0, sr.dtype), jnp.asarray([sr.zero], sr.dtype)]
        )
        x_ref = x_halo = x
        for _ in range(3):
            x_ref = ref(x_ref)
            x_halo = ext(x_halo, jnp.zeros((), jnp.int32), *args)
            np.testing.assert_array_equal(
                np.asarray(x_ref[:-1]), np.asarray(x_halo[:-1])
            )
