"""Evolving graphs: EdgeBatch application, warm restart, targeted invalidation.

Acceptance-criteria coverage for ``repro.evolve``:

* correctness gate — for every problem family (pagerank, ppr, sssp, cc,
  jacobi) and every update kind (insert, delete, reweight),
  ``Solver.resolve(updates=...)`` converges to the cold-solve fixed point on
  the mutated graph: bit-exact labels for min-plus, within the residual
  bound for plus-times — plus a hypothesis property test over random mixed
  batches;
* efficiency gate — incremental re-solves of small batches take strictly
  fewer rounds (median) than cold solves of the same mutated snapshots, and
  a restarted process pointed at the same cache rebuilds only the schedule
  stripes whose rows a mutation touched (the rest load);
* the per-regime δ-model: observations are tagged ``cold``/``incremental``
  and refit into separate round-count curves.
"""

import dataclasses

import numpy as np
import pytest

from repro.algorithms.jacobi import jacobi_graph
from repro.core.delta_model import fit_delta_model, refit_delta_models
from repro.evolve import EdgeBatch, warm_start_state
from repro.graphs.formats import CSRGraph
from repro.graphs.generators import make_graph
from repro.solve import (
    Solver,
    cc_problem,
    jacobi_problem,
    pagerank_problem,
    ppr_problem,
    ppr_teleport,
    sssp_problem,
)

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is a dev dependency
    HAVE_HYPOTHESIS = False

RNG = np.random.default_rng(11)


def _edge_list(g):
    dst = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(g.indptr))
    return g.indices.astype(np.int64), dst


def _pick_edges(g, k, rng, symmetric=False):
    """k distinct existing edges; with ``symmetric`` both directions exist
    and only the canonical (src < dst) representative is returned."""
    src, dst = _edge_list(g)
    if symmetric:
        cand = np.flatnonzero(src < dst)
    else:
        cand = np.arange(g.nnz)
    pick = rng.choice(cand, size=k, replace=False)
    return [(int(src[e]), int(dst[e])) for e in pick]


def _fresh_pairs(g, k, rng, forbid_self=True, symmetric=False):
    """k (src, dst) pairs absent from the graph (both directions if
    ``symmetric``)."""
    src, dst = _edge_list(g)
    keys = set((dst * g.n + src).tolist())
    out = []
    while len(out) < k:
        s, d = (int(v) for v in rng.integers(0, g.n, size=2))
        if forbid_self and s == d:
            continue
        if d * g.n + s in keys or (symmetric and s * g.n + d in keys):
            continue
        keys.add(d * g.n + s)
        if symmetric:
            keys.add(s * g.n + d)
        out.append((s, d))
    return out


def _symmetric_graph(scale=7, seed=3) -> CSRGraph:
    base = make_graph("kron", scale=scale, efactor=8, kind="sssp", seed=seed)
    src, dst = _edge_list(base)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    return CSRGraph.from_edges(
        base.n,
        np.concatenate([src, dst]),
        np.concatenate([dst, src]),
        np.zeros(2 * src.size, dtype=np.int32),
        name="sym",
    )


def _jacobi_system(n=96, seed=5):
    rng = np.random.default_rng(seed)
    m = 3 * n
    rows = rng.integers(0, n, m)
    cols = rng.integers(0, n, m)
    keep = rows != cols
    rows, cols = rows[keep], cols[keep]
    key = rows * n + cols
    _, first = np.unique(key, return_index=True)
    rows, cols = rows[first], cols[first]
    vals = rng.uniform(-1.0, 1.0, rows.size)
    row_sum = np.zeros(n)
    np.add.at(row_sum, rows, np.abs(vals))
    diag = 2.0 * (row_sum + 1.0)  # strictly diagonally dominant
    b = rng.uniform(-1.0, 1.0, n)
    return rows, cols, vals, diag, b


class _Case:
    """One problem family: its graph, problem, query, and batch builders."""

    def __init__(self, name):
        self.name = name
        rng = np.random.default_rng(17)
        if name in ("pagerank", "ppr"):
            self.g = make_graph("kron", scale=7, efactor=8, kind="pagerank", seed=1)
            self.problem = pagerank_problem() if name == "pagerank" else ppr_problem()
            self.q = (
                ppr_teleport(self.g, [int(np.argmax(self.g.out_degree))])[0]
                if name == "ppr"
                else None
            )
            ins_val = rw_val = lambda old=None: 0.05  # noqa: E731
        elif name == "sssp":
            self.g = make_graph("kron", scale=7, efactor=8, kind="sssp", seed=1)
            self.problem = sssp_problem(source=int(np.argmax(self.g.out_degree)))
            self.q = None
            ins_val = rw_val = lambda old=None: int(rng.integers(1, 256))  # noqa: E731
        elif name == "cc":
            self.g = _symmetric_graph()
            self.problem = cc_problem()
            self.q = None
            ins_val = rw_val = lambda old=None: 0  # noqa: E731
        else:  # jacobi
            rows, cols, vals, diag, b = _jacobi_system()
            self.g = jacobi_graph(len(diag), rows, cols, vals, diag)
            self.problem = jacobi_problem(diag, b)
            self.q = None
            ins_val = rw_val = lambda old=None: 0.02  # noqa: E731
        self._rng = rng
        self._ins_val = ins_val
        self._rw_val = rw_val
        self.symmetric = name == "cc"

    def batch(self, kind: str) -> EdgeBatch:
        rng = self._rng
        if kind == "insert":
            pairs = _fresh_pairs(self.g, 3, rng, symmetric=self.symmetric)
            ops = [(s, d, self._ins_val()) for s, d in pairs]
            if self.symmetric:
                ops += [(d, s, v) for s, d, v in ops]
            return EdgeBatch.from_ops(inserts=ops)
        if kind == "delete":
            pairs = _pick_edges(self.g, 3, rng, symmetric=self.symmetric)
            if self.symmetric:
                pairs = pairs + [(d, s) for s, d in pairs]
            return EdgeBatch.from_ops(deletes=pairs)
        pairs = _pick_edges(self.g, 3, rng, symmetric=self.symmetric)
        ops = [(s, d, self._rw_val()) for s, d in pairs]
        if self.symmetric:
            ops += [(d, s, v) for s, d, v in ops]
        return EdgeBatch.from_ops(reweights=ops)


def _solver(g, problem, **kw):
    kw.setdefault("n_workers", 4)
    kw.setdefault("delta", 16)
    kw.setdefault("backend", "host")
    return Solver(g, problem, **kw)


def _assert_fixed_points_match(problem, xi, xc):
    xi, xc = np.asarray(xi), np.asarray(xc)
    if problem.semiring.name == "min_plus":
        np.testing.assert_array_equal(xi, xc)
    else:
        # each run stops within tol of the fixed point in the L1 residual
        # metric; 20·tol bounds the gap between two converged states for
        # every contraction factor used here
        assert np.abs(xi - xc).sum() <= 20 * problem.tol


class TestResolveMatchesCold:
    """Correctness gate: incremental == cold on the mutated graph."""

    @pytest.mark.parametrize("kind", ["insert", "delete", "reweight"])
    @pytest.mark.parametrize("name", ["pagerank", "ppr", "sssp", "cc", "jacobi"])
    def test_resolve_matches_cold(self, name, kind):
        case = _Case(name)
        inc = _solver(case.g, case.problem)
        inc.solve(q=case.q) if case.q is not None else inc.solve()
        batch = case.batch(kind)
        ri = (
            inc.resolve(updates=batch, q=case.q)
            if case.q is not None
            else inc.resolve(updates=batch)
        )
        cold = _solver(inc.graph, case.problem)
        rc = cold.solve(q=case.q) if case.q is not None else cold.solve()
        assert ri.converged and rc.converged
        _assert_fixed_points_match(case.problem, ri.x, rc.x)

    def test_resolve_requires_prior_fixed_point(self):
        case = _Case("sssp")
        sv = _solver(case.g, case.problem)
        with pytest.raises(ValueError, match="warm-starts"):
            sv.resolve(updates=case.batch("delete"))

    def test_resolve_without_updates_is_warm_resolve(self):
        case = _Case("sssp")
        sv = _solver(case.g, case.problem)
        r0 = sv.solve()
        r1 = sv.resolve()
        assert r1.rounds <= 1 + 0 * r0.rounds  # already at the fixed point
        np.testing.assert_array_equal(r0.x, r1.x)

    def test_minplus_delete_cone_reraised(self):
        """A delete that invalidates downstream labels must re-raise them:
        the warm state is never below the new fixed point."""
        case = _Case("sssp")
        inc = _solver(case.g, case.problem)
        x_prev = np.asarray(inc.solve().x)
        batch = case.batch("delete")
        g2, report = inc.graph.apply_updates(batch)
        ev = case.problem.edge_values
        sched2 = g2.with_values(ev(g2)) if ev is not None else g2
        y = warm_start_state(
            case.problem, g2, sched2, x_prev, batch=batch, report=report
        )
        x_new = np.asarray(_solver(g2, case.problem).solve().x)
        assert np.all(y.astype(np.int64) >= x_new.astype(np.int64))


if HAVE_HYPOTHESIS:
    _G_PROP = make_graph("kron", scale=6, efactor=8, kind="sssp", seed=2)
    _PROB_PROP = sssp_problem(source=int(np.argmax(_G_PROP.out_degree)))
    _X_STAR = np.asarray(
        Solver(_G_PROP, _PROB_PROP, n_workers=2, delta=8, backend="host").solve().x
    )

    @given(st.integers(0, 2**31 - 1), st.integers(1, 12))
    @settings(
        deadline=None, max_examples=10, suppress_health_check=[HealthCheck.too_slow]
    )
    def test_resolve_matches_cold_on_random_batches(seed, k):
        """Random mixed insert/delete/reweight batches: bit-exact labels."""
        rng = np.random.default_rng(seed)
        g = _G_PROP
        n_del = rng.integers(0, k + 1)
        n_rw = rng.integers(0, k + 1 - n_del)
        n_ins = k - n_del - n_rw
        picked = _pick_edges(g, int(n_del + n_rw), rng)
        deletes = picked[: int(n_del)]
        reweights = [(s, d, int(rng.integers(1, 256))) for s, d in picked[int(n_del) :]]
        inserts = [
            (s, d, int(rng.integers(1, 256)))
            for s, d in _fresh_pairs(g, int(n_ins), rng)
        ]
        batch = EdgeBatch.from_ops(
            inserts=inserts, deletes=deletes, reweights=reweights
        )
        inc = Solver(g, _PROB_PROP, n_workers=2, delta=8, backend="host")
        ri = inc.resolve(updates=batch, x0=_X_STAR)
        rc = Solver(inc.graph, _PROB_PROP, n_workers=2, delta=8, backend="host").solve()
        np.testing.assert_array_equal(np.asarray(ri.x), np.asarray(rc.x))


class TestEfficiencyGates:
    def test_small_batches_beat_cold_median_rounds(self):
        g = make_graph("kron", scale=8, efactor=8, kind="sssp", seed=6)
        prob = sssp_problem(source=int(np.argmax(g.out_degree)))
        inc = _solver(g, prob, n_workers=4, delta=32)
        inc.solve()
        rng = np.random.default_rng(0)
        inc_rounds, cold_rounds = [], []
        for _ in range(3):
            batch = EdgeBatch.from_ops(deletes=_pick_edges(inc.graph, 8, rng))
            ri = inc.resolve(updates=batch)
            rc = _solver(inc.graph, prob, n_workers=4, delta=32).solve()
            np.testing.assert_array_equal(np.asarray(ri.x), np.asarray(rc.x))
            inc_rounds.append(ri.rounds)
            cold_rounds.append(rc.rounds)
        assert np.median(inc_rounds) < np.median(cold_rounds)

    def test_restarted_process_rebuilds_only_touched_stripes(self, tmp_path):
        """Cross-process targeted invalidation: after an out-of-band mutation
        touching one worker's rows, a fresh solver on the same cache loads
        every other worker's stripe and builds exactly the touched one."""
        g = make_graph("kron", scale=7, efactor=8, kind="sssp", seed=6)
        prob = sssp_problem(source=int(np.argmax(g.out_degree)))
        kw = dict(
            n_workers=4,
            delta=16,
            backend="host",
            partition_method="equal",  # degree-insensitive: bounds survive
            cache_dir=tmp_path,
        )
        s1 = Solver(g, prob, **kw)
        s1.solve()
        assert s1.stats["stripe_builds"] == 4
        assert s1.stats["stripe_loads"] == 0
        bounds = s1.bounds
        src, dst = _edge_list(g)
        block0 = np.flatnonzero(dst < bounds[1])  # rows owned by worker 0
        pick = block0[:2]
        batch = EdgeBatch.from_ops(deletes=[(int(src[e]), int(dst[e])) for e in pick])
        g2, report = g.apply_updates(batch)
        assert np.all(report.affected_rows < bounds[1])
        s2 = Solver(g2, prob, **kw)  # "restarted process"
        r2 = s2.solve()
        assert s2.stats["stripe_builds"] == 1  # only worker 0 rebuilt
        assert s2.stats["stripe_loads"] == 3  # the rest came from the store
        rc = _solver(g2, prob, n_workers=4, delta=16, partition_method="equal").solve()
        np.testing.assert_array_equal(np.asarray(r2.x), np.asarray(rc.x))

    def test_in_process_mutation_persists_touched_stripes(self, tmp_path):
        """apply_updates patches schedules in place AND refreshes the stripe
        store, so the next process is warm for the mutated graph too."""
        g = make_graph("kron", scale=7, efactor=8, kind="sssp", seed=6)
        prob = sssp_problem(source=int(np.argmax(g.out_degree)))
        kw = dict(
            n_workers=4,
            delta=16,
            backend="host",
            partition_method="equal",
            cache_dir=tmp_path,
        )
        s1 = Solver(g, prob, **kw)
        s1.solve()
        rng = np.random.default_rng(3)
        batch = EdgeBatch.from_ops(deletes=_pick_edges(g, 2, rng))
        s1.resolve(updates=batch)
        s2 = Solver(s1.graph, prob, **kw)
        s2.solve()
        assert s2.stats["stripe_builds"] == 0  # every stripe served warm
        assert s2.stats["stripe_loads"] == 4


class TestUpdatePrimitives:
    def test_apply_updates_keeps_partition_and_patches_schedule(self):
        case = _Case("sssp")
        sv = _solver(case.g, case.problem)
        r0 = sv.solve()
        bounds_before = sv.bounds.copy()
        batch = case.batch("delete")
        report = sv.apply_updates(batch)
        assert report.deleted == batch.n_deletes
        np.testing.assert_array_equal(sv.bounds, bounds_before)
        rc = _solver(sv.graph, case.problem).solve()
        r1 = sv.solve()  # cold solve on the patched schedule
        np.testing.assert_array_equal(np.asarray(r1.x), np.asarray(rc.x))
        assert r0.converged and r1.converged

    def test_dyn_backend_replays_compiled_loop_after_mutation(self):
        """The jit backend's dynamic-schedule executable survives
        apply_updates: same (δ, S, M) shape class → zero new traces."""
        case = _Case("sssp")
        sv = Solver(case.g, case.problem, n_workers=4, delta=16, backend="jit")
        sv.solve()
        traces_before = sv.stats["traces"]
        batch = case.batch("reweight")
        ri = sv.resolve(updates=batch)
        assert sv.stats["traces"] == traces_before
        rc = _solver(sv.graph, case.problem).solve()
        np.testing.assert_array_equal(np.asarray(ri.x), np.asarray(rc.x))


class TestPerRegimeDeltaModel:
    def test_observations_tagged_and_refit_per_regime(self, tmp_path):
        g = make_graph("kron", scale=7, efactor=8, kind="sssp", seed=6)
        prob = sssp_problem(source=int(np.argmax(g.out_degree)))
        sv = Solver(g, prob, n_workers=4, delta=16, backend="host", cache_dir=tmp_path)
        sv.solve()
        rng = np.random.default_rng(1)
        sv.resolve(updates=EdgeBatch.from_ops(deletes=_pick_edges(sv.graph, 2, rng)))
        rows = sv.persist.load_observations()
        regimes = {r["regime"] for r in rows}
        assert regimes == {"cold", "incremental"}
        model = fit_delta_model(g, P=4, r_sync=8, r_async=12)
        models = refit_delta_models(model, rows)
        assert set(models) == {"cold", "incremental"}
        # the incremental curve learns the cheaper re-solves
        assert models["incremental"].rounds(16) < models["cold"].rounds(16)

    def test_regime_models_roundtrip_store(self, tmp_path):
        g = make_graph("kron", scale=7, efactor=8, kind="sssp", seed=6)
        prob = sssp_problem(source=0)
        sv = Solver(g, prob, n_workers=4, delta=16, backend="host", cache_dir=tmp_path)
        model = fit_delta_model(g, P=4, r_sync=8, r_async=12)
        inc_model = dataclasses.replace(model, r_sync=2.0, r_async=3.0)
        sv.persist.save_delta_model(model, 64)
        sv.persist.save_delta_model(inc_model, 16, regime="incremental")
        got_cold = sv.persist.load_delta_model()
        got_inc = sv.persist.load_delta_model(regime="incremental")
        assert got_cold is not None and got_cold[1] == 64
        assert got_inc is not None and got_inc[1] == 16
        assert got_inc[0].r_sync == 2.0
        # regime keys are additive: writing one never clobbers the other
        sv.persist.save_delta_model(inc_model, 32, regime="incremental")
        assert sv.persist.load_delta_model()[1] == 64
