"""End-to-end behaviour tests for the paper's system.

1. The three execution disciplines (sync / async / delayed-δ) agree on the
   answer and differ only in rounds + commit traffic (the paper's thesis).
2. δ monotonically trades flush traffic against freshness.
3. The full training driver runs: data → model → optimizer → checkpoint →
   injected failure → restart → final loss improvement.
4. The serving driver generates greedy tokens from prefill + decode.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.algorithms import pagerank, sssp
from repro.graphs.generators import make_graph


class TestPaperThesis:
    def setup_method(self):
        self.g = make_graph("twitter", scale=11, efactor=8, kind="pagerank")

    def test_same_answer_different_schedule(self):
        rs = pagerank(self.g, P=8, delta="sync")
        ra = pagerank(self.g, P=8, delta="async", min_chunk=16)
        rd = pagerank(self.g, P=8, delta=256, min_chunk=16)
        assert np.abs(rs.x - ra.x).max() < 5e-5
        assert np.abs(rs.x - rd.x).max() < 5e-5

    def test_async_fewer_rounds_on_diffuse_graph(self):
        """Paper Table I direction: sharing sooner converges in fewer rounds."""
        rs = pagerank(self.g, P=8, delta="sync")
        ra = pagerank(self.g, P=8, delta="async", min_chunk=16)
        assert ra.rounds < rs.rounds

    def test_delta_interpolates_rounds(self):
        """Hybrid rounds sit between sync and async (freshness monotonicity)."""
        rs = pagerank(self.g, P=8, delta="sync")
        ra = pagerank(self.g, P=8, delta="async", min_chunk=16)
        rd = pagerank(self.g, P=8, delta=512, min_chunk=16)
        assert ra.rounds <= rd.rounds <= rs.rounds

    def test_delta_reduces_flushes_vs_async(self):
        """The hybrid's whole point: fewer commit collectives than async."""
        ra = pagerank(self.g, P=8, delta="async", min_chunk=16)
        rd = pagerank(self.g, P=8, delta=512, min_chunk=16)
        assert rd.flushes / rd.rounds < (ra.flushes / ra.rounds) / 4

    def test_sssp_all_modes_exact(self):
        g = make_graph("twitter", scale=10, efactor=8, kind="sssp")
        rs = sssp(g, P=8, delta="sync")
        ra = sssp(g, P=8, delta="async", min_chunk=16)
        rd = sssp(g, P=8, delta=128, min_chunk=16)
        assert (rs.x == ra.x).all() and (rs.x == rd.x).all()


class TestSharded:
    def test_sharded_engine_matches_reference(self):
        """shard_map worker execution == single-device engine, bit-exact.

        Runs in a subprocess so the 4-device host platform doesn't leak into
        this test session (device count locks on first jax init).
        """
        code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.graphs.generators import make_graph
from repro.core.engine import make_schedule, round_fn
from repro.core.semiring import PLUS_TIMES
from repro.dist.compat import AxisType, make_mesh, set_mesh
from repro.dist.engine_sharded import sharded_round_fn
g = make_graph("web", scale=10, efactor=8, kind="pagerank")
n = g.n; tele = np.float32((1-.85)/n)
sched = make_schedule(g, 4, 64, PLUS_TIMES, mode="delayed")
ru = lambda old, red, rows: tele + red
rnd = jax.jit(round_fn(sched, PLUS_TIMES, ru))
x0 = jnp.concatenate([jnp.full((n,), 1.0/n, jnp.float32), jnp.zeros((1,), jnp.float32)])
x_ref = rnd(rnd(x0))
mesh = make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
srnd = jax.jit(sharded_round_fn(sched, PLUS_TIMES, ru, mesh, axis="data"))
with set_mesh(mesh):
    x_s = srnd(srnd(x0, sched.src, sched.val, sched.dst_local, sched.rows),
               sched.src, sched.val, sched.dst_local, sched.rows)
assert float(jnp.abs(x_ref - x_s).max()) == 0.0, "sharded != reference"
print("OK")
"""
        env = dict(os.environ, PYTHONPATH="src")
        r = subprocess.run(
            [sys.executable, "-c", code],
            capture_output=True,
            text=True,
            env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        assert "OK" in r.stdout, r.stderr[-2000:]


class TestDrivers:
    def test_train_driver_end_to_end(self, tmp_path):
        from repro.launch.train import main

        hist = main(
            [
                "--arch", "minicpm-2b", "--reduced", "--steps", "8",
                "--batch", "4", "--seq", "32", "--ckpt-every", "4",
                "--ckpt-dir", str(tmp_path), "--fail-at", "5",
            ]
        )
        assert hist["restarts"] == 1
        assert len(hist["loss"]) >= 8

    def test_train_driver_delayed_commit(self, tmp_path):
        from repro.launch.train import main

        hist = main(
            [
                "--arch", "granite-8b", "--reduced", "--steps", "6",
                "--batch", "4", "--seq", "32", "--commit-delta", "2",
                "--n-pods", "2", "--ckpt-dir", str(tmp_path),
            ]
        )
        assert len(hist["loss"]) >= 6

    def test_serve_driver(self):
        from repro.configs import get_reduced
        from repro.launch.serve import generate
        from repro.models import init_params

        cfg = get_reduced("recurrentgemma_9b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        prompts = np.zeros((2, 12), np.int32)
        toks = generate(cfg, params, prompts, gen_len=6)
        assert toks.shape == (2, 6)
        assert (np.asarray(toks) >= 0).all() and (np.asarray(toks) < cfg.vocab).all()
