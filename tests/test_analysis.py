"""Analysis tooling: access matrices (Fig 5), δ-model, schedule stats,
input-spec construction for every dry-run cell."""

import jax
import numpy as np
import pytest

from repro.configs import all_arch_ids, get_config
from repro.configs.shapes import SHAPES, applicable_shapes
from repro.core.access_matrix import access_matrix, locality_fraction
from repro.core.delta_model import TPUCostParams, fit_delta_model
from repro.dist.sharding import Rules
from repro.graphs.formats import build_stripe_schedule
from repro.graphs.generators import make_graph
from repro.graphs.partition import balanced_blocks
from repro.launch.specs import input_specs


class TestAccessMatrix:
    def test_web_is_diagonal_kron_is_diffuse(self):
        web = make_graph("web", scale=12, efactor=8, kind="unit")
        kron = make_graph("kron", scale=12, efactor=8, kind="unit")
        P = 16
        loc_web = locality_fraction(access_matrix(web, balanced_blocks(web, P)))
        loc_kron = locality_fraction(access_matrix(kron, balanced_blocks(kron, P)))
        assert loc_web > 0.5 > loc_kron  # the paper's Fig-5 contrast

    def test_matrix_sums_to_edge_count(self):
        g = make_graph("twitter", scale=10, efactor=8, kind="unit")
        mat = access_matrix(g, balanced_blocks(g, 8))
        assert mat.sum() == g.nnz


class TestDeltaModel:
    def setup_method(self):
        self.g = make_graph("twitter", scale=11, efactor=8, kind="pagerank")

    def test_rounds_interpolates_monotonically(self):
        m = fit_delta_model(self.g, 16, r_sync=20, r_async=12, delta_min=16)
        rs = [m.rounds(d) for d in (16, 64, 256, 1024, m.B)]
        assert rs[0] <= rs[-1]
        assert all(a <= b + 1e-9 for a, b in zip(rs, rs[1:]))
        assert abs(rs[-1] - 20) < 1e-6

    def test_locality_discounts_gain(self):
        diffuse = fit_delta_model(self.g, 16, 20, 12, delta_min=16)
        web = make_graph("web", scale=11, efactor=8, kind="pagerank")
        clustered = fit_delta_model(web, 16, 20, 12, delta_min=16)
        # clustered topology → smaller freshness gain at fine δ
        assert clustered.rounds(16) > diffuse.rounds(16)

    def test_cost_model_penalizes_fine_delta(self):
        m = fit_delta_model(self.g, 16, 20, 12, delta_min=16)
        assert m.round_cost_s(16) > m.round_cost_s(m.B)

    def test_best_delta_in_grid(self):
        m = fit_delta_model(self.g, 16, 20, 12, delta_min=16)
        grid = [64, 256, 1024]
        assert m.best_delta(grid) in {min(d, m.B) for d in grid}


class TestStripeScheduleStats:
    def test_flush_accounting_formulae(self):
        g = make_graph("urand", scale=10, efactor=8, kind="pagerank")
        sched = build_stripe_schedule(g, balanced_blocks(g, 8), 64, np.float32(0))
        assert sched.flushes_per_round == sched.S
        assert sched.flush_bytes_per_round() == sched.S * 8 * 64 * 4
        assert sched.padding_overhead >= 1.0


class TestInputSpecs:
    @pytest.mark.parametrize("arch", all_arch_ids())
    def test_all_cells_have_wellformed_specs(self, arch):
        cfg = get_config(arch)
        rules = Rules.default()
        for shape_name in applicable_shapes(cfg.family):
            shape = SHAPES[shape_name]
            kind, arg_specs, arg_shards = input_specs(cfg, shape, rules)
            assert kind == shape.kind
            flat_specs = jax.tree_util.tree_flatten(
                arg_specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
            )[0]
            assert all(isinstance(s, jax.ShapeDtypeStruct) for s in flat_specs)
            # spec/shard trees must be congruent
            flat_shards = jax.tree_util.tree_flatten(
                arg_shards,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
            )[0]
            assert len(flat_shards) == len(flat_specs)
            if kind == "train":
                tok_key = "embeds" if cfg.family == "vlm" else "tokens"
                assert arg_specs[0][tok_key].shape[0] == shape.global_batch
