"""repro.solve: Problem/Solver API — backend parity, caches, batching, compat.

Acceptance-criteria coverage for the unified API:

* host / jit / sharded backends are bit-identical (per round and at the
  fixed point) for the same ``Problem`` on the same graph;
* a second ``solve()`` on the same ``(graph, P, δ)`` performs zero schedule
  builds and zero retraces (trace-count assertions);
* ``solve_batch(Q=1)`` is bit-identical to the unbatched path, and each
  query of a multi-query batch matches its unbatched reference;
* the PR-2 ``mode=`` / ``host_loop=`` kwargs are gone (TypeError), and the
  deprecated ``GraphService.sssp()/.ppr()`` sugar warns but still answers
  through the typed serving tier.
"""

import numpy as np
import pytest

from repro.algorithms import connected_components, pagerank, sssp
from repro.graphs.generators import make_graph
from repro.solve import (
    Solver,
    cc_problem,
    multi_source_x0,
    pagerank_problem,
    ppr_problem,
    ppr_teleport,
    solve_batch,
    sssp_problem,
)

GRAPH_PR = make_graph("twitter", scale=9, efactor=8, kind="pagerank")
GRAPH_S = make_graph("kron", scale=8, efactor=8, kind="sssp")


class TestBackendParity:
    @pytest.mark.parametrize(
        "problem,graph",
        [(pagerank_problem(), GRAPH_PR), (sssp_problem(), GRAPH_S)],
        ids=["pagerank", "sssp"],
    )
    def test_fixed_point_bit_identical(self, problem, graph):
        solver = Solver(graph, problem, n_workers=4, delta=64, min_chunk=16)
        r_host = solver.solve(backend="host")
        r_jit = solver.solve(backend="jit")
        r_shard = solver.solve(backend="sharded")
        assert r_host.rounds == r_jit.rounds == r_shard.rounds
        np.testing.assert_array_equal(r_host.x, r_jit.x)
        np.testing.assert_array_equal(r_host.x, r_shard.x)

    def test_per_round_bit_identical(self):
        solver = Solver(
            GRAPH_PR, pagerank_problem(), n_workers=4, delta=64, min_chunk=16
        )
        rnd_host = solver.round_callable(backend="host")
        rnd_shard = solver.round_callable(backend="sharded")
        x_h = x_s = solver._x_ext(None)
        for _ in range(3):
            x_h, x_s = rnd_host(x_h), rnd_shard(x_s)
            np.testing.assert_array_equal(np.asarray(x_h), np.asarray(x_s))

    def test_counter_parity_host_vs_jit(self):
        """Normalized EngineResult semantics: both runners report the same
        rounds/flush accounting, and compile cost never pollutes exec time."""
        solver = Solver(
            GRAPH_PR, pagerank_problem(), n_workers=4, delta=64, min_chunk=16
        )
        r_host = solver.solve(backend="host")
        r_jit = solver.solve(backend="jit")
        assert r_host.rounds == r_jit.rounds
        assert r_host.flushes == r_jit.flushes
        assert r_host.flush_bytes == r_jit.flush_bytes
        for r in (r_host, r_jit):
            assert r.total_time_s > 0
            assert r.avg_round_time_s > 0
            assert abs(r.avg_round_time_s * r.rounds - r.total_time_s) < 1e-6


class TestSolverCache:
    def test_second_solve_zero_builds_zero_retraces(self):
        solver = Solver(
            GRAPH_PR, pagerank_problem(), n_workers=4, delta=128, backend="jit"
        )
        r1 = solver.solve()
        snap = dict(solver.stats)
        assert snap["schedule_builds"] == 1 and snap["traces"] == 1
        r2 = solver.solve()
        assert solver.stats["schedule_builds"] == snap["schedule_builds"]
        assert solver.stats["traces"] == snap["traces"]
        assert solver.stats["compiles"] == snap["compiles"]
        assert r2.compile_time_s == 0.0 and r1.compile_time_s > 0.0
        np.testing.assert_array_equal(r1.x, r2.x)

    def test_cache_is_per_delta_and_backend(self):
        solver = Solver(
            GRAPH_PR, pagerank_problem(), n_workers=4, delta=128, min_chunk=16
        )
        solver.solve(backend="jit")
        builds = solver.stats["schedule_builds"]
        solver.solve(backend="host")  # same schedule, new executable
        assert solver.stats["schedule_builds"] == builds
        assert solver.stats["compiles"] == 2
        solver.solve(delta=32, backend="jit")  # new schedule + executable
        assert solver.stats["schedule_builds"] == builds + 1
        snap = dict(solver.stats)
        solver.solve(delta=32, backend="jit")
        assert solver.stats == snap | {"solves": snap["solves"] + 1}

    def test_batch_cache_keyed_by_shape(self):
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=4, delta=32, min_chunk=8)
        x0 = multi_source_x0(GRAPH_S, [0, 1])
        solve_batch(solver, x0)
        snap = dict(solver.stats)
        solve_batch(solver, multi_source_x0(GRAPH_S, [5, 9]))
        assert solver.stats["traces"] == snap["traces"]
        assert solver.stats["compiles"] == snap["compiles"]

    def test_auto_delta_probes_then_caches(self):
        solver = Solver(
            GRAPH_PR,
            pagerank_problem(),
            n_workers=4,
            delta="auto",
            backend="jit",
            min_chunk=16,
        )
        r = solver.solve()
        delta_star = solver.resolve_delta("auto")
        assert 1 <= delta_star <= solver.block_size
        assert solver.delta_model is not None
        # δ* is memoized: resolving again runs no further probes
        solves = solver.stats["solves"]
        assert solver.resolve_delta("auto") == delta_star
        assert solver.stats["solves"] == solves
        ref = solver.solve(delta="sync")
        assert np.abs(r.x - ref.x).max() < 5e-5


class TestBatch:
    def test_q1_bit_identical_to_unbatched(self):
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=4, delta=32, min_chunk=8)
        r = solver.solve(backend="jit")
        b = solve_batch(solver, multi_source_x0(GRAPH_S, [0]))
        assert b.rounds == r.rounds and b.Q == 1
        np.testing.assert_array_equal(b.x[0], r.x)

    def test_q1_bit_identical_float(self):
        solver = Solver(
            GRAPH_PR, pagerank_problem(), n_workers=4, delta=64, min_chunk=16
        )
        r = solver.solve(backend="jit")
        x0 = np.full((1, GRAPH_PR.n), 1.0 / GRAPH_PR.n, np.float32)
        b = solve_batch(solver, x0)
        np.testing.assert_array_equal(b.x[0], r.x)

    def test_multi_source_each_query_exact(self):
        sources = [0, 7, 33]
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=4, delta=32, min_chunk=8)
        batch = solve_batch(solver, multi_source_x0(GRAPH_S, sources))
        assert batch.converged.all()
        assert batch.rounds == batch.rounds_per_query.max()
        for i, s in enumerate(sources):
            ref = Solver(
                GRAPH_S, sssp_problem(source=s), n_workers=4, delta=32, min_chunk=8
            ).solve(backend="jit")
            # min-plus is idempotent: extra rounds past convergence are no-ops
            np.testing.assert_array_equal(batch.x[i], ref.x)
            assert batch.rounds_per_query[i] == ref.rounds

    def test_ppr_uniform_equals_pagerank_bit_identical(self):
        r_pr = Solver(
            GRAPH_PR, pagerank_problem(), n_workers=4, delta=64, min_chunk=16
        ).solve(backend="jit")
        solver = Solver(GRAPH_PR, ppr_problem(), n_workers=4, delta=64, min_chunk=16)
        r_ppr = solver.solve(backend="jit")  # default query = uniform teleport
        np.testing.assert_array_equal(r_pr.x, r_ppr.x)

    def test_ppr_batch_seeds(self):
        solver = Solver(GRAPH_PR, ppr_problem(), n_workers=4, delta=64, min_chunk=16)
        seeds = [3, 11]
        q = ppr_teleport(GRAPH_PR, seeds)
        x0 = np.tile(np.full(GRAPH_PR.n, 1.0 / GRAPH_PR.n, np.float32), (2, 1))
        batch = solve_batch(solver, x0, q=q)
        assert batch.converged.all()
        # localized teleport: each seed dominates its own ranking
        assert not np.array_equal(batch.x[0], batch.x[1])
        for i, s in enumerate(seeds):
            assert batch.x[i].argmax() == s
            # query i matches its unbatched reference run for the same rounds
            ref = solver.solve(q=q[i], max_rounds=batch.rounds, tol=0.0)
            np.testing.assert_array_equal(batch.x[i], ref.x)

    def test_batch_flush_accounting(self):
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=4, delta=32, min_chunk=8)
        sched = solver.schedule()
        b = solve_batch(solver, multi_source_x0(GRAPH_S, [0, 1]))
        assert b.flushes == b.rounds * sched.S
        assert b.flush_bytes == b.flushes * sched.P * sched.delta * 4 * b.Q


class TestProblemSpecs:
    def test_cc_edge_values_hook(self):
        """cc_problem zeroes weights internally — callers pass the graph as-is."""
        g = make_graph("road", scale=8, kind="unit")
        solver = Solver(g, cc_problem(), n_workers=4, delta=64, min_chunk=16)
        r = solver.solve(backend="jit")
        assert len(np.unique(r.x)) == 1

    def test_query_validation(self):
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=4, delta=32)
        with pytest.raises(ValueError, match="takes no query"):
            solver.solve(q=np.zeros(GRAPH_S.n))
        with pytest.raises(ValueError, match="x0 must have shape"):
            solver.solve(np.zeros(3, np.int32))

    def test_sharded_supports_query_problems(self):
        """q threads through the shard_map round (was NotImplementedError)."""
        solver = Solver(GRAPH_PR, ppr_problem(), n_workers=4, delta=64, min_chunk=16)
        q = ppr_teleport(GRAPH_PR, [5])[0]
        r_jit = solver.solve(q=q, backend="jit")
        r_shard = solver.solve(q=q, backend="sharded")
        assert r_jit.rounds == r_shard.rounds
        np.testing.assert_array_equal(r_jit.x, r_shard.x)


class TestLegacySurface:
    """PR-2's ``mode=``/``host_loop=`` kwargs are retired, not deprecated."""

    def test_mode_kwarg_gone(self):
        with pytest.raises(TypeError, match="mode"):
            pagerank(GRAPH_PR, P=4, mode="delayed", delta=64, min_chunk=16)

    def test_host_loop_kwarg_gone(self):
        with pytest.raises(TypeError, match="host_loop"):
            sssp(GRAPH_S, P=4, delta=32, host_loop=False, min_chunk=8)

    def test_resolve_legacy_args_gone(self):
        import repro.solve

        assert not hasattr(repro.solve, "resolve_legacy_args")

    def test_new_style_no_warning(self):
        import warnings

        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            connected_components(
                make_graph("road", scale=8, kind="unit"),
                P=4,
                delta=64,
                backend="jit",
                min_chunk=16,
            )

    def test_wrapper_matches_new_api(self):
        r_old = pagerank(GRAPH_PR, P=4, delta=64, min_chunk=16)
        r_new = Solver(
            GRAPH_PR,
            pagerank_problem(),
            n_workers=4,
            delta=64,
            backend="host",
            min_chunk=16,
        ).solve()
        np.testing.assert_array_equal(r_old.x, r_new.x)
        assert r_old.rounds == r_new.rounds


class TestServeGraphDriver:
    def test_end_to_end_batched_service(self):
        from repro.launch.serve_graph import main

        argv = "--graph kron --scale 8 --queries 2 --repeats 2 --delta 32"
        report = main(argv.split() + ["--algo", "both"])
        for algo in ("sssp", "ppr"):
            lat = report["latency_s"][algo]
            stats = report["stats"][algo]
            assert len(lat) == 2
            # warm waves reuse the cold wave's schedule and executable
            assert stats["schedule_builds"] == 1
            assert stats["compiles"] == 1

    def test_deprecated_sugar_answers_through_the_tier(self):
        from repro.launch.serve_graph import GraphService

        service = GraphService(GRAPH_S, n_workers=4, delta=32, batch_size=4)
        with pytest.warns(DeprecationWarning, match="sssp.. is deprecated"):
            d = service.sssp([0])
        assert d.shape == (1, GRAPH_S.n)
        ref = Solver(GRAPH_S, sssp_problem(), n_workers=4, delta=32).solve(
            backend="jit"
        )
        np.testing.assert_array_equal(d[0], ref.x)

    def test_legacy_sugar_rejects_empty_and_splits_oversize(self):
        from repro.launch.serve_graph import GraphService

        service = GraphService(GRAPH_S, n_workers=4, delta=32, batch_size=2)
        with pytest.raises(ValueError, match="empty query list"):
            with pytest.warns(DeprecationWarning):
                service.sssp([])
        # k > batch_size splits across queue slots instead of raising
        sources = [0, 3, 9, 21, 40]
        with pytest.warns(DeprecationWarning):
            d = service.sssp(sources)
        assert d.shape == (len(sources), GRAPH_S.n)
        for row, s in zip(d, sources):
            ref = solve_batch(service.solver("sssp"), multi_source_x0(GRAPH_S, [s]))
            np.testing.assert_array_equal(row, ref.x[0])
