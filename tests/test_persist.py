"""repro.persist: warm-restart round trips, cache invalidation, δ re-probing.

Acceptance-criteria coverage for the persistent solver cache:

* a second Solver "process" (fresh instance, same ``cache_dir``) performs
  **zero stripe builds and zero retraces**, with results bit-identical to the
  cold run — for the fused jit loop, the host round, batched solving, and
  the sharded halo plan;
* every mismatch class — graph content, problem fingerprint (including
  row-update closure constants), repro/jax version bump, corrupted entry —
  is a clean **miss** (cold rebuild), never a wrong answer;
* ``delta="auto"`` resolves from the persisted δ-model without re-probing,
  and :meth:`Solver.reprobe_delta` refits from logged ``EngineResult``
  observations and migrates δ* without dropping compiled neighbors.
"""

import numpy as np
import pytest

from repro.core.delta_model import DeltaModel, TPUCostParams, refit_delta_model
from repro.graphs.generators import make_graph
from repro.solve import (
    Solver,
    multi_source_x0,
    pagerank_problem,
    solve_batch,
    sssp_problem,
)

GRAPH_PR = make_graph("twitter", scale=9, efactor=8, kind="pagerank")
GRAPH_S = make_graph("kron", scale=8, efactor=8, kind="sssp")


def pr_solver(cache_dir, graph=GRAPH_PR, problem=None, **kw):
    kw.setdefault("n_workers", 4)
    kw.setdefault("delta", 64)
    kw.setdefault("min_chunk", 16)
    return Solver(graph, problem or pagerank_problem(), cache_dir=cache_dir, **kw)


def assert_cold(solver):
    assert solver.stats["schedule_builds"] >= 1
    assert solver.stats["traces"] >= 1


def assert_warm(solver):
    assert solver.stats["schedule_builds"] == 0, solver.stats
    assert solver.stats["plan_builds"] == 0, solver.stats
    assert solver.stats["traces"] == 0, solver.stats
    assert solver.stats["compiles"] == 0, solver.stats
    assert solver.stats["cache_loads"] >= 1, solver.stats


class TestWarmRestart:
    def test_jit_round_trip_bit_identical_zero_work(self, tmp_path):
        cold = pr_solver(tmp_path)
        r_cold = cold.solve()
        assert_cold(cold)
        warm = pr_solver(tmp_path)
        r_warm = warm.solve()
        assert_warm(warm)
        assert r_warm.rounds == r_cold.rounds
        np.testing.assert_array_equal(r_cold.x, r_warm.x)

    def test_host_round_trip_int_semiring(self, tmp_path):
        cold = pr_solver(tmp_path, graph=GRAPH_S, problem=sssp_problem(), delta=32)
        r_cold = cold.solve(backend="host")
        warm = pr_solver(tmp_path, graph=GRAPH_S, problem=sssp_problem(), delta=32)
        r_warm = warm.solve(backend="host")
        assert_warm(warm)
        np.testing.assert_array_equal(r_cold.x, r_warm.x)

    def test_batch_round_trip(self, tmp_path):
        sources = [0, 7]
        x0 = multi_source_x0(GRAPH_S, sources)
        cold = pr_solver(tmp_path, graph=GRAPH_S, problem=sssp_problem(), delta=32)
        b_cold = solve_batch(cold, x0)
        warm = pr_solver(tmp_path, graph=GRAPH_S, problem=sssp_problem(), delta=32)
        b_warm = solve_batch(warm, x0)
        assert_warm(warm)
        assert b_warm.rounds == b_cold.rounds
        np.testing.assert_array_equal(b_cold.x, b_warm.x)

    def test_halo_plan_round_trip(self, tmp_path):
        kw = dict(backend="sharded", frontier="halo")
        cold = pr_solver(tmp_path, **kw)
        r_cold = cold.solve()
        assert cold.stats["plan_builds"] == 1
        warm = pr_solver(tmp_path, **kw)
        r_warm = warm.solve()
        # the plan and schedule must hydrate from disk; the shard_map
        # executable persists only when exported single-device, so assert
        # the build counters rather than traces here
        assert warm.stats["plan_builds"] == 0
        assert warm.stats["schedule_builds"] == 0
        assert warm.stats["cache_loads"] >= 2
        np.testing.assert_array_equal(r_cold.x, r_warm.x)

    def test_auto_delta_loads_without_probing(self, tmp_path):
        cold = pr_solver(tmp_path, delta="auto")
        cold.solve()
        assert cold.stats["solves"] >= 3  # two probes + the real solve
        delta_star = cold.resolve_delta("auto")
        warm = pr_solver(tmp_path, delta="auto")
        assert warm.resolve_delta("auto") == delta_star
        assert warm.stats["solves"] == 0  # δ-model loaded, no probe solves
        assert warm.delta_model is not None


class TestInvalidation:
    def test_graph_content_mismatch_is_cold(self, tmp_path):
        pr_solver(tmp_path).solve()
        perturbed = GRAPH_PR.with_values(
            (GRAPH_PR.values * np.float32(0.5)).astype(np.float32)
        )
        other = pr_solver(tmp_path, graph=perturbed)
        other.solve()
        assert_cold(other)

    def test_problem_fingerprint_mismatch_recompiles(self, tmp_path):
        pr_solver(tmp_path).solve()
        # same problem name, different row-update closure constant (teleport)
        other = pr_solver(tmp_path, problem=pagerank_problem(damping=0.9))
        other.solve()
        # the compiled loop bakes the constant in: always a cold retrace
        assert other.stats["traces"] >= 1
        assert other.stats["compiles"] >= 1
        # the schedule holds only graph bytes — the content-addressed stripe
        # store may (and does) share it across problem namespaces
        assert other.stats["schedule_builds"] == 0
        assert other.stats["stripe_loads"] == other.n_workers

    def test_version_bump_is_cold(self, tmp_path, monkeypatch):
        cold = pr_solver(tmp_path)
        r_cold = cold.solve()
        monkeypatch.setattr("repro.persist.keys._REPRO_VERSION", "bumped")
        other = pr_solver(tmp_path)
        r_other = other.solve()
        assert_cold(other)
        np.testing.assert_array_equal(r_cold.x, r_other.x)

    def test_corrupt_entries_fall_back_cold(self, tmp_path):
        cold = pr_solver(tmp_path)
        r_cold = cold.solve()
        corrupted = 0
        for path in tmp_path.rglob("*"):
            if path.suffix in (".npz", ".bin", ".json"):
                path.write_bytes(b"\x00corrupt\xff")
                corrupted += 1
        assert corrupted >= 2  # schedule + executable at minimum
        warm = pr_solver(tmp_path)
        r_warm = warm.solve()
        assert_cold(warm)  # every load was a miss, never an exception
        np.testing.assert_array_equal(r_cold.x, r_warm.x)

    def test_truncated_observation_line_skipped(self, tmp_path):
        solver = pr_solver(tmp_path)
        solver.solve()
        store = solver.persist
        n_before = len(store.load_observations())
        assert n_before >= 1
        with open(store.dir / "observations.jsonl", "a") as f:
            f.write('{"delta": 64, "rou')  # killed mid-write
        assert len(store.load_observations()) == n_before
        store.record_observation(64, 5, 0.1, backend="jit")
        # the partial line has no newline; the reader must still see the
        # well-formed rows on either side of it
        assert len(store.load_observations()) >= n_before


class TestDeltaReprobing:
    @staticmethod
    def _model(r_sync, r_async):
        return DeltaModel(
            P=4,
            B=4096,
            delta_min=16,
            r_sync=r_sync,
            r_async=r_async,
            locality=0.0,
            edges=200_000,
            bytes_per_elem=4,
            hw=TPUCostParams(),
        )

    def test_refit_flat_observations_push_delta_up(self):
        """Flat rounds(δ) ⇒ no freshness benefit ⇒ commit cost picks big δ."""
        model = self._model(r_sync=1000, r_async=10)
        assert model.best_delta() < model.B
        flat = [(16, 60), (256, 60), (4096, 60)] * 5
        refit = refit_delta_model(model, flat)
        assert abs(refit.r_sync - refit.r_async) < abs(model.r_sync - model.r_async)
        assert refit.best_delta() > model.best_delta()

    def test_refit_steep_observations_push_delta_down(self):
        """Steep rounds(δ) ⇒ strong freshness benefit ⇒ finer δ wins."""
        model = self._model(r_sync=50, r_async=48)
        steep = [(16, 10), (256, 200), (4096, 2000)] * 5
        refit = refit_delta_model(model, steep)
        assert refit.r_sync > refit.r_async
        assert refit.best_delta() <= model.best_delta()

    def test_refit_empty_observations_keeps_model(self):
        model = self._model(r_sync=100, r_async=10)
        refit = refit_delta_model(model, [])
        assert refit.best_delta() == model.best_delta()
        assert np.isclose(refit.r_sync, model.r_sync)
        assert np.isclose(refit.r_async, model.r_async)

    def test_reprobe_migrates_without_dropping_neighbors(self, tmp_path):
        # Seed the store with a fitted δ-model whose freshness gap strongly
        # favors a *fine* δ (as a first probe on an async-friendly graph
        # would), so flat production observations have room to migrate up.
        seed = pr_solver(tmp_path, delta=64)
        seed.solve()
        base = DeltaModel(
            P=4,
            B=seed.block_size,
            delta_min=16,
            r_sync=1000,
            r_async=10,
            locality=0.0,
            edges=seed.graph.nnz,
            bytes_per_elem=4,
            hw=TPUCostParams(),
        )
        assert base.best_delta() < base.B
        seed.persist.save_delta_model(base, base.best_delta())

        solver = pr_solver(tmp_path, delta="auto")
        old_star = solver.resolve_delta("auto")
        assert old_star == base.best_delta()  # served from the store, no probe
        assert solver.stats["solves"] == 0
        solver.solve()  # compiles the old δ*'s executable
        compiled_before = set(solver._compiled)
        schedules_before = set(solver._schedules)
        # Production logs a flat rounds(δ) curve: delaying costs no extra
        # rounds on this workload, so the commit-cost term should win and
        # δ* should migrate up.
        for d in (16, old_star, solver.block_size):
            for _ in range(10):
                solver.persist.record_observation(d, 40, 0.01, backend="jit")
        migrated_from, new_star = solver.reprobe_delta()
        assert migrated_from == old_star
        assert new_star == solver.resolve_delta("auto")
        assert new_star > old_star
        # nothing dropped: every already-compiled executable and schedule
        # for the old δ* (and any neighbor) is still warm in memory
        assert compiled_before <= set(solver._compiled)
        assert schedules_before <= set(solver._schedules)
        # the migration is persisted: a restarted process serves the new δ*
        warm = pr_solver(tmp_path, delta="auto")
        assert warm.resolve_delta("auto") == new_star
        assert warm.stats["solves"] == 0

    def test_batch_observations_drive_reprobe(self, tmp_path):
        """Served batches are production traffic: they must advance the refit
        counter and feed the fit (a serving process emits nothing else)."""
        x0 = multi_source_x0(GRAPH_S, [0, 7])
        solver = pr_solver(
            tmp_path,
            graph=GRAPH_S,
            problem=sssp_problem(),
            delta="auto",
            reprobe_every=1,
        )
        solve_batch(solver, x0)
        obs = solver.persist.load_observations()
        assert any(o["kind"] == "batch" for o in obs)
        # the batch observation crossed reprobe_every, so a refit ran inline
        assert solver._obs_since_refit == 0
        assert solver.persist.load_delta_model() is not None

    def test_reprobe_every_refits_inline(self, tmp_path):
        solver = pr_solver(tmp_path, delta="auto", reprobe_every=1)
        solver.solve()
        # the auto-probe + solve recorded ≥ reprobe_every observations, so a
        # refit ran inline and reset the counter
        assert solver._obs_since_refit == 0
        assert solver.persist.load_delta_model() is not None

    def test_reprobe_requires_cache_dir(self):
        solver = Solver(GRAPH_PR, pagerank_problem(), n_workers=4, delta=64)
        with pytest.raises(ValueError, match="cache_dir"):
            solver.reprobe_delta()


class TestNamespaceKeys:
    def test_closure_constants_distinguish_problems(self, tmp_path):
        """Two Jacobi systems on one graph differ only in baked-in b."""
        from repro.algorithms.jacobi import jacobi_graph
        from repro.solve import jacobi_problem

        rng = np.random.default_rng(0)
        n = 128
        rows = np.repeat(np.arange(n), 4)
        cols = (rows + rng.integers(1, n, rows.shape[0])) % n
        vals = rng.normal(size=rows.shape[0]).astype(np.float32) * 0.1
        diag = np.full(n, 4.0, np.float32)
        g = jacobi_graph(n, rows, cols, vals, diag)
        b1 = rng.normal(size=n).astype(np.float32)
        b2 = rng.normal(size=n).astype(np.float32)
        s1 = pr_solver(tmp_path, graph=g, problem=jacobi_problem(diag, b1))
        s2 = pr_solver(tmp_path, graph=g, problem=jacobi_problem(diag, b2))
        assert s1.persist.namespace != s2.persist.namespace
        # sanity: the same problem maps to the same namespace
        s1b = pr_solver(tmp_path, graph=g, problem=jacobi_problem(diag, b1))
        assert s1.persist.namespace == s1b.persist.namespace

    def test_no_cache_dir_no_persistence(self, tmp_path):
        solver = Solver(GRAPH_PR, pagerank_problem(), n_workers=4, delta=64)
        solver.solve()
        assert solver.persist is None
        assert solver.stats["cache_loads"] == 0
        assert not any(tmp_path.iterdir())


class TestServeGraphGate:
    def test_serve_graph_warm_restart_gate(self, tmp_path):
        """The exact round trip the CI warm-start job runs, in-process."""
        from repro.launch.serve_graph import main

        argv = (
            "--graph kron --scale 8 --queries 2 --repeats 2 --delta 32 "
            f"--algo sssp --cache-dir {tmp_path}"
        ).split()
        cold = main(argv)
        assert cold["stats"]["sssp"]["schedule_builds"] == 1
        warm = main(argv + ["--assert-warm"])  # raises SystemExit if cold
        assert warm["stats"]["sssp"]["schedule_builds"] == 0
        assert warm["stats"]["sssp"]["traces"] == 0
        np.testing.assert_array_equal(
            np.asarray(cold["latency_s"]["sssp"]).shape,
            np.asarray(warm["latency_s"]["sssp"]).shape,
        )

    def test_assert_warm_fails_on_empty_cache(self, tmp_path):
        from repro.launch.serve_graph import main

        argv = (
            "--graph kron --scale 8 --queries 2 --repeats 1 --delta 32 "
            f"--algo sssp --cache-dir {tmp_path / 'empty'} --assert-warm"
        ).split()
        with pytest.raises(SystemExit, match="cold work performed"):
            main(argv)
