"""Hypothesis property tests on system invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.engine import make_schedule
from repro.core.semiring import INT_INF, MIN_PLUS, PLUS_TIMES
from repro.graphs.formats import CSRGraph, build_stripe_schedule
from repro.graphs.generators import make_graph
from repro.graphs.partition import balanced_blocks, equal_blocks
from repro.algorithms import pagerank, sssp

SETTINGS = dict(
    deadline=None,
    max_examples=20,
    suppress_health_check=[HealthCheck.too_slow],
)


@st.composite
def random_graph(draw):
    n = draw(st.integers(min_value=4, max_value=120))
    m = draw(st.integers(min_value=1, max_value=5 * n))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.integers(1, 64, m).astype(np.int32)
    return CSRGraph.from_edges(n, src, dst, w, name=f"h{seed}")


@given(random_graph(), st.integers(1, 6), st.integers(1, 64))
@settings(**SETTINGS)
def test_stripe_schedule_covers_every_edge_once(g, P, delta):
    """Padding never duplicates or drops edges: Σ real cells == nnz."""
    bounds = balanced_blocks(g, P)
    sched = build_stripe_schedule(g, bounds, delta, pad_val=INT_INF)
    real = int((sched.dst_local < sched.delta).sum())
    assert real == g.nnz
    # every row appears exactly once across (step, worker) cells
    rows = sched.rows[sched.rows < g.n]
    assert len(np.unique(rows)) == rows.size == g.n


@given(random_graph(), st.integers(1, 4), st.integers(1, 32))
@settings(**SETTINGS)
def test_sssp_fixed_point_delta_invariant(g, P, delta):
    """SSSP distances are δ-independent (monotone min-plus fixed point)."""
    r_sync = sssp(g, P=P, delta="sync", backend="host")
    r_del = sssp(g, P=P, delta=delta, min_chunk=8)
    assert (r_sync.x == r_del.x).all()


@given(random_graph(), st.integers(1, 4))
@settings(**SETTINGS)
def test_sssp_triangle_inequality(g, P):
    """d[v] ≤ d[u] + w(u, v) for every edge at the fixed point."""
    r = sssp(g, P=P, delta="async", min_chunk=8)
    d = r.x.astype(np.int64)
    dst_of = np.repeat(np.arange(g.n), np.diff(g.indptr))
    lhs = d[dst_of]
    rhs = d[g.indices] + g.values
    ok = lhs <= np.minimum(rhs, INT_INF)
    assert ok.all()


@given(random_graph(), st.integers(1, 4), st.integers(1, 32))
@settings(**SETTINGS)
def test_pagerank_mass_and_positivity(g, P, delta):
    gpr = g.with_values(
        (0.85 / np.maximum(g.out_degree[g.indices], 1)).astype(np.float32)
    )
    r = pagerank(gpr, P=P, delta=delta, min_chunk=8, max_rounds=200)
    assert (r.x >= 0).all()
    # dangling leakage only reduces mass: 0 < Σx ≤ 1 + tol
    assert 0 < r.x.sum() <= 1.0 + 1e-3


@given(st.integers(2, 64), st.integers(1, 6))
@settings(**SETTINGS)
def test_balanced_blocks_cover(n, P):
    rng = np.random.default_rng(n * 31 + P)
    src = rng.integers(0, n, 4 * n)
    dst = rng.integers(0, n, 4 * n)
    g = CSRGraph.from_edges(n, src, dst)
    b = balanced_blocks(g, P)
    assert b[0] == 0 and b[-1] == n and (np.diff(b) >= 0).all()
