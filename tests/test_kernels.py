"""Per-kernel shape/dtype sweeps: pallas (interpret) vs ref.py oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.semiring import INT_INF
from repro.kernels import ref
from repro.kernels.delayed_block import delayed_block_pagerank
from repro.kernels.ops import ell_from_csr, spmv
from repro.kernels.spmv_ell import spmv_ell


def _ell(rng, rows, max_deg, n_slots, dtype, pad_val):
    idx = rng.integers(0, n_slots - 1, (rows, max_deg)).astype(np.int32)
    if dtype == np.float32:
        val = (rng.random((rows, max_deg)) * 0.1).astype(dtype)
    else:
        val = rng.integers(1, 200, (rows, max_deg)).astype(dtype)
    # sprinkle padding entries
    mask = rng.random((rows, max_deg)) < 0.3
    val[mask] = pad_val
    return idx, val


@pytest.mark.parametrize("rows", [8, 64, 256])
@pytest.mark.parametrize("max_deg", [1, 7, 128])
def test_spmv_plus_times_shapes(rng, rows, max_deg):
    n = 500
    idx, val = _ell(rng, rows, max_deg, n, np.float32, 0.0)
    x = rng.random(n + 1).astype(np.float32)
    out_k = spmv_ell(
        jnp.asarray(x), jnp.asarray(idx), jnp.asarray(val),
        semiring="plus_times", row_tile=min(8, rows), interpret=True,
    )
    out_r = ref.spmv_ell_ref(jnp.asarray(x), jnp.asarray(idx), jnp.asarray(val),
                             "plus_times")
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5)


@pytest.mark.parametrize("rows", [8, 128])
@pytest.mark.parametrize("max_deg", [3, 64])
def test_spmv_min_plus_shapes(rng, rows, max_deg):
    n = 300
    idx, val = _ell(rng, rows, max_deg, n, np.int32, INT_INF)
    x = rng.integers(0, 1000, n + 1).astype(np.int32)
    x[rng.random(n + 1) < 0.5] = INT_INF
    out_k = spmv_ell(
        jnp.asarray(x), jnp.asarray(idx), jnp.asarray(val),
        semiring="min_plus", row_tile=min(8, rows), interpret=True,
    )
    out_r = ref.spmv_ell_ref(jnp.asarray(x), jnp.asarray(idx), jnp.asarray(val),
                             "min_plus")
    assert (np.asarray(out_k) == np.asarray(out_r)).all()


def test_spmv_on_real_graph(rng):
    from repro.graphs.generators import make_graph

    g = make_graph("web", scale=9, efactor=8, kind="pagerank")
    idx, val = ell_from_csr(g)
    pad = (-len(idx)) % 256
    idx = np.pad(idx, ((0, pad), (0, 0)))
    val = np.pad(val, ((0, pad), (0, 0)))
    x = rng.random(g.n + 1).astype(np.float32)
    out_k = spmv(jnp.asarray(x), jnp.asarray(idx), jnp.asarray(val), "plus_times")
    out_r = ref.spmv_ell_ref(jnp.asarray(x), jnp.asarray(idx), jnp.asarray(val),
                             "plus_times")
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5)


@pytest.mark.parametrize(
    "n_chunks,delta,max_deg", [(1, 8, 8), (4, 32, 16), (7, 16, 128)]
)
def test_delayed_block_vs_sequential_ref(rng, n_chunks, delta, max_deg):
    n = n_chunks * delta
    idx = rng.integers(0, n, (n_chunks, delta, max_deg)).astype(np.int32)
    val = (rng.random((n_chunks, delta, max_deg)) * 0.05).astype(np.float32)
    rows = np.arange(n, dtype=np.int32).reshape(n_chunks, delta)
    x = rng.random(n + 1).astype(np.float32)
    out_k = delayed_block_pagerank(
        jnp.asarray(x), jnp.asarray(idx), jnp.asarray(val), jnp.asarray(rows),
        0.05, interpret=True,
    )
    out_r = ref.delayed_block_ref(
        jnp.asarray(x), jnp.asarray(idx), jnp.asarray(val), jnp.asarray(rows),
        0.05, n_chunks,
    )
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5)


def test_delayed_block_is_gauss_seidel_not_jacobi(rng):
    """Later chunks must see earlier commits (the whole point of the fusion)."""
    n_chunks, delta, max_deg, n = 3, 8, 4, 24
    idx = rng.integers(0, n, (n_chunks, delta, max_deg)).astype(np.int32)
    val = (rng.random((n_chunks, delta, max_deg)) * 0.5).astype(np.float32)
    rows = np.arange(n, dtype=np.int32).reshape(n_chunks, delta)
    x = rng.random(n + 1).astype(np.float32)
    out_k = np.asarray(
        delayed_block_pagerank(
            jnp.asarray(x), jnp.asarray(idx), jnp.asarray(val), jnp.asarray(rows),
            0.05, interpret=True,
        )
    )
    # Jacobi version: all chunks read the original x
    x_j = jnp.asarray(x)
    upd = [
        0.05 + ref.spmv_ell_ref(jnp.asarray(x), jnp.asarray(idx)[c],
                                jnp.asarray(val)[c], "plus_times")
        for c in range(n_chunks)
    ]
    for c in range(n_chunks):
        x_j = x_j.at[jnp.asarray(rows)[c]].set(upd[c], mode="drop")
    assert np.abs(out_k - np.asarray(x_j)).max() > 1e-6
