"""Kernel checks: interpret-mode Pallas vs oracles AND vs the engine itself.

The spmv sweeps keep the isolated shape/dtype coverage; the fused-round
checks are engine-integration tests — the kernel consumes a real
:class:`repro.core.engine.DeviceSchedule` built from a real graph and must
match the engine's XLA round bit-for-bit (the contract ``backend="pallas"``
stands on; see ``tests/test_pallas_backend.py`` for the full solver matrix).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import make_schedule, round_fn
from repro.core.semiring import INT_INF, MIN_PLUS, PLUS_TIMES
from repro.graphs.generators import make_graph
from repro.kernels import ref
from repro.kernels.ops import ell_from_csr, fused_round, spmv
from repro.kernels.round_block import fused_round_fn, resolve_interpret
from repro.kernels.spmv_ell import spmv_ell


def _ell(rng, rows, max_deg, n_slots, dtype, pad_val):
    idx = rng.integers(0, n_slots - 1, (rows, max_deg)).astype(np.int32)
    if dtype == np.float32:
        val = (rng.random((rows, max_deg)) * 0.1).astype(dtype)
    else:
        val = rng.integers(1, 200, (rows, max_deg)).astype(dtype)
    # sprinkle padding entries
    mask = rng.random((rows, max_deg)) < 0.3
    val[mask] = pad_val
    return idx, val


@pytest.mark.parametrize("rows", [8, 64, 256])
@pytest.mark.parametrize("max_deg", [1, 7, 128])
def test_spmv_plus_times_shapes(rng, rows, max_deg):
    n = 500
    idx, val = _ell(rng, rows, max_deg, n, np.float32, 0.0)
    x = rng.random(n + 1).astype(np.float32)
    out_k = spmv_ell(
        jnp.asarray(x),
        jnp.asarray(idx),
        jnp.asarray(val),
        semiring="plus_times",
        row_tile=min(8, rows),
    )
    out_r = ref.spmv_ell_ref(
        jnp.asarray(x), jnp.asarray(idx), jnp.asarray(val), "plus_times"
    )
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5)


@pytest.mark.parametrize("rows", [8, 128])
@pytest.mark.parametrize("max_deg", [3, 64])
def test_spmv_min_plus_shapes(rng, rows, max_deg):
    n = 300
    idx, val = _ell(rng, rows, max_deg, n, np.int32, INT_INF)
    x = rng.integers(0, 1000, n + 1).astype(np.int32)
    x[rng.random(n + 1) < 0.5] = INT_INF
    out_k = spmv_ell(
        jnp.asarray(x),
        jnp.asarray(idx),
        jnp.asarray(val),
        semiring="min_plus",
        row_tile=min(8, rows),
    )
    out_r = ref.spmv_ell_ref(
        jnp.asarray(x), jnp.asarray(idx), jnp.asarray(val), "min_plus"
    )
    assert (np.asarray(out_k) == np.asarray(out_r)).all()


def test_spmv_on_real_graph(rng):
    g = make_graph("web", scale=9, efactor=8, kind="pagerank")
    idx, val = ell_from_csr(g)
    pad = (-len(idx)) % 256
    idx = np.pad(idx, ((0, pad), (0, 0)))
    val = np.pad(val, ((0, pad), (0, 0)))
    x = rng.random(g.n + 1).astype(np.float32)
    out_k = spmv(jnp.asarray(x), jnp.asarray(idx), jnp.asarray(val), "plus_times")
    out_r = ref.spmv_ell_ref(
        jnp.asarray(x), jnp.asarray(idx), jnp.asarray(val), "plus_times"
    )
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5)


def test_interpret_auto_dispatch_is_backend_aware():
    """``interpret=None`` interprets off-TPU and compiles on TPU; explicit
    booleans are honoured (the old ``interpret=True`` default silently
    interpreted on TPU when called directly)."""
    import jax

    on_tpu = jax.default_backend() == "tpu"
    assert resolve_interpret(None) == (not on_tpu)
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


class TestEllFromCsr:
    """The vectorized layout builder (no per-row Python loop)."""

    def test_matches_loop_reference(self, rng):
        g = make_graph("kron", scale=8, efactor=8, kind="pagerank")
        idx, val = ell_from_csr(g, lane_pad=8)
        degs = np.diff(g.indptr)
        assert idx.shape == val.shape == (g.n, -(-int(degs.max()) // 8) * 8)
        for r in [0, 1, int(degs.argmax()), g.n - 1]:  # spot-check rows
            e0, e1 = g.indptr[r], g.indptr[r + 1]
            np.testing.assert_array_equal(idx[r, : e1 - e0], g.indices[e0:e1])
            np.testing.assert_array_equal(val[r, : e1 - e0], g.values[e0:e1])
            assert (val[r, e1 - e0 :] == 0.0).all()  # plus-times annihilator

    def test_rows_slice_and_int_padding(self):
        g = make_graph("kron", scale=8, efactor=8, kind="sssp")
        rows = np.asarray([3, 0, 17])
        idx, val = ell_from_csr(g, rows_slice=rows, lane_pad=4)
        assert idx.shape[0] == 3
        for i, r in enumerate(rows):
            e0, e1 = g.indptr[r], g.indptr[r + 1]
            np.testing.assert_array_equal(idx[i, : e1 - e0], g.indices[e0:e1])
            assert (val[i, e1 - e0 :] == INT_INF).all()  # min-plus annihilator

    def test_ell_reduction_matches_graph_spmv(self, rng):
        """ELL built by fancy indexing computes the same pull reduction as
        the CSR definition — end-to-end layout correctness."""
        g = make_graph("web", scale=8, efactor=8, kind="pagerank")
        idx, val = ell_from_csr(g, lane_pad=8)
        x = rng.random(g.n + 1).astype(np.float32)
        out = np.asarray(ref.spmv_ell_ref(jnp.asarray(x), idx, val, "plus_times"))
        expect = np.zeros(g.n, np.float32)
        for u in range(g.n):
            e0, e1 = g.indptr[u], g.indptr[u + 1]
            expect[u] = np.sum(x[g.indices[e0:e1]] * g.values[e0:e1])
        np.testing.assert_allclose(out, expect, rtol=1e-5)


class TestFusedRoundEngineIntegration:
    """round_block vs the engine's XLA round on real schedules."""

    def _x_ext(self, g, sr, rng):
        if sr is MIN_PLUS:
            x0 = rng.integers(0, 1000, g.n).astype(np.int32)
        else:
            x0 = rng.random(g.n).astype(np.float32)
        return jnp.concatenate([jnp.asarray(x0), jnp.asarray([sr.zero], sr.dtype)])

    @pytest.mark.parametrize("delta", [16, 64, 10_000])
    def test_pagerank_round_bit_identical(self, rng, delta):
        g = make_graph("twitter", scale=9, efactor=8, kind="pagerank")
        sched = make_schedule(g, 4, delta, PLUS_TIMES, min_chunk=8)
        tele = np.float32(0.15 / g.n)
        row_update = lambda o, r, w: tele + r
        x = self._x_ext(g, PLUS_TIMES, rng)
        x_ref = np.asarray(round_fn(sched, PLUS_TIMES, row_update)(x))
        x_pal = np.asarray(fused_round(x, sched, PLUS_TIMES, row_update))
        np.testing.assert_array_equal(x_ref[:-1], x_pal[:-1])

    def test_min_plus_round_bit_identical(self, rng):
        g = make_graph("kron", scale=8, efactor=8, kind="sssp")
        sched = make_schedule(g, 4, 32, MIN_PLUS)
        row_update = lambda o, r, w: jnp.minimum(o, r)
        x = self._x_ext(g, MIN_PLUS, rng)
        x_ref = np.asarray(round_fn(sched, MIN_PLUS, row_update)(x))
        x_pal = np.asarray(fused_round(x, sched, MIN_PLUS, row_update))
        np.testing.assert_array_equal(x_ref[:-1], x_pal[:-1])

    def test_kernel_matches_pure_jnp_oracle(self, rng):
        g = make_graph("kron", scale=8, efactor=8, kind="pagerank")
        sched = make_schedule(g, 4, 32, PLUS_TIMES)
        tele = np.float32(0.15 / g.n)
        row_update = lambda o, r, w: tele + r
        x = self._x_ext(g, PLUS_TIMES, rng)
        out_k = fused_round(x, sched, PLUS_TIMES, row_update, use_kernel=True)
        out_r = fused_round(x, sched, PLUS_TIMES, row_update, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(out_k)[:-1], np.asarray(out_r)[:-1])

    def test_fused_round_is_gauss_seidel_not_jacobi(self, rng):
        """Later commit steps must see earlier commits (the whole point of
        the fusion): with S > 1 the fused round differs from applying every
        commit step against the frozen round-start frontier."""
        g = make_graph("twitter", scale=9, efactor=8, kind="pagerank")
        sched = make_schedule(g, 4, 32, PLUS_TIMES, min_chunk=8)
        assert sched.S > 1
        tele = np.float32(0.15 / g.n)
        row_update = lambda o, r, w: tele + r
        x = self._x_ext(g, PLUS_TIMES, rng)
        out_gs = np.asarray(fused_round(x, sched, PLUS_TIMES, row_update))
        # Jacobi variant: every step's reduction reads the original frontier
        x_j = x
        for s in range(sched.S):
            contrib = PLUS_TIMES.mul(x[sched.src[s]], sched.val[s])
            seg = (
                sched.dst_local[s]
                + (jnp.arange(sched.P, dtype=jnp.int32) * (sched.delta + 1))[:, None]
            )
            red = PLUS_TIMES.segment_reduce(
                contrib.reshape(-1), seg.reshape(-1), sched.P * (sched.delta + 1)
            ).reshape(sched.P, sched.delta + 1)[:, : sched.delta]
            new = tele + red
            x_j = x_j.at[sched.rows[s].reshape(-1)].set(new.reshape(-1), mode="drop")
        assert np.abs(out_gs[:-1] - np.asarray(x_j)[:-1]).max() > 1e-6

    def test_query_round_via_ops(self, rng):
        g = make_graph("twitter", scale=9, efactor=8, kind="pagerank")
        sched = make_schedule(g, 4, 48, PLUS_TIMES, min_chunk=8)
        row_update_q = lambda o, r, w, q: q[w] + r
        q = jnp.asarray(rng.random(g.n).astype(np.float32))
        x = self._x_ext(g, PLUS_TIMES, rng)
        out_k = fused_round(x, sched, PLUS_TIMES, row_update_q, q=q)
        out_r = fused_round(x, sched, PLUS_TIMES, row_update_q, q=q, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(out_k)[:-1], np.asarray(out_r)[:-1])

    def test_sync_schedule_single_kernel_step(self, rng):
        """S == 1 (sync): one commit step, still one fused kernel — exact
        Jacobi, matching the engine."""
        g = make_graph("kron", scale=8, efactor=8, kind="pagerank")
        sched = make_schedule(g, 4, None, PLUS_TIMES, mode="sync")
        assert sched.S == 1
        tele = np.float32(0.15 / g.n)
        row_update = lambda o, r, w: tele + r
        x = self._x_ext(g, PLUS_TIMES, rng)
        x_ref = np.asarray(round_fn(sched, PLUS_TIMES, row_update)(x))
        x_pal = np.asarray(
            fused_round_fn(sched, PLUS_TIMES, row_update, interpret=True)(x)
        )
        np.testing.assert_array_equal(x_ref[:-1], x_pal[:-1])
