"""Graceful degradation and serving-tier fault recovery under injected chaos.

Acceptance-criteria coverage for the degradation ladder and the scheduler's
retry machinery:

* the ladder drops the halo frontier first, then steps pallas/sharded →
  jit → host; a degraded solve returns the **bit-identical** answer (every
  backend computes the same rounds) and records a typed ``Degradation``;
* a faulted lane quantum evicts its riders back to the queue head and
  retries with exponential backoff — every answer is still delivered,
  bit-identical to the fault-free run;
* retry budgets, per-request deadlines, and per-lane circuit breakers
  retire undeliverable queries as typed ``QueryFailure`` records — the
  no-silent-loss accounting ``accepted == completed + failed`` holds, and
  a poisoned lane never wedges its neighbours or ``drain()``.
"""

import numpy as np
import pytest

from repro.ft.degrade import BACKEND_LADDER, degradation_ladder
from repro.ft.inject import FaultPlan, FaultSpec, InjectedFault, inject
from repro.graphs.generators import make_graph
from repro.launch.serve_graph import GraphService
from repro.launch.service import ClassPolicy, ContinuousScheduler, QueryRequest
from repro.solve import Solver, sssp_problem

GRAPH_S = make_graph("kron", scale=8, efactor=8, kind="sssp")


def sssp_service(**kw):
    kw.setdefault("n_workers", 4)
    kw.setdefault("delta", 32)
    kw.setdefault("batch_size", 4)
    kw.setdefault("min_chunk", 8)
    kw.setdefault("algos", ("sssp",))
    return GraphService(GRAPH_S, **kw)


class TestDegradationLadder:
    def test_ladder_orders(self):
        assert degradation_ladder("pallas", "halo") == [
            ("pallas", "halo"),
            ("pallas", "replicated"),
            ("jit", "replicated"),
            ("host", "replicated"),
        ]
        assert degradation_ladder("jit", "replicated") == [
            ("jit", "replicated"),
            ("host", "replicated"),
        ]
        assert degradation_ladder("host", "replicated") == [("host", "replicated")]
        assert BACKEND_LADDER["host"] is None  # the ladder has a floor

    def test_degraded_solve_bit_identical(self):
        ref = Solver(GRAPH_S, sssp_problem(), n_workers=4, delta=32).solve(
            backend="jit"
        )
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=4, delta=32, degrade=True)
        plan = FaultPlan([FaultSpec(site="kernel.dispatch", match={"backend": "jit"})])
        with inject(plan):
            out = solver.solve(backend="jit")
        assert plan.fired == 1
        assert len(solver.degradations) == 1
        d = solver.degradations[0]
        assert (d.from_backend, d.to_backend) == ("jit", "host")
        assert solver.stats["degradations"] == 1
        # performance degraded, the answer did not
        assert out.rounds == ref.rounds
        np.testing.assert_array_equal(out.x, ref.x)

    def test_degrade_off_raises(self):
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=4, delta=32)
        plan = FaultPlan([FaultSpec(site="kernel.dispatch")])
        with inject(plan):
            with pytest.raises(InjectedFault):
                solver.solve(backend="jit")

    def test_ladder_exhausted_reraises(self):
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=4, delta=32, degrade=True)
        plan = FaultPlan([FaultSpec(site="kernel.dispatch", at=0, times=-1)])
        with inject(plan):
            with pytest.raises(InjectedFault):
                solver.solve(backend="jit")
        assert len(solver.degradations) == 1  # jit→host tried before giving up

    def test_caller_errors_never_degraded(self):
        solver = Solver(GRAPH_S, sssp_problem(), n_workers=4, delta=32, degrade=True)
        with pytest.raises(ValueError):
            solver.solve(backend="warp")
        assert solver.degradations == []


def _submit_all(svc, payloads, **kw):
    ids = []
    for v in payloads:
        adm = svc.submit(QueryRequest(algo="sssp", payload=v, **kw))
        assert adm.accepted, adm.reason
        ids.append(adm.request_id)
    return ids


class TestSchedulerFaults:
    def test_lane_fault_retries_and_delivers_bit_identical(self):
        payloads = list(range(6))
        baseline = {r.payload: r.x for r in _drain_clean(payloads)}
        svc = sssp_service(queue_capacity=16)
        plan = FaultPlan([FaultSpec(site="scheduler.lane", at=0, times=1)])
        with inject(plan):
            ids = _submit_all(svc, payloads)
            results = svc.drain()
        assert svc.take_failures() == []
        assert sorted(r.request_id for r in results) == sorted(ids)
        for r in results:
            np.testing.assert_array_equal(r.x, baseline[r.payload])
        st = svc.scheduler.stats()
        assert st["counters"]["lane_faults"] == 1
        assert st["counters"]["retries"] >= 1
        assert st["counters"]["failed"] == 0
        assert st["counters"]["accepted"] == st["counters"]["completed"] == 6

    def test_poisoned_lane_fails_typed_and_terminates(self):
        svc = sssp_service(queue_capacity=16)
        plan = FaultPlan([FaultSpec(site="scheduler.lane", at=0, times=-1)])
        with inject(plan):
            ids = _submit_all(svc, range(4))
            results = svc.drain()  # must terminate, not spin
        failures = svc.take_failures()
        assert results == []
        assert sorted(f.request_id for f in failures) == sorted(ids)
        assert {f.reason for f in failures} == {"retries_exhausted"}
        # default policy: max_retries=2 ⇒ three faulted quanta per rider
        assert {f.attempts for f in failures} == {3}
        st = svc.scheduler.stats()
        assert st["counters"]["accepted"] == st["counters"]["failed"] == 4
        assert st["queue_depth"] == 0 and st["in_flight"] == 0

    def test_poisoned_lane_does_not_wedge_neighbours(self):
        classes = {
            "cheap": ClassPolicy(name="cheap", slot_rounds=2),
            "deep": ClassPolicy(name="deep", slot_rounds=8),
        }
        baseline = {r.payload: r.x for r in _drain_clean([1, 2, 3])}
        svc = sssp_service(classes=classes, queue_capacity=16)
        sched = ContinuousScheduler({"road": svc}, classes=classes, queue_capacity=16)
        plan = FaultPlan(
            [
                FaultSpec(
                    site="scheduler.lane",
                    at=0,
                    times=-1,
                    match={"request_class": "cheap"},
                )
            ]
        )
        with inject(plan):
            for v in (1, 2, 3):
                assert sched.submit(
                    QueryRequest(algo="sssp", payload=v, graph="road")
                ).accepted
            doomed = sched.submit(
                QueryRequest(
                    algo="sssp", payload=0, graph="road", request_class="cheap"
                )
            )
            assert doomed.accepted
            results = sched.drain()
        (failure,) = sched.take_failures()
        assert failure.request_id == doomed.request_id
        assert failure.reason == "retries_exhausted"
        assert len(results) == 3  # the deep lane never noticed
        for r in results:
            np.testing.assert_array_equal(r.x, baseline[r.payload])

    def test_circuit_breaker_opens_then_cools(self):
        classes = {
            "deep": ClassPolicy(
                name="deep",
                slot_rounds=8,
                max_retries=1,
                breaker_threshold=2,
                breaker_cooldown_rounds=10_000,
            )
        }
        svc = sssp_service(classes=classes, queue_capacity=16)
        plan = FaultPlan([FaultSpec(site="scheduler.lane", at=0, times=2)])
        with inject(plan):
            _submit_all(svc, [5])
            svc.drain()
        # two consecutive faulted quanta tripped the breaker
        (failure,) = svc.take_failures()
        assert failure.reason == "retries_exhausted"
        adm = svc.submit(QueryRequest(algo="sssp", payload=6))
        assert not adm.accepted and adm.reason == "lane_open"
        assert svc.scheduler.rejections["lane_open"] == 1
        brk = svc.scheduler.stats()["breakers"]["default/sssp/deep"]
        assert brk["open"] and brk["consecutive"] == 2
        # after the cooldown the lane half-opens and serves again
        svc.scheduler.advance_clock(brk["open_until"])
        assert svc.submit(QueryRequest(algo="sssp", payload=6)).accepted
        (r,) = svc.drain()
        assert r.converged
        assert not svc.scheduler.stats()["breakers"]["default/sssp/deep"]["open"]

    def test_deadline_exceeded_while_queued(self):
        svc = sssp_service(batch_size=4, queue_capacity=16)
        _submit_all(svc, range(4))  # fills every slot of the deep lane
        late = svc.submit(QueryRequest(algo="sssp", payload=9, deadline_rounds=1))
        assert late.accepted  # admission is about queue space, not deadlines
        results = svc.drain()
        (failure,) = svc.take_failures()
        assert failure.request_id == late.request_id
        assert failure.reason == "deadline_exceeded"
        assert failure.attempts == 0  # it never reached a slot
        assert len(results) == 4  # slotted-in queries run to retirement
        st = svc.scheduler.stats()
        assert st["counters"]["accepted"] == 5
        assert st["counters"]["completed"] == 4
        assert st["counters"]["failed"] == 1

    def test_deadline_generous_enough_completes(self):
        svc = sssp_service(batch_size=4, queue_capacity=16)
        _submit_all(svc, range(4))
        ok = svc.submit(QueryRequest(algo="sssp", payload=9, deadline_rounds=10_000))
        assert ok.accepted
        results = svc.drain()
        assert svc.take_failures() == []
        assert len(results) == 5


def _drain_clean(payloads):
    svc = sssp_service(queue_capacity=16)
    _submit_all(svc, payloads)
    return svc.drain()
