"""Training substrate: optimizer, schedules, accumulation, delayed commit,
checkpoint/restart, fault-tolerant runner."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data.pipeline import SyntheticLM
from repro.dist.delayed_commit import (
    DelayedCommitConfig,
    init_delayed_state,
    make_delayed_commit_step,
)
from repro.train.optimizer import AdamW, Adafactor, constant, linear_warmup_cosine, wsd
from repro.train.train_step import init_train_state, make_train_step

CFG = get_reduced("granite_8b")
KEY = jax.random.PRNGKey(0)


def batch_for(step, B=4, S=32, pods=0):
    data = SyntheticLM(vocab=CFG.vocab, seq_len=S, global_batch=B)
    b = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
    if pods:
        b = jax.tree.map(
            lambda x: x.reshape((pods, x.shape[0] // pods) + x.shape[1:]), b
        )
    return b


class TestOptimizers:
    def test_adamw_reduces_loss(self):
        opt = AdamW(schedule=constant(1e-2))
        state = init_train_state(CFG, opt, KEY)
        step = jax.jit(make_train_step(CFG, opt))
        losses = []
        for i in range(20):
            state, m = step(state, batch_for(0))  # same batch → must overfit
            losses.append(float(m["total_loss"]))
        assert losses[-1] < losses[0] - 0.5

    def test_adafactor_runs_and_reduces(self):
        opt = Adafactor(schedule=constant(1e-2))
        state = init_train_state(CFG, opt, KEY)
        step = jax.jit(make_train_step(CFG, opt))
        losses = []
        for i in range(20):
            state, m = step(state, batch_for(0))
            losses.append(float(m["total_loss"]))
        assert losses[-1] < losses[0] - 0.3

    def test_accum_matches_full_batch(self):
        """Microbatched grads average to the full-batch gradient.

        f32 + tiny lr so matmul reduction-order noise can't be amplified by
        Adam's first-step sign normalisation.
        """
        import dataclasses

        cfg = dataclasses.replace(CFG, dtype="float32")
        opt = AdamW(schedule=constant(1e-6))
        s1 = init_train_state(cfg, opt, KEY)
        s2 = init_train_state(cfg, opt, KEY)
        b = batch_for(0, B=8)
        step1 = jax.jit(make_train_step(cfg, opt, accum_steps=1))
        step4 = jax.jit(make_train_step(cfg, opt, accum_steps=4))
        s1, m1 = step1(s1, b)
        s2, m2 = step4(s2, b)
        # losses agree exactly up to f32 reduction order
        assert abs(float(m1["total_loss"]) - float(m2["total_loss"])) < 1e-5
        d = jax.tree.map(
            lambda a, b: float(jnp.abs(a - b).max()), s1.params, s2.params
        )
        assert max(jax.tree.leaves(d)) < 5e-6  # bounded by 2·lr + noise

    def test_schedules(self):
        sc = linear_warmup_cosine(1.0, warmup=10, total=100)
        assert float(sc(jnp.asarray(5))) == pytest.approx(0.5)
        assert float(sc(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)
        sw = wsd(1.0, warmup=10, stable=50, decay=40)
        assert float(sw(jnp.asarray(30))) == 1.0
        assert float(sw(jnp.asarray(100))) == pytest.approx(0.01, rel=1e-3)


class TestDelayedCommit:
    """The paper's δ-buffering at training scale (DESIGN.md §3)."""

    def test_delta1_equals_sync_dp(self):
        """δ=1 with identical pod batches must reproduce plain DP exactly.

        (With *different* pod shards, δ=1 is mean-of-local-Adam-steps which
        differs from Adam-on-mean-gradients by Adam's nonlinearity — the
        local-update semantics of the paper's buffer, see module docstring.)
        """
        opt = AdamW(schedule=constant(1e-3))
        cc = DelayedCommitConfig(n_pods=2, delta=1)
        ds = init_delayed_state(CFG, opt, cc, KEY)
        dstep = jax.jit(make_delayed_commit_step(CFG, opt, cc))
        ss = init_train_state(CFG, opt, KEY)
        sstep = jax.jit(make_train_step(CFG, opt))
        b = batch_for(0, B=8)
        bp = jax.tree.map(lambda x: jnp.stack([x, x]), b)  # same batch per pod
        for i in range(3):
            ds, _ = dstep(ds, bp)
            ss, _ = sstep(ss, b)
        diff = jax.tree.map(
            lambda a, b: float(jnp.abs(a - b).max()), ds.global_params, ss.params
        )
        assert max(jax.tree.leaves(diff)) < 1e-5

    def test_commit_period_semantics(self):
        opt = AdamW(schedule=constant(1e-3))
        cc = DelayedCommitConfig(n_pods=2, delta=3)
        ds = init_delayed_state(CFG, opt, cc, KEY)
        dstep = jax.jit(make_delayed_commit_step(CFG, opt, cc))
        g0 = jax.tree.leaves(ds.global_params)[0].copy()
        bp = batch_for(0, B=8, pods=2)
        for i in range(1, 4):
            ds, m = dstep(ds, bp)
            committed = float(m["committed"])
            if i % 3 == 0:
                assert committed == 1.0
            else:
                assert committed == 0.0
                # global params untouched between commits
                assert jnp.array_equal(jax.tree.leaves(ds.global_params)[0], g0)
        assert not jnp.array_equal(jax.tree.leaves(ds.global_params)[0], g0)

    def test_delayed_commit_converges(self):
        opt = AdamW(schedule=constant(5e-3))
        cc = DelayedCommitConfig(n_pods=2, delta=4)
        ds = init_delayed_state(CFG, opt, cc, KEY)
        dstep = jax.jit(make_delayed_commit_step(CFG, opt, cc))
        losses = []
        for i in range(24):
            ds, m = dstep(ds, batch_for(0, B=8, pods=2))
            losses.append(float(m["total_loss"]))
        assert losses[-1] < losses[0] - 0.5

    @pytest.mark.parametrize("compress", ["int8", "topk"])
    def test_compressed_commit_still_learns(self, compress):
        opt = AdamW(schedule=constant(5e-3))
        cc = DelayedCommitConfig(n_pods=2, delta=2, compress=compress, topk_frac=0.25)
        ds = init_delayed_state(CFG, opt, cc, KEY)
        dstep = jax.jit(make_delayed_commit_step(CFG, opt, cc))
        losses = []
        for i in range(16):
            ds, m = dstep(ds, batch_for(0, B=8, pods=2))
            losses.append(float(m["total_loss"]))
        assert losses[-1] < losses[0] - 0.3


class TestCheckpoint:
    def test_roundtrip_and_elastic(self, tmp_path):
        from repro.ckpt.checkpoint import restore_checkpoint, save_checkpoint

        opt = AdamW(schedule=constant(1e-3))
        state = init_train_state(CFG, opt, KEY)
        # two hosts write, then restore on a different host count
        save_checkpoint(tmp_path, 7, state, host_index=0, n_hosts=2)
        save_checkpoint(tmp_path, 7, state, host_index=1, n_hosts=2)
        restored = restore_checkpoint(tmp_path, 7, state)
        flat_a = jax.tree.leaves(state.params)
        flat_b = jax.tree.leaves(restored.params)
        for a, b in zip(flat_a, flat_b):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_latest_step_ignores_uncommitted(self, tmp_path):
        from repro.ckpt.checkpoint import latest_step, save_checkpoint

        opt = AdamW(schedule=constant(1e-3))
        state = init_train_state(CFG, opt, KEY)
        save_checkpoint(tmp_path, 5, state)
        (tmp_path / "step_000000009").mkdir()  # torn write: no _COMMITTED
        assert latest_step(tmp_path) == 5


class TestFTRunner:
    def test_failure_recovery_replays_and_finishes(self, tmp_path):
        from repro.ft.runner import FailureInjector, RunnerConfig, run_training

        opt = AdamW(schedule=constant(1e-3))
        state = init_train_state(CFG, opt, KEY)
        step = jax.jit(make_train_step(CFG, opt))
        cfg = RunnerConfig(total_steps=12, ckpt_every=4, ckpt_dir=str(tmp_path))
        inj = FailureInjector(fail_at=[6, 10])
        state, hist = run_training(
            state, step, lambda s: batch_for(s), cfg, injector=inj
        )
        assert hist["restarts"] == 2
        assert int(state.step) == 12

    def test_straggler_monitor_flags_outliers(self):
        from repro.ft.runner import StragglerMonitor

        m = StragglerMonitor(z_thresh=3.0)
        for _ in range(50):
            m.observe(0.1 + np.random.default_rng(0).normal() * 0.0)
        assert m.observe(10.0) is True


class TestDataPipeline:
    def test_deterministic_and_shardable(self):
        d = SyntheticLM(vocab=1000, seq_len=16, global_batch=8)
        b1, b2 = d.batch(3), d.batch(3)
        assert np.array_equal(b1["tokens"], b2["tokens"])
        sh0 = d.shard(3, 0, 2)
        sh1 = d.shard(3, 1, 2)
        glued = np.concatenate([sh0["tokens"], sh1["tokens"]])
        assert np.array_equal(glued, b1["tokens"])
        assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()
        assert (b1["labels"][:, -1] == -1).all()

    def test_file_backed(self, tmp_path):
        from repro.data.pipeline import FileBackedLM

        arr = np.arange(1000, dtype=np.int32) % 97
        fn = tmp_path / "tokens.bin"
        arr.tofile(fn)
        d = FileBackedLM(str(fn), vocab=97, seq_len=10, global_batch=4)
        b = d.batch(0)
        assert b["tokens"].shape == (4, 10)
        assert (b["tokens"] < 97).all()