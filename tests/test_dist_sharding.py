"""repro.dist.sharding: rules round-trip, logical() gating, param specs."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import all_arch_ids, get_config
from repro.dist.compat import make_mesh, set_mesh
from repro.dist.sharding import (
    Rules,
    current_rules,
    logical,
    tree_param_specs,
    use_rules,
)


class FakeMesh:
    """Production mesh axis sizes without needing 512 local devices."""

    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


PROD = FakeMesh(pod=2, data=16, model=16)


class TestRules:
    def test_roundtrip(self):
        r = Rules.default(shard_cache_heads=True, seq_axis="model")
        assert Rules.from_dict(r.to_dict()) == r
        assert r.to_dict()["kv_heads"] == "model"
        assert Rules.default().mapping["cache_seq"] == "model"

    def test_spec_drops_nondividing_and_reused_axes(self):
        r = Rules.default()
        # vocab 100 not divisible by |model|=16 → replicated
        assert r.spec(("vocab", "embed_fsdp"), PROD, (100, 64)) == P(None, "data")
        # batch spans pod×data = 32
        assert r.spec(("batch", "seq"), PROD, (64, 128)) == P(("pod", "data"), None)
        assert r.spec(("batch", "seq"), PROD, (8, 128)) == P(None, None)

    def test_use_rules_scopes(self):
        assert current_rules() is None
        with use_rules(Rules.default()) as r:
            assert current_rules() is r
        assert current_rules() is None


class TestLogical:
    def test_noop_outside_mesh(self):
        x = jnp.ones((4, 8))
        assert logical(x, ("batch", "embed")) is x
        with use_rules(Rules.default()):
            # rules active but still no mesh context → still a no-op
            assert logical(x, ("batch", "embed")) is x

    def test_applies_constraint_under_mesh(self):
        mesh = make_mesh((1, 1), ("data", "model"))
        x = jnp.ones((4, 8))
        with use_rules(Rules.default(seq_axis="model")), set_mesh(mesh):
            y = jax.jit(lambda a: logical(a, ("batch", "embed")))(x)
        assert jnp.array_equal(y, x)


class TestTreeParamSpecs:
    @pytest.mark.parametrize("arch", all_arch_ids())
    def test_specs_valid_for_arch(self, arch):
        cfg = get_config(arch)
        from repro.models import init_params

        params = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
        rules = Rules.default(seq_axis="model")
        specs = tree_param_specs(params, rules, PROD)
        flat_p, tdef_p = jax.tree_util.tree_flatten(params)
        flat_s, tdef_s = jax.tree_util.tree_flatten(
            specs, is_leaf=lambda x: isinstance(x, P)
        )
        assert tdef_p == tdef_s  # congruent trees
        for leaf, spec in zip(flat_p, flat_s):
            assert isinstance(spec, P)
            assert len(spec) == leaf.ndim
            used = []
            for dim, entry in zip(leaf.shape, spec):
                axes = entry if isinstance(entry, tuple) else (entry,)
                for a in axes:
                    if a is None:
                        continue
                    assert a in PROD.shape and a not in used
                    used.append(a)
                total = 1
                for a in axes:
                    if a is not None:
                        total *= PROD.shape[a]
                assert dim % total == 0

    def test_known_layouts(self):
        cfg = get_config("granite-8b")
        from repro.models import init_params

        params = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
        specs = tree_param_specs(params, Rules.default(), PROD)
        assert specs["embed"] == P("model", "data")  # vocab × d_model
        layer = specs["layers"]["b0_attn"]
        assert layer["wq"] == P(None, "data", "model")  # stacked (L, d, H·hd)
        assert layer["wo"] == P(None, "model", "data")
        assert layer["ln1"] == P(None, None)
