"""backend="pallas" × frontier="halo": fused sharded rounds, quantized halo.

Acceptance coverage for the composed fastest path (ISSUE 8's tentpole):

* ``Solver(backend="pallas", frontier="halo")`` is bit-identical to
  ``backend="jit"`` for pagerank / sssp / cc / jacobi in every discipline
  (sync / async / delayed) at the default ``halo_dtype="f32"`` — fixed point
  AND per round;
* ``halo_dtype="int8"`` / ``"fp8"`` converge to the same fixed point within
  quantization tolerance, with the round-count delta logged;
* the table-driven backend × frontier validation produces exact error
  messages, low-precision halo rejects non-floating semirings, and batched
  pallas+halo points at the sharded backend;
* cache keys: the fused halo round compiles once per
  ``("pallas-halo", δ, dtype, D)`` and a second solve is warm;
* a hypothesis property test drives random graphs × P × δ × semiring
  through the fused halo round against the engine's reference round.

Device-count adaptive like ``tests/test_frontier_sharded.py``: with 1 local
device the mesh is 1-wide (halo sets empty, the exchange machinery still
runs); under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
matrix entry) the same tests exercise real 8-way sharding.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms.jacobi import jacobi_graph
from repro.core.engine import make_schedule, round_fn
from repro.core.semiring import INT_INF, MIN_PLUS, PLUS_TIMES
from repro.dist.compat import make_mesh
from repro.dist.engine_sharded import (
    frontier_ef_init,
    frontier_pallas_round_ext_fn,
    frontier_plan_args,
    make_frontier_plan,
)
from repro.graphs.formats import CSRGraph
from repro.graphs.generators import make_graph
from repro.solve import (
    Solver,
    cc_problem,
    jacobi_problem,
    multi_source_x0,
    pagerank_problem,
    ppr_problem,
    ppr_teleport,
    solve_batch,
    sssp_problem,
)

N_WORKERS = 8


def mesh_width() -> int:
    """Largest power-of-two device count dividing N_WORKERS."""
    return math.gcd(N_WORKERS, len(jax.devices()))


GRAPH_PR = make_graph("twitter", scale=9, efactor=8, kind="pagerank")
GRAPH_S = make_graph("kron", scale=8, efactor=8, kind="sssp")
GRAPH_U = make_graph("road", scale=8, kind="unit")


def _jacobi_case():
    rng = np.random.default_rng(0)
    n = 256
    rows = np.repeat(np.arange(n), 4)
    cols = (rows + rng.integers(1, n, rows.shape[0])) % n
    vals = rng.normal(size=rows.shape[0]).astype(np.float32) * 0.1
    diag = np.full(n, 4.0, np.float32)
    b = rng.normal(size=n).astype(np.float32)
    return jacobi_graph(n, rows, cols, vals, diag), jacobi_problem(diag, b)


CASES = {
    "pagerank": lambda: (GRAPH_PR, pagerank_problem()),
    "sssp": lambda: (GRAPH_S, sssp_problem()),
    "cc": lambda: (GRAPH_U, cc_problem()),
    "jacobi": _jacobi_case,
}

# The paper's three disciplines, as Solver δ arguments.
MODES = {"sync": "sync", "async": "async", "delayed": 48}


class TestFourProblemParity:
    @pytest.mark.parametrize("mode", sorted(MODES))
    @pytest.mark.parametrize("name", sorted(CASES))
    def test_f32_fixed_point_bit_identical_to_jit(self, name, mode):
        graph, problem = CASES[name]()
        solver = Solver(
            graph, problem, n_workers=N_WORKERS, delta=MODES[mode], min_chunk=16
        )
        r_jit = solver.solve(backend="jit")
        r_ph = solver.solve(backend="pallas", frontier="halo")
        assert r_ph.rounds == r_jit.rounds
        assert r_ph.converged == r_jit.converged
        np.testing.assert_array_equal(r_ph.x, r_jit.x)

    @pytest.mark.parametrize("name", sorted(CASES))
    def test_per_round_bit_identical(self, name):
        graph, problem = CASES[name]()
        solver = Solver(graph, problem, n_workers=N_WORKERS, delta=48, min_chunk=16)
        rnd_host = solver.round_callable(backend="host")
        rnd_ph = solver.round_callable(backend="pallas", frontier="halo")
        x_h = x_p = solver._x_ext(None)
        for _ in range(3):
            x_h, x_p = rnd_host(x_h), rnd_ph(x_p)
            # owned frontier identical; the local dump slots differ by design
            np.testing.assert_array_equal(np.asarray(x_h[:-1]), np.asarray(x_p[:-1]))

    def test_ppr_query_threading(self):
        solver = Solver(
            GRAPH_PR, ppr_problem(), n_workers=N_WORKERS, delta=64, min_chunk=16
        )
        q = ppr_teleport(GRAPH_PR, [5])[0]
        r_jit = solver.solve(q=q, backend="jit")
        r_ph = solver.solve(q=q, backend="pallas", frontier="halo")
        assert r_ph.rounds == r_jit.rounds
        np.testing.assert_array_equal(r_ph.x, r_jit.x)


class TestQuantizedHalo:
    @pytest.mark.parametrize("halo_dtype", ["int8", "fp8"])
    def test_low_precision_converges_to_same_fixed_point(self, halo_dtype):
        solver = Solver(
            GRAPH_PR, pagerank_problem(), n_workers=N_WORKERS, delta=64, min_chunk=16
        )
        r_jit = solver.solve(backend="jit")
        # Quantization noise floors the per-round residual near the
        # per-commit scale (~2e-4 here), so the convergence tolerance must
        # sit above that floor — the fixed point itself is still accurate to
        # ~2e-5, which the allclose below checks against the exact solve.
        tol = max(solver.tol, 1e-3)
        r_q = solver.solve(
            backend="pallas", frontier="halo", halo_dtype=halo_dtype, tol=tol
        )
        assert r_q.converged
        np.testing.assert_allclose(np.asarray(r_q.x), np.asarray(r_jit.x), atol=1e-3)
        print(
            f"{halo_dtype} halo rounds: {r_q.rounds} "
            f"(jit: {r_jit.rounds}, delta {r_q.rounds - r_jit.rounds:+d})"
        )

    def test_jacobi_int8_converges(self):
        graph, problem = _jacobi_case()
        solver = Solver(graph, problem, n_workers=N_WORKERS, delta=48, min_chunk=16)
        r_jit = solver.solve(backend="jit")
        tol = max(solver.tol, 1e-3)
        r_q = solver.solve(
            backend="pallas", frontier="halo", halo_dtype="int8", tol=tol
        )
        assert r_q.converged
        np.testing.assert_allclose(np.asarray(r_q.x), np.asarray(r_jit.x), atol=1e-3)

    def test_f32_default_keeps_exactness(self):
        """A solver constructed with a low-precision default still runs the
        exact paths exactly: non-halo backends silently resolve to f32."""
        solver = Solver(
            GRAPH_PR,
            pagerank_problem(),
            n_workers=N_WORKERS,
            delta=64,
            min_chunk=16,
            halo_dtype="int8",
        )
        r_jit = solver.solve(backend="jit")
        r_sh = solver.solve(backend="sharded", frontier="halo")
        np.testing.assert_array_equal(r_jit.x, r_sh.x)


class TestValidationTable:
    def _solver(self, problem=None, graph=None):
        return Solver(
            graph if graph is not None else GRAPH_PR,
            problem if problem is not None else pagerank_problem(),
            n_workers=N_WORKERS,
            delta=32,
            min_chunk=16,
        )

    @pytest.mark.parametrize("backend", ["host", "jit"])
    def test_halo_rejects_single_device_backends(self, backend):
        solver = self._solver()
        with pytest.raises(
            ValueError,
            match=(
                "frontier='halo' requires backend='sharded' or "
                f"backend='pallas', got '{backend}'"
            ),
        ):
            solver.solve(backend=backend, frontier="halo")

    def test_low_precision_requires_pallas_halo(self):
        solver = self._solver()
        with pytest.raises(
            ValueError, match="halo_dtype='int8' requires backend='pallas'"
        ):
            solver.solve(backend="sharded", frontier="halo", halo_dtype="int8")
        with pytest.raises(
            ValueError, match="halo_dtype='fp8' requires backend='pallas'"
        ):
            solver.solve(backend="pallas", frontier="replicated", halo_dtype="fp8")

    def test_unknown_halo_dtype(self):
        with pytest.raises(ValueError, match="halo_dtype must be one of"):
            Solver(GRAPH_PR, pagerank_problem(), halo_dtype="bf16")
        solver = self._solver()
        with pytest.raises(ValueError, match="halo_dtype must be one of"):
            solver.solve(backend="pallas", frontier="halo", halo_dtype="bf16")

    def test_min_plus_rejects_low_precision(self):
        solver = self._solver(problem=sssp_problem(), graph=GRAPH_S)
        with pytest.raises(ValueError, match="floating-point semiring"):
            solver.solve(backend="pallas", frontier="halo", halo_dtype="int8")

    def test_batched_pallas_halo_points_to_sharded(self):
        solver = self._solver(problem=sssp_problem(), graph=GRAPH_S)
        with pytest.raises(ValueError, match="backend='sharded', frontier='halo'"):
            solve_batch(
                solver,
                multi_source_x0(GRAPH_S, [0]),
                backend="pallas",
                frontier="halo",
            )


class TestCache:
    def test_key_anatomy_and_warm_second_solve(self):
        solver = Solver(
            GRAPH_PR, pagerank_problem(), n_workers=N_WORKERS, delta=64, min_chunk=16
        )
        r1 = solver.solve(backend="pallas", frontier="halo")
        d, D = solver.schedule().delta, mesh_width()
        assert ("pallas-halo", d, "f32", D) in solver._compiled
        snap = dict(solver.stats)
        r2 = solver.solve(backend="pallas", frontier="halo")
        assert solver.stats["traces"] == snap["traces"]
        assert solver.stats["compiles"] == snap["compiles"]
        np.testing.assert_array_equal(r1.x, r2.x)
        # each dtype is its own executable
        solver.solve(backend="pallas", frontier="halo", halo_dtype="int8")
        assert ("pallas-halo", d, "int8", D) in solver._compiled


# --------------------------------------------------------------------------- #
# Property test: fused halo round ≡ reference round on random graphs × P × δ
# --------------------------------------------------------------------------- #
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    SETTINGS = dict(
        deadline=None,
        max_examples=10,
        suppress_health_check=[HealthCheck.too_slow],
    )

    @st.composite
    def random_case(draw):
        n = draw(st.integers(min_value=8, max_value=96))
        m = draw(st.integers(min_value=1, max_value=5 * n))
        seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        semiring = draw(st.sampled_from(["plus_times", "min_plus"]))
        p_loc = draw(st.integers(min_value=1, max_value=3))
        delta = draw(st.integers(min_value=1, max_value=24))
        rng = np.random.default_rng(seed)
        src = rng.integers(0, n, m)
        dst = rng.integers(0, n, m)
        if semiring == "min_plus":
            vals = rng.integers(1, 64, m).astype(np.int32)
        else:
            vals = (rng.random(m) * 0.2).astype(np.float32)
        g = CSRGraph.from_edges(n, src, dst, vals, name=f"ph{seed}")
        return g, semiring, p_loc, delta, seed

    @given(random_case())
    @settings(**SETTINGS)
    def test_pallas_halo_round_bit_identical_property(case):
        g, sr_name, p_loc, delta, seed = case
        D = mesh_width()
        P = D * p_loc
        sr = MIN_PLUS if sr_name == "min_plus" else PLUS_TIMES
        sched = make_schedule(g, P, delta, sr)
        plan = make_frontier_plan(sched, D)
        mesh = make_mesh((D,), ("data",), devices=jax.devices()[:D])
        rng = np.random.default_rng(seed)
        if sr_name == "min_plus":
            row_update_q = lambda o, r, w, q: jnp.minimum(o, r)
            x0 = rng.integers(0, INT_INF, g.n, dtype=np.int32)
        else:
            row_update_q = lambda o, r, w, q: jnp.float32(0.01) + r
            x0 = rng.random(g.n).astype(np.float32)
        row_update = lambda o, r, w: row_update_q(o, r, w, None)
        ref = jax.jit(round_fn(sched, sr, row_update))
        ext = jax.jit(frontier_pallas_round_ext_fn(sched, plan, sr, row_update_q, mesh))
        args = frontier_plan_args(sched, plan)
        ef = frontier_ef_init(plan)
        x = jnp.concatenate(
            [jnp.asarray(x0, sr.dtype), jnp.asarray([sr.zero], sr.dtype)]
        )
        x_ref = x_ph = x
        q = jnp.zeros((), jnp.int32)
        for _ in range(3):
            x_ref = ref(x_ref)
            x_ph, ef = ext(x_ph, ef, q, *args)
            np.testing.assert_array_equal(
                np.asarray(x_ref[:-1]), np.asarray(x_ph[:-1])
            )
            assert not np.asarray(ef).any()  # f32 halo never carries residuals
