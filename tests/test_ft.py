"""Chaos harness, elastic solve checkpoints, persist hardening, FT runner.

Acceptance-criteria coverage for the fault-tolerance tier:

* ``FaultPlan`` firing is a pure function of the call sequence
  (``at`` / ``every`` / seeded ``p`` / ``match`` / ``times``) and plans
  round-trip through JSON, so committed chaos traces replay identically;
* ``checkpointed_solve`` resumes **bit-identically** — same per-round
  trajectory and fixed point as the uninterrupted solve — after injected
  faults, after a simulated process kill, and from a cold start;
* torn / corrupt / EIO checkpoint and cache writes read as *absent*
  (cold start / cache miss), never as exceptions, and concurrent cache
  writers never publish torn bytes (unique tmp + atomic replace);
* delayed-commit state reshards elastically: same pod count resumes
  bit-identical, a different count folds buffered deltas into the global
  store (fixed-point-identical);
* the training runner counts every step's loss exactly once across
  restore-and-replay (the history truncation fix).
"""

import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager, latest_step, save_checkpoint
from repro.dist.delayed_commit import DelayedCommitState, reshard_delayed_state
from repro.ft.elastic import checkpointed_solve, restore_delayed_state
from repro.ft.inject import FaultPlan, FaultSpec, InjectedFault, active_plan, inject
from repro.ft.runner import FailureInjector, RunnerConfig, run_training
from repro.graphs.generators import make_graph
from repro.persist.store import SolverCache
from repro.solve import Solver, sssp_problem

GRAPH_S = make_graph("kron", scale=8, efactor=8, kind="sssp")


def sssp_solver(**kw):
    kw.setdefault("n_workers", 4)
    kw.setdefault("delta", 32)
    kw.setdefault("min_chunk", 8)
    return Solver(GRAPH_S, sssp_problem(), **kw)


class TestFaultPlan:
    def test_at_and_times(self):
        plan = FaultPlan([FaultSpec(site="s", at=2, times=2)])
        fired = []
        for visit in range(6):
            try:
                plan.fire("s")
            except InjectedFault:
                fired.append(visit)
        assert fired == [2, 3]
        assert plan.fired == 2

    def test_every_unlimited(self):
        plan = FaultPlan([FaultSpec(site="s", every=3, times=-1)])
        fired = []
        for visit in range(9):
            try:
                plan.fire("s")
            except InjectedFault:
                fired.append(visit)
        assert fired == [2, 5, 8]

    def test_match_filters_context(self):
        plan = FaultPlan([FaultSpec(site="k", match={"backend": "pallas"})])
        assert plan.fire("k", backend="jit") is None
        assert plan.fire("k") is None  # absent context key never matches
        with pytest.raises(InjectedFault):
            plan.fire("k", backend="pallas")

    def test_io_kinds_returned_not_raised(self):
        plan = FaultPlan([FaultSpec(site="w", kind="torn", times=-1, at=0)])
        assert plan.fire("w") == "torn"
        assert plan.fire("r") is None  # other sites untouched

    def test_seeded_p_deterministic(self):
        def run(seed):
            plan = FaultPlan([FaultSpec(site="s", p=0.3, times=-1)], seed=seed)
            out = []
            for _ in range(40):
                try:
                    plan.fire("s")
                    out.append(0)
                except InjectedFault:
                    out.append(1)
            return out

        assert run(7) == run(7)
        assert sum(run(7)) > 0

    def test_json_roundtrip_replays_identically(self):
        plan = FaultPlan(
            [
                FaultSpec(site="a", at=1, times=2, match={"round": 3}),
                FaultSpec(site="b", kind="eio", every=2, times=-1),
            ],
            seed=5,
        )
        back = FaultPlan.loads(plan.dumps())
        seq = [("a", {"round": 3}), ("b", {}), ("a", {"round": 0}), ("b", {})]

        def trace(p):
            out = []
            for _ in range(3):
                for site, ctx in seq:
                    try:
                        out.append(p.fire(site, **ctx))
                    except InjectedFault:
                        out.append("raised")
            return out

        assert trace(plan) == trace(back)
        assert plan.events == back.events

    def test_inject_context_scopes_plan(self):
        from repro.ft.inject import fire

        assert active_plan() is None
        assert fire("anything") is None  # no plan installed: no-op
        plan = FaultPlan([FaultSpec(site="s")])
        with inject(plan):
            assert active_plan() is plan
            with pytest.raises(InjectedFault):
                fire("s")
        assert active_plan() is None
        assert plan.sites_fired() == ["s"]


class TestCheckpointedSolve:
    def test_no_fault_matches_plain_solve(self, tmp_path):
        # host reference: same bit-identical rounds as jit, but the host
        # loop records per-round residuals (the fused jit path keeps only
        # the final one), so the whole trajectory is comparable
        solver = sssp_solver()
        ref = solver.solve(backend="host")
        out = checkpointed_solve(
            sssp_solver(), backend="jit", ckpt_dir=tmp_path, every=4
        )
        assert out.restores == 0 and out.resumed_at is None
        assert out.result.rounds == ref.rounds
        np.testing.assert_array_equal(out.result.x, ref.x)
        np.testing.assert_array_equal(out.result.residuals, ref.residuals)

    def test_fault_restores_and_stays_bit_identical(self, tmp_path):
        ref = sssp_solver().solve(backend="host")
        plan = FaultPlan([FaultSpec(site="solver.round", match={"round": 6})])
        with inject(plan):
            out = checkpointed_solve(
                sssp_solver(), backend="jit", ckpt_dir=tmp_path, every=4
            )
        assert plan.fired == 1
        assert out.restores == 1
        # killed at round 6, restored to the round-4 snapshot: 2 replayed
        assert out.rounds_executed == ref.rounds + 2
        assert out.result.rounds == ref.rounds
        np.testing.assert_array_equal(out.result.x, ref.x)
        np.testing.assert_array_equal(out.result.residuals, ref.residuals)

    def test_cold_restart_before_first_snapshot(self, tmp_path):
        ref = sssp_solver().solve(backend="host")
        plan = FaultPlan([FaultSpec(site="solver.round", match={"round": 2})])
        with inject(plan):
            out = checkpointed_solve(
                sssp_solver(), backend="jit", ckpt_dir=tmp_path, every=64
            )
        assert out.restores == 1
        assert out.rounds_executed == ref.rounds + 2  # full replay from 0
        np.testing.assert_array_equal(out.result.x, ref.x)

    def test_kill_and_resume_fresh_process(self, tmp_path):
        """Simulated kill -9 mid-solve; a fresh solver resumes from disk."""
        ref = sssp_solver().solve(backend="host")
        plan = FaultPlan([FaultSpec(site="solver.round", match={"round": 6})])
        with inject(plan):
            with pytest.raises(InjectedFault):
                checkpointed_solve(
                    sssp_solver(),
                    backend="jit",
                    ckpt_dir=tmp_path,
                    every=4,
                    max_restores=0,  # the "process" dies on the first fault
                )
        out = checkpointed_solve(
            sssp_solver(), backend="jit", ckpt_dir=tmp_path, every=4
        )
        assert out.resumed_at == 4
        assert out.rounds_executed == ref.rounds - 4
        assert out.result.rounds == ref.rounds
        np.testing.assert_array_equal(out.result.x, ref.x)
        np.testing.assert_array_equal(out.result.residuals, ref.residuals)

    def test_max_restores_exhausted_raises(self, tmp_path):
        plan = FaultPlan([FaultSpec(site="solver.round", at=0, times=-1)])
        with inject(plan):
            with pytest.raises(InjectedFault):
                checkpointed_solve(
                    sssp_solver(),
                    backend="jit",
                    ckpt_dir=tmp_path,
                    every=4,
                    max_restores=2,
                )
        assert plan.fired == 3  # initial fault + max_restores failed retries


def _toy_delayed_state(n_pods=2, delta=1.0):
    gp = {"w": jnp.arange(6.0, dtype=jnp.float32).reshape(2, 3)}
    return DelayedCommitState(
        global_params=gp,
        local_delta={"w": jnp.full((n_pods, 2, 3), delta, jnp.float32)},
        opt_state={
            "m": jnp.ones((n_pods, 2, 3), jnp.float32),
            "count": jnp.asarray(9, jnp.int32),
        },
        step=jnp.asarray(5, jnp.int32),
    )


class TestElasticDelayedState:
    def test_same_width_is_identity(self):
        state = _toy_delayed_state(n_pods=2)
        back = reshard_delayed_state(state, 2)
        assert back is state  # bit-identical resume, no copies

    def test_different_width_folds_deltas(self):
        state = _toy_delayed_state(n_pods=2, delta=1.0)
        back = reshard_delayed_state(state, 4)
        # one flush-equivalent commit: mean of per-pod deltas folds in
        np.testing.assert_array_equal(
            np.asarray(back.global_params["w"]),
            np.asarray(state.global_params["w"]) + 1.0,
        )
        assert back.local_delta["w"].shape == (4, 2, 3)
        assert not np.asarray(back.local_delta["w"]).any()
        assert back.opt_state["m"].shape == (4, 2, 3)
        assert int(back.opt_state["count"]) == 9  # shared scalar passes through
        assert int(back.step) == 5

    def test_restore_roundtrip_and_elastic(self, tmp_path):
        state = _toy_delayed_state(n_pods=2, delta=0.5)
        save_checkpoint(tmp_path, 3, state)
        step, same = restore_delayed_state(tmp_path, state, n_pods=2)
        assert step == 3
        np.testing.assert_array_equal(
            np.asarray(same.local_delta["w"]), np.asarray(state.local_delta["w"])
        )
        step, wider = restore_delayed_state(tmp_path, state, n_pods=4)
        assert step == 3
        np.testing.assert_array_equal(
            np.asarray(wider.global_params["w"]),
            np.asarray(state.global_params["w"]) + 0.5,
        )
        assert wider.local_delta["w"].shape == (4, 2, 3)

    def test_restore_missing_or_mismatched_is_none(self, tmp_path):
        state = _toy_delayed_state()
        assert restore_delayed_state(tmp_path, state, 2) == (None, None)
        save_checkpoint(tmp_path, 1, {"other": jnp.zeros(3)})
        assert restore_delayed_state(tmp_path, state, 2) == (None, None)


class TestCheckpointFaults:
    def test_torn_commit_is_invisible(self, tmp_path):
        tree = {"x": jnp.arange(4.0)}
        with inject(FaultPlan([FaultSpec(site="ckpt.write", kind="torn")])):
            save_checkpoint(tmp_path, 5, tree)
        # shards + manifest landed but _COMMITTED never did: restart skips it
        assert (tmp_path / "step_000000005" / "manifest.json").exists()
        assert latest_step(tmp_path) is None
        save_checkpoint(tmp_path, 7, tree)
        assert latest_step(tmp_path) == 7

    def test_eio_write_raises_and_runner_survives(self, tmp_path):
        tree = {"x": jnp.arange(4.0)}
        with inject(FaultPlan([FaultSpec(site="ckpt.write", kind="eio")])):
            with pytest.raises(OSError):
                save_checkpoint(tmp_path, 5, tree)
        assert latest_step(tmp_path) is None

    def test_manager_gc_keeps_last_k(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        for step in (1, 2, 3, 4):
            mgr.save(step, {"x": jnp.asarray(float(step))}, block=True)
        assert latest_step(tmp_path) == 4
        committed = sorted(
            p.name for p in tmp_path.iterdir() if p.name.startswith("step_")
        )
        assert committed == ["step_000000003", "step_000000004"]


def _stripe(fill: int) -> dict:
    return {
        "src": np.full(8, fill, np.int64),
        "val": np.full(8, float(fill), np.float32),
        "dst_local": np.arange(8, dtype=np.int64),
        "rows": np.arange(8, dtype=np.int64),
    }


class TestPersistFaults:
    @pytest.mark.parametrize("kind", ["torn", "corrupt", "eio"])
    def test_injected_write_fault_reads_as_miss(self, tmp_path, kind):
        cache = SolverCache(tmp_path, "f" * 16)
        digest = "a" * 24
        with inject(FaultPlan([FaultSpec(site="persist.write", kind=kind)])):
            cache.save_stripe(digest, _stripe(3))  # must not raise
        assert cache.load_stripe(digest) is None  # corruption ⇒ miss
        cache.save_stripe(digest, _stripe(3))  # clean retry heals
        got = cache.load_stripe(digest)
        np.testing.assert_array_equal(got["src"], _stripe(3)["src"])

    def test_injected_read_fault_is_transient_miss(self, tmp_path):
        cache = SolverCache(tmp_path, "f" * 16)
        digest = "b" * 24
        cache.save_stripe(digest, _stripe(7))
        with inject(FaultPlan([FaultSpec(site="persist.read", kind="eio")])):
            assert cache.load_stripe(digest) is None
        got = cache.load_stripe(digest)  # the bytes were never damaged
        np.testing.assert_array_equal(got["val"], _stripe(7)["val"])

    def test_concurrent_writers_never_publish_torn_bytes(self, tmp_path):
        cache = SolverCache(tmp_path, "f" * 16)
        digest = "c" * 24
        errors = []

        def hammer(fill):
            try:
                for _ in range(30):
                    cache.save_stripe(digest, _stripe(fill))
                    got = cache.load_stripe(digest)
                    if got is None:
                        continue  # a miss is legal mid-race; torn data is not
                    v = int(got["src"][0])
                    assert v in (1, 2)
                    assert (got["src"] == v).all()
                    assert (got["val"] == float(v)).all()
            except Exception as err:  # pragma: no cover - failure path
                errors.append(err)

        threads = [threading.Thread(target=hammer, args=(f,)) for f in (1, 2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errors == []
        final = cache.load_stripe(digest)  # last writer wins, file is whole
        assert final is not None and int(final["src"][0]) in (1, 2)


def _toy_training(tmp_path, injector=None, total_steps=12, ckpt_every=4):
    """Tiny deterministic training loop: loss of step i is i(i+1)/2."""
    state = {"x": jnp.zeros(()), "step": jnp.zeros((), jnp.int32)}

    def step_fn(s, b):
        x = s["x"] + b
        return {"x": x, "step": s["step"] + 1}, {"loss": x}

    cfg = RunnerConfig(
        total_steps=total_steps, ckpt_every=ckpt_every, ckpt_dir=str(tmp_path)
    )
    return run_training(
        state, step_fn, lambda s: jnp.asarray(float(s)), cfg, injector=injector
    )


class TestRunnerReplayAccounting:
    def test_replay_counts_each_step_once(self, tmp_path):
        _, clean = _toy_training(tmp_path / "clean")
        state, hist = _toy_training(
            tmp_path / "faulted", injector=FailureInjector(fail_at=[6, 10])
        )
        assert hist["restarts"] == 2
        assert int(state["step"]) == 12
        # the fix under test: replayed steps overwrite, they don't append
        assert len(hist["loss"]) == 12
        assert hist["loss"] == clean["loss"]

    def test_cold_restart_replay_accounting(self, tmp_path):
        _, clean = _toy_training(tmp_path / "clean", total_steps=6, ckpt_every=100)
        _, hist = _toy_training(
            tmp_path / "faulted",
            injector=FailureInjector(fail_at=[3]),
            total_steps=6,
            ckpt_every=100,  # nothing committed before the fault: cold restart
        )
        assert hist["restarts"] == 1
        assert len(hist["loss"]) == 6
        assert hist["loss"] == clean["loss"]

    def test_faultplan_injector_and_global_plan(self, tmp_path):
        plan = FaultPlan([FaultSpec(site="train.step", match={"step": 5})])
        _, hist = _toy_training(tmp_path / "direct", injector=plan, total_steps=8)
        assert hist["restarts"] == 1 and plan.fired == 1
        globally = FaultPlan([FaultSpec(site="train.step", match={"step": 5})])
        with inject(globally):
            _, hist2 = _toy_training(tmp_path / "ambient", total_steps=8)
        assert hist2["restarts"] == 1 and globally.fired == 1
        assert hist["loss"] == hist2["loss"]
