"""Engine behaviour: schedule semantics, fixed-point identity, paper invariants."""

import numpy as np
import pytest

from repro.algorithms import connected_components, jacobi_solve, pagerank, sssp
from repro.core.engine import make_schedule
from repro.core.semiring import INT_INF, PLUS_TIMES
from repro.graphs.generators import make_graph

GRAPH = make_graph("twitter", scale=10, efactor=8, kind="pagerank")
GRAPH_S = make_graph("kron", scale=9, efactor=8, kind="sssp")
GRAPH_U = make_graph("road", scale=10, kind="unit")


def bellman_ford_oracle(g, src=0):
    d = np.full(g.n, INT_INF, dtype=np.int64)
    d[src] = 0
    for _ in range(g.n):
        nd = d.copy()
        src_of = g.indices
        dst_of = np.repeat(np.arange(g.n), np.diff(g.indptr))
        relax = d[src_of] + g.values
        np.minimum.at(nd, dst_of, relax)
        if (nd == d).all():
            break
        d = nd
    return d


class TestModes:
    def test_sync_equals_jacobi_numpy(self):
        """S == 1 schedule must be exact Jacobi."""
        r = pagerank(GRAPH, P=4, delta="sync")
        n = GRAPH.n
        x = np.full(n, 1.0 / n, dtype=np.float64)
        tele = 0.15 / n
        for _ in range(r.rounds):
            new = np.full(n, tele)
            np.add.at(
                new,
                np.repeat(np.arange(n), np.diff(GRAPH.indptr)),
                x[GRAPH.indices] * GRAPH.values,
            )
            x = new
        assert np.abs(r.x - x).max() < 1e-5

    def test_async_p1_equals_sequential_gs(self):
        """P=1, finest chunk == sequential (chunked) Gauss-Seidel."""
        r = pagerank(GRAPH, P=1, delta="async", min_chunk=8)
        n = GRAPH.n
        x = np.full(n, 1.0 / n, dtype=np.float64)
        tele = 0.15 / n
        for _ in range(r.rounds):
            for c0 in range(0, n, 8):
                rows = np.arange(c0, min(c0 + 8, n))
                e = []
                for u in rows:  # chunk reads pre-chunk state: emulate exactly
                    lo, hi = GRAPH.indptr[u], GRAPH.indptr[u + 1]
                    e.append((x[GRAPH.indices[lo:hi]] * GRAPH.values[lo:hi]).sum())
                x[rows] = tele + np.asarray(e)
        assert np.abs(r.x - x).max() < 1e-5

    @pytest.mark.parametrize("delta", [32, 128, 512])
    def test_fixed_point_independent_of_delta(self, delta):
        """Every δ converges to the same PageRank vector (same fixed point)."""
        ref = pagerank(GRAPH, P=4, delta="sync")
        r = pagerank(GRAPH, P=4, delta=delta, min_chunk=16)
        assert np.abs(ref.x - r.x).max() < 5e-5

    def test_flush_accounting(self):
        sched = make_schedule(GRAPH, 4, 100, PLUS_TIMES, mode="delayed")
        r = pagerank(GRAPH, P=4, delta=100)
        assert r.flushes == r.rounds * sched.S
        assert r.flush_bytes == r.flushes * sched.P * sched.delta * 4

    def test_sync_single_flush_per_round(self):
        r = pagerank(GRAPH, P=4, delta="sync")
        assert r.flushes == r.rounds


class TestSSSP:
    @pytest.mark.parametrize("delta", ["sync", "async", 64])
    def test_distances_exact(self, delta):
        oracle = bellman_ford_oracle(GRAPH_S)
        r = sssp(GRAPH_S, P=4, delta=delta, min_chunk=16)
        assert (r.x.astype(np.int64) == oracle).all()

    def test_async_no_more_rounds_than_vertices(self):
        r = sssp(GRAPH_S, P=4, delta="async", min_chunk=16)
        assert r.converged and r.rounds <= GRAPH_S.n


class TestCC:
    def test_grid_single_component(self):
        r = connected_components(GRAPH_U, P=4, delta=64, min_chunk=16)
        assert len(np.unique(r.x)) == 1

    def test_two_components(self):
        from repro.graphs.formats import CSRGraph

        src = np.array([0, 1, 2, 3, 4, 5])
        dst = np.array([1, 0, 3, 2, 5, 4])
        g = CSRGraph.from_edges(6, src, dst, np.zeros(6, np.int32))
        r = connected_components(g, P=2, delta="async", min_chunk=2)
        assert len(np.unique(r.x)) == 3


class TestJacobiSolver:
    def test_solves_diagonally_dominant(self, rng):
        n = 256
        rows = np.repeat(np.arange(n), 4)
        cols = (rows + rng.integers(1, n, rows.shape[0])) % n
        vals = rng.normal(size=rows.shape[0]).astype(np.float32) * 0.1
        diag = np.full(n, 4.0, np.float32)
        b = rng.normal(size=n).astype(np.float32)
        r = jacobi_solve(
            n, rows, cols, vals, diag, b, P=4, delta=32, min_chunk=8, tol=1e-6
        )
        A = np.zeros((n, n), np.float64)
        np.add.at(A, (rows, cols), vals)  # duplicates accumulate
        np.fill_diagonal(A, diag)
        x_np = np.linalg.solve(A, b)
        assert np.abs(r.x - x_np).max() < 1e-3

    def test_gs_mode_converges_faster_or_equal(self, rng):
        n = 256
        rows = np.repeat(np.arange(n), 4)
        cols = (rows + rng.integers(1, n, rows.shape[0])) % n
        vals = rng.normal(size=rows.shape[0]).astype(np.float32) * 0.15
        diag = np.full(n, 4.0, np.float32)
        b = rng.normal(size=n).astype(np.float32)
        rs = jacobi_solve(n, rows, cols, vals, diag, b, P=4, delta="sync", tol=1e-6)
        ra = jacobi_solve(
            n, rows, cols, vals, diag, b, P=4, delta="async", min_chunk=8, tol=1e-6
        )
        # classic Stein–Rosenberg territory: GS ≤ Jacobi rounds for this class
        assert ra.rounds <= rs.rounds
