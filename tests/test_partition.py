"""repro.graphs.partition: Partition invariants, partitioners, access matrix.

The Partition is what the frontier-sharded engine trusts for correctness:
index maps must be bijections onto the local layout, halo sets must cover
every cut edge (a missed halo vertex would silently read a stale frontier
value), and the edge-cut counters must agree with an independent numpy
reference and with the Fig-5 access matrix.
"""

import numpy as np
import pytest

from repro.core.access_matrix import (
    access_matrix,
    locality_fraction,
    partition_report,
    remote_read_fraction,
)
from repro.graphs.formats import CSRGraph
from repro.graphs.generators import make_graph
from repro.graphs.partition import (
    PARTITION_METHODS,
    equal_blocks,
    greedy_degree_blocks,
    make_partition,
)


def _random_graph(n, m, seed):
    rng = np.random.default_rng(seed)
    return CSRGraph.from_edges(
        n, rng.integers(0, n, m), rng.integers(0, n, m), name=f"r{seed}"
    )


def _edge_endpoints(g):
    dst = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(g.indptr))
    return g.indices.astype(np.int64), dst


class TestPartitionInvariants:
    @pytest.mark.parametrize("seed,P", [(0, 1), (1, 3), (2, 4), (3, 7)])
    def test_index_maps_are_bijections(self, seed, P):
        g = _random_graph(100, 400, seed)
        part = make_partition(g, P)
        for p in range(P):
            gi = part.global_index(p)
            # every resident slot maps to a distinct global vertex…
            assert len(np.unique(gi)) == gi.size == part.local_sizes[p]
            # …and local_index inverts global_index exactly
            np.testing.assert_array_equal(part.local_index(p, gi), np.arange(gi.size))
        # non-resident vertices resolve to -1
        for p in range(P):
            resident = set(part.global_index(p).tolist())
            absent = np.array(
                [v for v in range(g.n) if v not in resident][:10], dtype=np.int64
            )
            if absent.size:
                assert (part.local_index(p, absent) == -1).all()

    @pytest.mark.parametrize("seed,P", [(0, 2), (5, 4), (9, 6)])
    def test_halo_covers_every_cut_edge(self, seed, P):
        g = _random_graph(80, 500, seed)
        part = make_partition(g, P)
        src, dst = _edge_endpoints(g)
        o_src, o_dst = part.owner[src], part.owner[dst]
        cut = o_src != o_dst
        for s, d in zip(src[cut], dst[cut]):
            reader, owner = part.owner[d], part.owner[s]
            assert s in part.halo_in[reader]
            assert s in part.halo_out[owner]
        # and nothing more: halo_in holds only remote read targets
        for p in range(P):
            assert not np.isin(
                part.halo_in[p], np.arange(part.bounds[p], part.bounds[p + 1])
            ).any()

    @pytest.mark.parametrize("method", sorted(PARTITION_METHODS))
    def test_edge_cut_matches_numpy_reference(self, method):
        g = _random_graph(120, 700, 7)
        part = make_partition(g, 5, method=method)
        src, dst = _edge_endpoints(g)
        owner_ref = np.searchsorted(part.bounds[1:], np.arange(g.n), side="right")
        np.testing.assert_array_equal(part.owner, owner_ref)
        assert part.edge_cut == int((owner_ref[src] != owner_ref[dst]).sum())
        assert 0.0 <= part.cut_fraction <= 1.0

    def test_owner_map_matches_bounds(self):
        g = _random_graph(50, 200, 3)
        part = make_partition(g, 4)
        for p in range(4):
            lo, hi = part.bounds[p], part.bounds[p + 1]
            assert (part.owner[lo:hi] == p).all()

    def test_access_matrix_offdiag_equals_edge_cut(self):
        g = make_graph("web", scale=9, efactor=8, kind="pagerank")
        part = make_partition(g, 8)
        mat = access_matrix(g, part)  # Partition accepted directly
        assert int(mat.sum() - np.trace(mat)) == part.edge_cut
        rep = partition_report(g, part)
        assert rep["edge_cut"] == part.edge_cut
        assert abs(rep["locality_fraction"] + rep["remote_read_fraction"] - 1.0) < 1e-6
        assert rep["replication_factor"] >= 1.0


class TestPartitioners:
    @pytest.mark.parametrize("method", sorted(PARTITION_METHODS))
    @pytest.mark.parametrize("P", [1, 3, 8])
    def test_bounds_valid(self, method, P):
        g = _random_graph(64, 300, 11)
        b = PARTITION_METHODS[method](g, P)
        assert b.shape == (P + 1,)
        assert b[0] == 0 and b[-1] == g.n
        assert (np.diff(b) >= 0).all()

    def test_equal_blocks_sizes(self):
        b = equal_blocks(100, 4)
        assert (np.diff(b) == 25).all()

    def test_greedy_degree_balances_skew(self):
        """One hub vertex must not drag every later cut off balance."""
        n, P = 400, 4
        rng = np.random.default_rng(0)
        # hub at vertex 10: huge in-degree; rest uniform
        src = np.concatenate([rng.integers(0, n, 2000), rng.integers(0, n, 2000)])
        dst = np.concatenate([np.full(2000, 10), rng.integers(0, n, 2000)])
        g = CSRGraph.from_edges(n, src, dst)
        cost = g.in_degree + 0.5 * g.out_degree
        spreads = {}
        for method in ("balanced", "greedy_degree"):
            b = PARTITION_METHODS[method](g, P)
            per_block = np.array(
                [cost[b[p] : b[p + 1]].sum() for p in range(P)], dtype=float
            )
            spreads[method] = per_block.max() / max(per_block.mean(), 1e-9)
        assert spreads["greedy_degree"] <= spreads["balanced"] * 1.05

    @pytest.mark.parametrize("name", ["web", "urand"])
    def test_refine_cut_at_most_greedy_degree(self, name):
        """FM-style boundary refinement only ever accepts strict cut
        improvements over its greedy_degree seed, so its edge cut can never
        exceed the seed's — on the clustered (web) and random (urand)
        generators alike."""
        g = make_graph(name, scale=9, efactor=8, kind="pagerank")
        for P in (4, 8):
            seed_cut = make_partition(g, P, method="greedy_degree").edge_cut
            refined = make_partition(g, P, method="refine")
            assert refined.edge_cut <= seed_cut
            assert (np.diff(refined.bounds) >= 0).all()

    def test_greedy_degree_rejects_bad_alpha(self):
        g = _random_graph(10, 20, 0)
        with pytest.raises(ValueError, match="alpha"):
            greedy_degree_blocks(g, 2, alpha=-1.0)

    def test_make_partition_rejects_unknown_method(self):
        g = _random_graph(10, 20, 0)
        with pytest.raises(ValueError, match="unknown partition method"):
            make_partition(g, 2, method="metis")


class TestClusteredVsDiffuse:
    def test_clustered_graph_cuts_less(self):
        """The paper's Fig-5 story as numbers: web (diagonal) cuts fewer
        edges and needs less halo than kron (diffuse) at the same P."""
        web = make_graph("web", scale=10, efactor=8, kind="pagerank")
        kron = make_graph("kron", scale=10, efactor=8, kind="pagerank")
        p_web = make_partition(web, 8)
        p_kron = make_partition(kron, 8)
        assert p_web.cut_fraction < p_kron.cut_fraction
        assert p_web.replication_factor < p_kron.replication_factor
        m_web = access_matrix(web, p_web)
        m_kron = access_matrix(kron, p_kron)
        assert locality_fraction(m_web) > locality_fraction(m_kron)
        assert remote_read_fraction(m_web) < remote_read_fraction(m_kron)


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    SETTINGS = dict(
        deadline=None,
        max_examples=20,
        suppress_health_check=[HealthCheck.too_slow],
    )

    @st.composite
    def random_graph(draw):
        n = draw(st.integers(min_value=4, max_value=100))
        m = draw(st.integers(min_value=1, max_value=5 * n))
        seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        rng = np.random.default_rng(seed)
        return CSRGraph.from_edges(
            n, rng.integers(0, n, m), rng.integers(0, n, m), name=f"h{seed}"
        )

    @given(
        random_graph(), st.integers(1, 6), st.sampled_from(sorted(PARTITION_METHODS))
    )
    @settings(**SETTINGS)
    def test_partition_invariants_property(g, P, method):
        part = make_partition(g, P, method=method)
        # bounds cover, owners consistent
        assert part.bounds[0] == 0 and part.bounds[-1] == g.n
        src, dst = _edge_endpoints(g)
        cut = part.owner[src] != part.owner[dst]
        assert part.edge_cut == int(cut.sum())
        # halo covers every cut edge, halo_out mirrors halo_in
        for p in range(P):
            gi = part.global_index(p)
            assert len(np.unique(gi)) == gi.size
            np.testing.assert_array_equal(part.local_index(p, gi), np.arange(gi.size))
        readers_needed = np.unique(src[cut])
        halo_union = (
            np.unique(np.concatenate([h for h in part.halo_in]))
            if part.halo_total
            else np.zeros(0, np.int64)
        )
        out_union = (
            np.unique(np.concatenate([h for h in part.halo_out]))
            if sum(h.size for h in part.halo_out)
            else np.zeros(0, np.int64)
        )
        np.testing.assert_array_equal(halo_union, readers_needed)
        np.testing.assert_array_equal(out_union, readers_needed)
