"""Validate the trip-count-aware analytic FLOP model (benchmarks §Roofline).

XLA's ``cost_analysis()`` counts loop bodies once, so the roofline uses an
analytic model of the compiled program.  Here we compile configurations with
NO loops (unrolled layers, single-tile attention) where ``cost_analysis`` is
trustworthy, and check the model agrees.
"""

import dataclasses
import sys

import jax
import jax.numpy as jnp
import pytest

sys.path.insert(0, ".")  # benchmarks is a top-level package in the repo
from benchmarks.model_costs import cell_cost
from repro.configs.shapes import ShapeSpec
from repro.dist.compat import cost_analysis
from repro.models.config import ModelConfig
from repro.train.optimizer import AdamW, constant
from repro.train.train_step import init_train_state, make_train_step

B, S = 2, 64

CFG = ModelConfig(
    name="val",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv=2,
    d_ff=256,
    vocab=512,
    scan_layers=False,  # no layer loop
    remat=True,
    q_chunk=S,  # single attention tile → map/scan trip count 1
    kv_chunk=S,
    attn_schedule="masked",
)


def test_xla_counts_loop_bodies_once():
    """The premise: scanned matmuls under-report by the trip count."""

    def f_scan(x, w):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=10)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f_scan).lower(x, w).compile()
    one_matmul = 2 * 128**3
    assert cost_analysis(c)["flops"] < 2 * one_matmul  # not 10×


def test_train_flops_model_matches_unrolled_compile():
    opt = AdamW(schedule=constant(1e-3))
    state = jax.eval_shape(
        lambda k: init_train_state(CFG, opt, k), jax.random.PRNGKey(0)
    )
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    step = make_train_step(CFG, opt)
    compiled = jax.jit(step).lower(state, batch).compile()
    hlo_flops = cost_analysis(compiled)["flops"]
    shape = ShapeSpec("val", "train", S, B)
    model = cell_cost(CFG, shape).flops
    ratio = model / hlo_flops
    # the analytic model should land within 2× of a loop-free compile
    assert 0.5 < ratio < 2.0, (model, hlo_flops)


def test_prefill_flops_model_matches():
    from repro.models import init_params, prefill

    params = jax.eval_shape(lambda k: init_params(CFG, k), jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    compiled = (
        jax.jit(lambda p, b: prefill(p, CFG, b)).lower(params, batch).compile()
    )
    hlo_flops = cost_analysis(compiled)["flops"]
    shape = ShapeSpec("val", "prefill", S, B)
    model = cell_cost(CFG, shape).flops
    ratio = model / hlo_flops
    assert 0.4 < ratio < 2.5, (model, hlo_flops)
