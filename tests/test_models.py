"""Model zoo: per-arch smoke (reduced config, one train step, no NaNs),
decode-vs-full-forward equivalence, SSD & attention oracles."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get_config, get_reduced
from repro.models import (
    decode_step,
    init_params,
    prefill,
    train_loss,
)
from repro.models.lm import init_cache, pad_cache

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=32, with_labels=True):
    batch = {}
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(
            KEY, (B, S, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    else:
        batch["tokens"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    if with_labels:
        batch["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", all_arch_ids())
class TestArchSmoke:
    def test_train_step_shapes_and_finite(self, arch):
        cfg = get_reduced(arch)
        params = init_params(cfg, KEY)
        batch = make_batch(cfg)
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: train_loss(p, cfg, batch), has_aux=True
        )(params)
        assert jnp.isfinite(loss)
        assert np.isfinite(
            sum(
                float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads)
            )
        )
        # loss starts near ln(vocab) for random init
        assert abs(float(loss) - np.log(cfg.vocab)) < 2.0

    def test_prefill_decode_shapes(self, arch):
        cfg = get_reduced(arch)
        params = init_params(cfg, KEY)
        B, S = 2, 16
        logits, cache = prefill(params, cfg, make_batch(cfg, B, S, with_labels=False))
        assert logits.shape == (B, cfg.vocab)
        assert int(cache["cur_len"][0]) == S
        tok = (
            jax.random.normal(KEY, (B, 1, cfg.d_model), jnp.dtype(cfg.dtype))
            if cfg.family == "vlm"
            else jnp.zeros((B, 1), jnp.int32)
        )
        zc = init_cache(cfg, B, S + 4, jnp.dtype(cfg.dtype))
        if cfg.family == "encdec":
            zc["enc"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        dl, zc2 = decode_step(params, cfg, zc, tok)
        assert dl.shape == (B, cfg.vocab)
        assert jnp.isfinite(dl).all()
        assert int(zc2["cur_len"][0]) == 1

    def test_full_config_instantiates(self, arch):
        cfg = get_config(arch)
        assert cfg.param_count() > 1e8  # full configs are real-model sized
        assert cfg.active_param_count() <= cfg.param_count()


@pytest.mark.parametrize(
    "arch", [a for a in all_arch_ids() if a not in ("phi3p5_moe_42b", "qwen3_moe_30b")]
)
def test_decode_matches_full_forward(arch):
    """prefill(S) + decode(token S) == full forward logits at position S."""
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    params = init_params(cfg, KEY)
    B, S = 2, 24
    key = jax.random.PRNGKey(3)
    if cfg.family == "vlm":
        full = jax.random.normal(key, (B, S + 1, cfg.d_model), jnp.float32)
        b_full, b_pre = {"embeds": full}, {"embeds": full[:, :S]}
        tok = full[:, S : S + 1]
    else:
        full = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
        b_full, b_pre = {"tokens": full}, {"tokens": full[:, :S]}
        tok = full[:, S : S + 1]
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
        b_full["frames"] = b_pre["frames"] = frames
    lg_full, _ = prefill(params, cfg, b_full)
    _, cache = prefill(params, cfg, b_pre)
    cache = pad_cache(cfg, cache, S + 8)
    lg_dec, _ = decode_step(params, cfg, cache, tok)
    rel = float(jnp.abs(lg_full - lg_dec).max()) / max(
        float(jnp.abs(lg_full).max()), 1e-6
    )
    assert rel < 1e-4


def test_moe_decode_matches_at_high_capacity():
    """MoE equivalence holds when nothing is capacity-dropped."""
    cfg = dataclasses.replace(
        get_reduced("qwen3_moe_30b"), dtype="float32", capacity_factor=16.0
    )
    params = init_params(cfg, KEY)
    B, S = 2, 24
    full = jax.random.randint(jax.random.PRNGKey(3), (B, S + 1), 0, cfg.vocab)
    lg_full, _ = prefill(params, cfg, {"tokens": full})
    _, cache = prefill(params, cfg, {"tokens": full[:, :S]})
    cache = pad_cache(cfg, cache, S + 8)
    lg_dec, _ = decode_step(params, cfg, cache, full[:, S : S + 1])
    rel = float(jnp.abs(lg_full - lg_dec).max()) / float(jnp.abs(lg_full).max())
    assert rel < 1e-4


class TestPrimitives:
    def test_ssd_chunked_matches_recurrence(self):
        from repro.models.mamba2 import ssd_forward, ssd_reference

        ks = jax.random.split(KEY, 5)
        B, S, H, P, N = 2, 48, 3, 8, 8
        xs = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
        Bm = jax.random.normal(ks[3], (B, S, N))
        Cm = jax.random.normal(ks[4], (B, S, N))
        y, s = ssd_forward(xs, dt, A, Bm, Cm, chunk=16)
        y_r, s_r = ssd_reference(xs, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_r), atol=2e-4)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_r), atol=2e-4)

    @pytest.mark.parametrize("schedule", ["masked", "banded"])
    @pytest.mark.parametrize("window", [0, 16])
    def test_flash_attention_matches_naive(self, schedule, window):
        from repro.models.layers import flash_attention

        ks = jax.random.split(KEY, 3)
        B, S, Hq, Hkv, D = 2, 64, 4, 2, 8
        q = jax.random.normal(ks[0], (B, S, Hq, D))
        k = jax.random.normal(ks[1], (B, S, Hkv, D))
        v = jax.random.normal(ks[2], (B, S, Hkv, D))
        o = flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16,
                            window=window, schedule=schedule)
        # naive
        G = Hq // Hkv
        qr = q.reshape(B, S, Hkv, G, D)
        lg = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k) / np.sqrt(D)
        pos = jnp.arange(S)
        m = pos[None, :] <= pos[:, None]
        if window:
            m &= pos[None, :] > pos[:, None] - window
        lg = jnp.where(m, lg, -1e30)
        o_n = jnp.einsum("bhgqk,bkhd->bqhgd", jax.nn.softmax(lg, -1), v).reshape(
            B, S, Hq, D
        )
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_n), atol=2e-5)

    def test_mrope_sections(self):
        from repro.models.layers import rope_angles

        B, S, hd = 2, 8, 16
        pos3 = jnp.stack(
            [jnp.arange(S) * (i + 1) for i in range(3)], axis=0
        )[None].repeat(B, 0)
        ang = rope_angles(pos3, hd, 1e4, mrope_sections=(4, 2, 2))
        assert ang.shape == (B, S, hd // 2)
        # first section driven by stream 0, last by stream 2
        assert not jnp.allclose(ang[:, :, 0], ang[:, :, -1])