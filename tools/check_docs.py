"""Docs gate: markdown link integrity + public-docstring coverage ratchet.

Two independent checks, both stdlib-only so the gate computes identical
results in CI and in a bare dev container:

1. **Relative-link check** — every ``[text](target)`` in every tracked
   ``*.md`` whose target is not an external URL or a pure anchor must
   resolve to a file or directory relative to the markdown file (anchors
   on relative targets are stripped before the existence check).  Fenced
   code blocks are skipped so example snippets can't false-positive.

2. **Docstring-coverage ratchet** — counts *missing public docstrings*
   (module docstring + every public top-level / class-level ``def`` and
   ``class``, the pydocstyle D1xx surface) per module under the ratcheted
   paths (``src/repro/core``, ``src/repro/solve``) via ``ast``.  The
   committed ``docs/docstring_baseline.json`` pins the allowed count per
   file; any file whose count *rises* fails the gate, and files absent
   from the baseline (new modules) are allowed zero.  After intentionally
   documenting more, run with ``--write-baseline`` to tighten the ratchet.

    python tools/check_docs.py [--write-baseline]
"""

from __future__ import annotations

import argparse
import ast
import json
import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
BASELINE = REPO / "docs" / "docstring_baseline.json"

# Surfaces whose public-docstring coverage may only go up.
RATCHET_PATHS = ("src/repro/core", "src/repro/solve")

# [text](target) — target captured lazily so `)` in prose doesn't leak in.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = re.compile(r"^(?:[a-z][a-z0-9+.-]*:|//)", re.IGNORECASE)


def _tracked_markdown() -> list[Path]:
    out = subprocess.run(
        ["git", "ls-files", "*.md", "**/*.md"],
        cwd=REPO,
        capture_output=True,
        text=True,
        check=True,
    )
    return sorted({REPO / line for line in out.stdout.splitlines() if line})


def check_links() -> list[str]:
    """Return one error string per broken relative link in tracked *.md."""
    errors = []
    for md in _tracked_markdown():
        in_fence = False
        for lineno, line in enumerate(md.read_text().splitlines(), start=1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for target in _LINK.findall(line):
                if _EXTERNAL.match(target) or target.startswith("#"):
                    continue
                path = target.split("#", 1)[0]
                if not path:
                    continue
                if not (md.parent / path).exists():
                    rel = md.relative_to(REPO)
                    errors.append(f"{rel}:{lineno}: broken link -> {target}")
    return errors


def _missing_in_module(source: str) -> int:
    """Missing public docstrings in one module (the pydocstyle D1 surface).

    Counts the module docstring plus every public (no leading underscore)
    ``def``/``class`` at module level or directly inside a class body —
    nested functions are implementation detail and exempt, as are private
    and dunder names.
    """
    tree = ast.parse(source)
    missing = 0 if ast.get_docstring(tree) else 1

    def public_defs(body):
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                if not node.name.startswith("_"):
                    yield node

    for node in public_defs(tree.body):
        if not ast.get_docstring(node):
            missing += 1
        if isinstance(node, ast.ClassDef):
            for meth in public_defs(node.body):
                if not ast.get_docstring(meth):
                    missing += 1
    return missing


def docstring_counts() -> dict[str, int]:
    """Missing-public-docstring count per file under the ratcheted paths."""
    counts = {}
    for root in RATCHET_PATHS:
        for py in sorted((REPO / root).rglob("*.py")):
            n = _missing_in_module(py.read_text())
            if n:
                counts[str(py.relative_to(REPO))] = n
    return counts


def check_ratchet(counts: dict[str, int]) -> list[str]:
    """Return one error string per file whose missing count rose."""
    if not BASELINE.exists():
        return [f"missing baseline {BASELINE.relative_to(REPO)} (--write-baseline)"]
    baseline = json.loads(BASELINE.read_text())
    errors = []
    for path, count in counts.items():
        allowed = baseline.get(path, 0)
        if count > allowed:
            errors.append(
                f"{path}: {count} missing public docstrings "
                f"(baseline allows {allowed}) — document, don't regress"
            )
    return errors


def main(argv=None) -> int:
    """CLI entry point; returns the process exit code."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="rewrite docs/docstring_baseline.json from the current tree",
    )
    args = ap.parse_args(argv)

    link_errors = check_links()
    for err in link_errors:
        print(f"FAIL {err}")
    print(
        f"link check: {len(_tracked_markdown())} markdown files, "
        f"{len(link_errors)} broken links"
    )

    counts = docstring_counts()
    if args.write_baseline:
        BASELINE.parent.mkdir(parents=True, exist_ok=True)
        BASELINE.write_text(json.dumps(counts, indent=1, sort_keys=True) + "\n")
        print(f"wrote {BASELINE.relative_to(REPO)} ({sum(counts.values())} allowed)")
        return 1 if link_errors else 0

    ratchet_errors = check_ratchet(counts)
    for err in ratchet_errors:
        print(f"FAIL {err}")
    print(
        f"docstring ratchet: {sum(counts.values())} missing across "
        f"{len(counts)} files (per-file caps from baseline)"
    )
    return 1 if (link_errors or ratchet_errors) else 0


if __name__ == "__main__":
    sys.exit(main())
