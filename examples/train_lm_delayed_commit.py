"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
delayed gradient commit (the paper's δ-buffering at training scale) and
fault-tolerant checkpointing, on CPU.

    PYTHONPATH=src python examples/train_lm_delayed_commit.py [--steps 300]

Compares the loss trajectory of synchronous DP (δ=1) against delayed commit
(δ=8) — the LM analogue of the paper's sync↔async spectrum: δ=8 runs one
cross-pod commit per 8 steps (8× fewer DCN collectives) at the cost of
δ-bounded parameter staleness between pods.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.data.pipeline import SyntheticLM
from repro.dist.delayed_commit import (
    DelayedCommitConfig,
    init_delayed_state,
    make_delayed_commit_step,
)
from repro.models.config import ModelConfig
from repro.train.optimizer import AdamW, linear_warmup_cosine

# ~100M params: 12L × 512 × MHA-8 × ff 2048, 32k vocab
CFG = ModelConfig(
    name="lm-100m",
    family="dense",
    n_layers=12,
    d_model=512,
    n_heads=8,
    n_kv=8,
    d_ff=2048,
    vocab=32_000,
    q_chunk=64,
    kv_chunk=64,
    remat=False,
)


def run(delta: int, steps: int, seq: int, batch: int, n_pods: int = 2):
    opt = AdamW(schedule=linear_warmup_cosine(3e-4, warmup=20, total=steps))
    cc = DelayedCommitConfig(n_pods=n_pods, delta=delta)
    state = init_delayed_state(CFG, opt, cc, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_delayed_commit_step(CFG, opt, cc))
    data = SyntheticLM(vocab=CFG.vocab, seq_len=seq, global_batch=batch)
    losses = []
    t0 = time.time()
    for s in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        b = jax.tree.map(
            lambda x: x.reshape((n_pods, x.shape[0] // n_pods) + x.shape[1:]), b
        )
        state, m = step_fn(state, b)
        losses.append(float(m["total_loss"]))
        if s % 25 == 0:
            print(f"  δ={delta}: step {s:4d} loss {losses[-1]:.4f}", flush=True)
    dt = time.time() - t0
    commits = steps // delta
    print(f"  δ={delta}: final loss {losses[-1]:.4f}, {commits} commits, "
          f"{dt:.0f}s")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    n_params = CFG.param_count()
    print(f"model: {CFG.name} ({n_params/1e6:.0f}M params)\n")
    l1 = run(1, args.steps, args.seq, args.batch)
    l8 = run(8, args.steps, args.seq, args.batch)
    print(f"\nsync DP (δ=1)  : loss {l1[0]:.3f} → {l1[-1]:.3f}")
    print(f"delayed  (δ=8) : loss {l8[0]:.3f} → {l8[-1]:.3f} "
          f"with 8× fewer cross-pod collectives")


if __name__ == "__main__":
    main()
