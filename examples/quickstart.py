"""Quickstart: one Solver, the paper's three execution disciplines + auto-δ.

Runs PageRank on a synthetic scale-free graph under synchronous (Jacobi),
asynchronous (finest-δ block Gauss–Seidel), and delayed-asynchronous
(hybrid δ) schedules — all through one `Solver`, which caches the stripe
schedule and the compiled loop per δ — then lets `delta="auto"` pick δ* from
the analytic cost model, and shows the warm-cache replay cost.

    PYTHONPATH=src python examples/quickstart.py [--scale 13]
"""

import argparse

import numpy as np

from repro.graphs.generators import make_graph
from repro.solve import Solver, pagerank_problem


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--workers", type=int, default=16)
    args = ap.parse_args(argv)

    g = make_graph("twitter", scale=args.scale, efactor=8, kind="pagerank")
    print(f"graph: {g.stats()}\n")
    solver = Solver(
        g, pagerank_problem(), n_workers=args.workers, backend="host", min_chunk=16
    )

    print(
        f"{'schedule':14s} {'δ':>6s} {'rounds':>7s} {'flushes':>8s} "
        f"{'flush MiB':>10s} {'total s':>9s}"
    )
    results = {}
    for label, delta in [
        ("sync", "sync"),
        ("delayed", 1024),
        ("delayed", 256),
        ("async", "async"),
    ]:
        r = solver.solve(delta=delta)
        results[f"{label}{delta}"] = r
        print(
            f"{label:14s} {r.delta:6d} {r.rounds:7d} {r.flushes:8d} "
            f"{r.flush_bytes / 2**20:10.2f} {r.total_time_s:9.4f}"
        )

    # δ="auto" probes sync/async round counts (reusing the cached schedules
    # above) and asks the TPU cost model for δ*.
    r_auto = solver.solve(delta="auto")
    print(
        f"{'auto':14s} {r_auto.delta:6d} {r_auto.rounds:7d} {r_auto.flushes:8d} "
        f"{r_auto.flush_bytes / 2**20:10.2f} {r_auto.total_time_s:9.4f}"
    )

    # all schedules converge to the same fixed point
    xs = [r.x for r in results.values()]
    drift = max(np.abs(a - xs[0]).max() for a in xs[1:])
    print(f"\nmax fixed-point drift across schedules: {drift:.2e}")

    # warm cache: a second query on the same (graph, problem, δ) rebuilds and
    # retraces nothing — this is what serving-scale batching rides on.
    before = dict(solver.stats)
    r2 = solver.solve(delta=256)
    assert solver.stats["schedule_builds"] == before["schedule_builds"]
    assert solver.stats["traces"] == before["traces"]
    print(
        f"warm replay at δ=256: {r2.total_time_s:.4f} s "
        f"(schedule builds {solver.stats['schedule_builds']}, "
        f"compiles {solver.stats['compiles']} — unchanged)"
    )
    print(
        "async converges in fewer rounds; delayed-δ keeps most of that while "
        "cutting flushes by the buffer factor — the paper's hybrid."
    )


if __name__ == "__main__":
    main()
