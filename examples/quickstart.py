"""Quickstart: the paper's three execution disciplines on one graph.

Runs PageRank on a synthetic scale-free graph under synchronous (Jacobi),
asynchronous (finest-δ block Gauss–Seidel), and delayed-asynchronous
(hybrid δ) schedules, and prints the paper's core trade-off: rounds to
convergence vs commit (flush) traffic.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.algorithms import pagerank
from repro.graphs.generators import make_graph


def main():
    g = make_graph("twitter", scale=13, efactor=8, kind="pagerank")
    print(f"graph: {g.stats()}\n")
    print(f"{'mode':12s} {'δ':>6s} {'rounds':>7s} {'flushes':>8s} "
          f"{'flush MiB':>10s} {'total s':>9s}")
    results = {}
    for mode, delta in [("sync", None), ("delayed", 1024), ("delayed", 256),
                        ("async", None)]:
        r = pagerank(g, P=16, mode=mode, delta=delta, min_chunk=16)
        label = mode if delta is None else f"{mode}"
        key = f"{mode}{delta or ''}"
        results[key] = r
        total = r.rounds * r.avg_round_time_s
        print(f"{label:12s} {r.delta:6d} {r.rounds:7d} {r.flushes:8d} "
              f"{r.flush_bytes/2**20:10.2f} {total:9.4f}")
    # all modes converge to the same fixed point
    xs = [r.x for r in results.values()]
    drift = max(np.abs(a - xs[0]).max() for a in xs[1:])
    print(f"\nmax fixed-point drift across schedules: {drift:.2e}")
    print("async converges in fewer rounds; delayed-δ keeps most of that "
          "while cutting flushes by the buffer factor — the paper's hybrid.")


if __name__ == "__main__":
    main()
