"""Quickstart: one Solver, the paper's three execution disciplines + auto-δ.

Runs PageRank on a synthetic scale-free graph under synchronous (Jacobi),
asynchronous (finest-δ block Gauss–Seidel), and delayed-asynchronous
(hybrid δ) schedules — all through one `Solver`, which caches the stripe
schedule and the compiled loop per δ — then lets `delta="auto"` pick δ* from
the analytic cost model, and shows the warm-cache replay cost.  A second
act runs an (n, F) *matrix* frontier — F-class label propagation — through
the identical engine: same schedules, same commit discipline, features just
ride along on the trailing axis.

    PYTHONPATH=src python examples/quickstart.py [--scale 13]
"""

import argparse

import numpy as np

from repro.graphs.generators import make_graph
from repro.solve import (
    Solver,
    default_landmarks,
    label_propagation_problem,
    pagerank_problem,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--workers", type=int, default=16)
    args = ap.parse_args(argv)

    g = make_graph("twitter", scale=args.scale, efactor=8, kind="pagerank")
    print(f"graph: {g.stats()}\n")
    solver = Solver(
        g, pagerank_problem(), n_workers=args.workers, backend="host", min_chunk=16
    )

    print(
        f"{'schedule':14s} {'δ':>6s} {'rounds':>7s} {'flushes':>8s} "
        f"{'flush MiB':>10s} {'total s':>9s}"
    )
    results = {}
    for label, delta in [
        ("sync", "sync"),
        ("delayed", 1024),
        ("delayed", 256),
        ("async", "async"),
    ]:
        r = solver.solve(delta=delta)
        results[f"{label}{delta}"] = r
        print(
            f"{label:14s} {r.delta:6d} {r.rounds:7d} {r.flushes:8d} "
            f"{r.flush_bytes / 2**20:10.2f} {r.total_time_s:9.4f}"
        )

    # δ="auto" probes sync/async round counts (reusing the cached schedules
    # above) and asks the TPU cost model for δ*.
    r_auto = solver.solve(delta="auto")
    print(
        f"{'auto':14s} {r_auto.delta:6d} {r_auto.rounds:7d} {r_auto.flushes:8d} "
        f"{r_auto.flush_bytes / 2**20:10.2f} {r_auto.total_time_s:9.4f}"
    )

    # all schedules converge to the same fixed point
    xs = [r.x for r in results.values()]
    drift = max(np.abs(a - xs[0]).max() for a in xs[1:])
    print(f"\nmax fixed-point drift across schedules: {drift:.2e}")

    # warm cache: a second query on the same (graph, problem, δ) rebuilds and
    # retraces nothing — this is what serving-scale batching rides on.
    before = dict(solver.stats)
    r2 = solver.solve(delta=256)
    assert solver.stats["schedule_builds"] == before["schedule_builds"]
    assert solver.stats["traces"] == before["traces"]
    print(
        f"warm replay at δ=256: {r2.total_time_s:.4f} s "
        f"(schedule builds {solver.stats['schedule_builds']}, "
        f"compiles {solver.stats['compiles']} — unchanged)"
    )
    print(
        "async converges in fewer rounds; delayed-δ keeps most of that while "
        "cutting flushes by the buffer factor — the paper's hybrid."
    )

    # --- matrix frontier: F classes propagate in ONE solve -----------------
    # A clustered web graph, 4 anchor vertices pinned to one-hot labels each;
    # the frontier is (n, 4) and every engine stage — gather, ⊗, segment-⊕,
    # row update, commit flush — broadcasts over the trailing feature axis.
    F = 4
    gw = make_graph("web", scale=args.scale, efactor=8, kind="pagerank")
    lp = Solver(
        gw,
        label_propagation_problem(feature_dim=F),
        n_workers=args.workers,
        backend="host",
        min_chunk=16,
    )
    r_lp = lp.solve(delta=256)
    labels = np.asarray(r_lp.x)  # (n, F) soft label distributions
    hard = labels.argmax(axis=1)
    anchors = default_landmarks(gw.n, F)
    assert r_lp.converged
    assert np.array_equal(hard[anchors], np.arange(F)), "anchors must keep labels"
    share = np.bincount(hard, minlength=F) / gw.n
    print(
        f"\nlabelprop (n, {F}) matrix frontier at δ=256: "
        f"{r_lp.rounds} rounds, converged={r_lp.converged}"
    )
    shares = "  ".join(f"{k}:{share[k]:.2f}" for k in range(F))
    print(
        f"class shares: {shares} — one matrix solve instead of "
        f"{F} vector solves, same engine, same δ-schedule."
    )


if __name__ == "__main__":
    main()
