"""Serving example: batched prefill + greedy decode on the hybrid
(RG-LRU + local attention) architecture — constant-memory long context.

    PYTHONPATH=src python examples/serve_hybrid.py
"""

import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.launch.serve import generate
from repro.models import init_params


def main():
    cfg = get_reduced("recurrentgemma_9b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, prompt_len, gen = 4, 48, 24
    prompts = rng.integers(0, cfg.vocab, (B, prompt_len)).astype(np.int32)
    t0 = time.time()
    toks = generate(cfg, params, prompts, gen)
    dt = time.time() - t0
    print(f"arch: {cfg.name} (pattern {cfg.pattern}, window {cfg.window})")
    print(f"generated {toks.shape} greedy tokens in {dt:.1f}s")
    print("decode state: RG-LRU (B, W) + rolling window KV — context cost is "
          "O(window), which is why long_500k runs for this family")
    for i in range(B):
        print(f"  seq {i}:", np.asarray(toks[i]))


if __name__ == "__main__":
    main()
