"""Bellman-Ford SSSP δ sweep + the analytic δ-selector (beyond paper).

Sweeps the delay parameter on two topologies with opposite behaviour
(paper Fig 6): a scale-free graph that tolerates delay, and a huge-diameter
road grid where delaying updates slows information transfer.  One `Solver`
per graph serves the whole sweep from its schedule cache; `delta="auto"`
fits the δ-model from two probes and picks δ*.  Ends with multi-source SSSP
answered as a single batched lowering.

    PYTHONPATH=src python examples/sssp_delta_sweep.py [--scale 12]
"""

import argparse

import numpy as np

from repro.graphs.generators import make_graph
from repro.solve import Solver, multi_source_x0, sssp_problem


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--workers", type=int, default=16)
    args = ap.parse_args(argv)

    for name in ("twitter", "road"):
        g = make_graph(name, scale=args.scale, efactor=8, kind="sssp")
        solver = Solver(
            g, sssp_problem(), n_workers=args.workers, backend="host", min_chunk=16
        )
        sync = solver.solve(delta="sync")
        asyn = solver.solve(delta="async")
        print(f"\n{name}: sync={sync.rounds} rounds, async={asyn.rounds} rounds")
        print(f"{'δ':>6s} {'rounds':>7s} {'flushes/round':>14s}")
        for d in (64, 256, 1024, 4096):
            r = solver.solve(delta=d)
            print(f"{d:6d} {r.rounds:7d} {r.flushes / r.rounds:14.1f}")

        # the probes reuse the sync/async schedules already in the cache
        delta_star = solver.resolve_delta("auto")
        model = solver.delta_model
        print(
            f"δ-model: locality={model.locality:.2f} → δ* = {delta_star}"
            f"  (modeled TPU time {model.total_time_s(delta_star) * 1e3:.2f} ms"
            f" vs async {model.total_time_s(model.delta_min) * 1e3:.2f} ms)"
        )

        # multi-source SSSP: Q sources, one schedule, one compiled loop
        sources = np.arange(4) * (g.n // 4)
        batch = solver.solve_batch(multi_source_x0(g, sources), delta=delta_star)
        print(
            f"batched {batch.Q}-source SSSP @ δ*: {batch.rounds} rounds, "
            f"per-query convergence {batch.rounds_per_query.tolist()}"
        )


if __name__ == "__main__":
    main()
