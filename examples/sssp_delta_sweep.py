"""Bellman-Ford SSSP δ sweep + the analytic δ-selector (beyond paper).

Sweeps the delay parameter on two topologies with opposite behaviour
(paper Fig 6): a scale-free graph that tolerates delay, and a huge-diameter
road grid where delaying updates slows information transfer.  Then asks the
δ-model (fit from two probes) to pick δ* and compares.

    PYTHONPATH=src python examples/sssp_delta_sweep.py
"""

from repro.algorithms import sssp
from repro.core.delta_model import fit_delta_model
from repro.graphs.generators import make_graph


def main():
    for name in ("twitter", "road"):
        g = make_graph(name, scale=12, efactor=8, kind="sssp")
        sync = sssp(g, P=16, mode="sync")
        asyn = sssp(g, P=16, mode="async", min_chunk=16)
        print(f"\n{name}: sync={sync.rounds} rounds, async={asyn.rounds} rounds")
        print(f"{'δ':>6s} {'rounds':>7s} {'flushes/round':>14s}")
        for d in (64, 256, 1024, 4096):
            r = sssp(g, P=16, mode="delayed", delta=d, min_chunk=16)
            print(f"{d:6d} {r.rounds:7d} {r.flushes / r.rounds:14.1f}")
        model = fit_delta_model(g, 16, sync.rounds, asyn.rounds, delta_min=16)
        print(f"δ-model: locality={model.locality:.2f} → δ* = {model.best_delta()}"
              f"  (modeled TPU time {model.total_time_s(model.best_delta())*1e3:.2f} ms"
              f" vs async {model.total_time_s(model.delta_min)*1e3:.2f} ms)")


if __name__ == "__main__":
    main()
