"""Bench-regression guard: fresh ``results/*.json`` vs committed baselines.

The smoke-bench CI job snapshots the committed ``results/`` tree before
running the benchmarks, then calls this script to diff every regenerated
file against its baseline — so a drifting counter **fails the job** instead
of silently riding along in the uploaded artifacts.

What is compared: every numeric leaf reachable through matching JSON
structure (dicts by key, lists by index).  Wall-clock fields are skipped —
they measure the runner, not the code — identified by name
(``*time*``/``*latency*``/``*second*``/``*duration*`` or a ``_s``/``_ms``/
``_us`` suffix).  Deterministic fields (round counts, flush/wire bytes,
cache-counter stats) must agree within ``--rtol``; a missing key, missing
baseline-relative file, or structural mismatch is always a failure.  Files
present only in the fresh tree are reported as new and pass (first run of a
new benchmark: commit its output to create the baseline).

Usage (what CI runs)::

    cp -r results results-baseline       # before the benchmarks
    ...run benchmarks...
    python -m benchmarks.check_regression results-baseline results
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

TIME_KEY = re.compile(r"time|latency|second|duration", re.IGNORECASE)
TIME_SUFFIX = ("_s", "_ms", "_us")


def is_time_key(key: str) -> bool:
    return bool(TIME_KEY.search(key)) or key.endswith(TIME_SUFFIX)


def compare(base, fresh, rtol: float, atol: float, path: str, problems: list):
    """Recursively diff ``fresh`` against ``base``; append findings."""
    if isinstance(base, dict):
        if not isinstance(fresh, dict):
            problems.append(f"{path}: dict became {type(fresh).__name__}")
            return
        for key, bval in base.items():
            if is_time_key(str(key)):
                continue
            if key not in fresh:
                problems.append(f"{path}.{key}: missing from fresh results")
                continue
            compare(bval, fresh[key], rtol, atol, f"{path}.{key}", problems)
        return
    if isinstance(base, list):
        if not isinstance(fresh, list):
            problems.append(f"{path}: list became {type(fresh).__name__}")
            return
        if len(fresh) < len(base):
            problems.append(f"{path}: {len(base)} baseline rows, {len(fresh)} fresh")
        for i, bval in enumerate(base[: len(fresh)]):
            compare(bval, fresh[i], rtol, atol, f"{path}[{i}]", problems)
        return
    if isinstance(base, bool) or isinstance(fresh, bool):
        if base != fresh:
            problems.append(f"{path}: baseline={base} fresh={fresh}")
        return
    if isinstance(base, (int, float)) and isinstance(fresh, (int, float)):
        if abs(fresh - base) > atol + rtol * abs(base):
            rel = (fresh - base) / base * 100 if base else float("inf")
            problems.append(
                f"{path}: baseline={base} fresh={fresh} ({rel:+.1f}% > ±{rtol:.0%})"
            )
        return
    if base != fresh:
        problems.append(f"{path}: baseline={base!r} fresh={fresh!r}")


def check(baseline_dir: Path, fresh_dir: Path, rtol: float, atol: float) -> int:
    problems: list[str] = []
    compared = 0
    for base_file in sorted(baseline_dir.rglob("*.json")):
        rel = base_file.relative_to(baseline_dir)
        fresh_file = fresh_dir / rel
        if not fresh_file.exists():
            problems.append(f"{rel}: baseline exists but fresh run produced no file")
            continue
        try:
            base = json.loads(base_file.read_text())
        except ValueError:
            print(f"  skip {rel}: unreadable baseline (regenerate and commit)")
            continue
        try:
            fresh = json.loads(fresh_file.read_text())
        except ValueError:
            problems.append(f"{rel}: fresh file is not valid JSON")
            continue
        compared += 1
        compare(base, fresh, rtol, atol, str(rel), problems)
    new = {
        str(p.relative_to(fresh_dir))
        for p in fresh_dir.rglob("*.json")
        if not (baseline_dir / p.relative_to(fresh_dir)).exists()
    }
    for name in sorted(new):
        print(f"  new (no baseline, passes): {name}")
    print(f"compared {compared} result files against {baseline_dir}")
    if problems:
        print(f"\n{len(problems)} regression(s) beyond rtol={rtol}:")
        for p in problems:
            print(f"  FAIL {p}")
        return 1
    print("no regressions")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", type=Path, help="snapshot of committed results/")
    ap.add_argument("fresh", type=Path, help="results/ after the benchmark run")
    ap.add_argument(
        "--rtol",
        type=float,
        default=0.2,
        help="relative tolerance for numeric drift (default 0.2)",
    )
    ap.add_argument(
        "--atol",
        type=float,
        default=1e-9,
        help="absolute tolerance floor (default 1e-9)",
    )
    args = ap.parse_args(argv)
    if not args.baseline.is_dir():
        print(f"baseline dir {args.baseline} missing", file=sys.stderr)
        return 2
    return check(args.baseline, args.fresh, args.rtol, args.atol)


if __name__ == "__main__":
    sys.exit(main())
