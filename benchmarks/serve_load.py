"""Serving-tier load sweep: continuous batching vs the fixed-batch barrier.

Replays the same open-loop Poisson traces (``repro.launch.service.loadgen``)
through both disciplines at a sweep of offered loads:

* **continuous** — the serving tier: arrivals slot into in-flight batches as
  converged queries retire, per-class quanta, bounded admission queue;
* **fixed** — the pre-serving-tier counterfactual: arrivals wait for the
  device, are padded to a full fixed batch, and the whole batch runs to
  collective convergence before anyone is answered.

A load is *sustained* when nothing was shed (zero rejections, everything
completed and converged) and p99 latency stays under ``--p99-threshold``
round-clock units.  The summary reports the highest sustained load per
discipline; the serving tier's win condition — strictly higher sustained
load at the same p99 bar — is a committed boolean the regression guard
enforces.  All reported fields except ``wall_s`` are deterministic functions
of the trace (latency is measured on the round clock), so the whole report
is CI-diffable.

    PYTHONPATH=src python -m benchmarks.serve_load \\
        --trace benchmarks/traces/serve_smoke.json

Regenerate the committed traces with ``--write-trace`` after changing rates
or scale (then re-commit ``results/serve_load.json``).
"""

from __future__ import annotations

import argparse
from pathlib import Path

import numpy as np

from benchmarks.common import write_json_atomic
from repro.graphs.generators import make_graph
from repro.launch.serve_graph import GraphService
from repro.launch.service import (
    load_traces,
    poisson_trace,
    replay_continuous,
    replay_fixed,
    save_traces,
)
from repro.launch.service.scheduler import ContinuousScheduler
from repro.solve import multi_source_x0, ppr_teleport, solve_batch

RESULTS = Path(__file__).resolve().parents[1] / "results"
TRACES = Path(__file__).resolve().parent / "traces" / "serve_smoke.json"

# SSSP wants length-valued edges, PPR wants pagerank-valued ones — two
# resident tenants in one scheduler process, same topology family.
TENANTS = {"road": ("sssp", "sssp"), "social": ("ppr", "pagerank")}


def build_services(args) -> dict:
    services = {}
    for tenant, (algo, kind) in TENANTS.items():
        g = make_graph("kron", scale=args.scale, efactor=8, kind=kind)
        services[tenant] = GraphService(
            g,
            n_workers=args.workers,
            delta=args.delta,
            batch_size=args.batch_size,
            min_chunk=args.min_chunk,
            algos=(algo,),
            queue_capacity=args.queue_capacity,
        )
    return services


def sustained(report: dict, p99_threshold: float) -> bool:
    return (
        report["rejected"] == 0
        and report["unconverged"] == 0
        and report["completed"] == report["offered"]
        and report["p99_rounds"] <= p99_threshold
    )


def check_bit_identity(services: dict, results: list, sample: int = 4) -> bool:
    """Slotted-in answers == fresh Q=1 ``solve_batch`` of the same query."""
    for r in results[:sample]:
        service = services[r.graph]
        solver = service.solver(r.algo)
        g = service.graph
        if r.algo == "sssp":
            ref = solve_batch(solver, multi_source_x0(g, [r.payload]))
        else:
            x0 = np.full((1, g.n), 1.0 / g.n, np.float32)
            ref = solve_batch(
                solver, x0, q=ppr_teleport(g, [r.payload], service.damping)
            )
        if not np.array_equal(r.x, ref.x[0]):
            return False
    return True


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=8, help="log2 vertices per tenant")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--delta", type=int, default=32)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--min-chunk", type=int, default=8)
    ap.add_argument("--queue-capacity", type=int, default=16)
    ap.add_argument("--duration", type=float, default=400.0, help="arrival window")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument(
        "--rates",
        default="0.02,0.03,0.05,0.08,0.12,0.16",
        help="offered loads to sweep, queries per round (comma list)",
    )
    ap.add_argument(
        "--p99-threshold",
        type=float,
        default=60.0,
        help="p99 latency bar (round-clock units) defining a sustained load",
    )
    ap.add_argument(
        "--trace",
        default=None,
        help="replay committed traces instead of generating (the CI path)",
    )
    ap.add_argument(
        "--write-trace",
        default=None,
        help="save the generated traces here (commit for CI replay)",
    )
    ap.add_argument("--out", default=str(RESULTS / "serve_load.json"))
    args = ap.parse_args(argv)

    if args.trace:
        traces = load_traces(args.trace)
    else:
        rates = [float(r) for r in args.rates.split(",")]
        n_v = {t: 2**args.scale for t in TENANTS}
        traces = [
            poisson_trace(
                rate,
                args.duration,
                n_v,
                seed=args.seed,
                graph_for={algo: (t,) for t, (algo, _) in TENANTS.items()},
            )
            for rate in rates
        ]
        if args.write_trace:
            save_traces(args.write_trace, traces)
            print(f"wrote {len(traces)} traces -> {args.write_trace}")

    sweep = []
    bit_identical = True
    for trace in traces:
        services = build_services(args)
        sched = ContinuousScheduler(services, queue_capacity=args.queue_capacity)
        cont = replay_continuous(sched, trace)
        bit_identical &= check_bit_identity(services, cont["results"])
        fixed = replay_fixed(
            build_services(args),
            trace,
            batch_size=args.batch_size,
            queue_capacity=args.queue_capacity,
        )
        row = {
            "rate": trace.rate,
            "offered": len(trace.events),
            "continuous": cont["report"],
            "fixed": fixed["report"],
            "continuous_sustained": sustained(cont["report"], args.p99_threshold),
            "fixed_sustained": sustained(fixed["report"], args.p99_threshold),
        }
        sweep.append(row)
        print(
            f"rate={trace.rate:g} offered={row['offered']:4d}  "
            f"continuous: p99={cont['report']['p99_rounds']:8.1f} "
            f"shed={cont['report']['rejected']:3d} "
            f"{'OK ' if row['continuous_sustained'] else 'sat'}  |  "
            f"fixed: p99={fixed['report']['p99_rounds']:8.1f} "
            f"shed={fixed['report']['rejected']:3d} "
            f"{'OK' if row['fixed_sustained'] else 'sat'}"
        )

    max_cont = max(
        (r["rate"] for r in sweep if r["continuous_sustained"]), default=0.0
    )
    max_fixed = max((r["rate"] for r in sweep if r["fixed_sustained"]), default=0.0)
    summary = {
        "p99_threshold_rounds": args.p99_threshold,
        "max_load_continuous": max_cont,
        "max_load_fixed": max_fixed,
        # the tentpole claim, enforced by check_regression as a boolean
        "continuous_sustains_higher_load": max_cont > max_fixed,
        "slot_in_bit_identical": bool(bit_identical),
    }
    print(
        f"max sustained load: continuous={max_cont:g} fixed={max_fixed:g} "
        f"(p99 <= {args.p99_threshold:g} rounds)  "
        f"bit-identical={summary['slot_in_bit_identical']}"
    )
    report = {
        "config": {
            "scale": args.scale,
            "batch_size": args.batch_size,
            "queue_capacity": args.queue_capacity,
            "delta": args.delta,
            "n_traces": len(traces),
        },
        "sweep": sweep,
        "summary": summary,
    }
    write_json_atomic(args.out, report)
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
