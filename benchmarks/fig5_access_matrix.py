"""Paper Fig 5: worker-to-worker access matrices (local vs remote reads).

Kron should be diffuse (low diagonal mass), Web diagonal-clustered (high) —
the paper's explanation for when delaying helps.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import DEFAULT_P, GRAPHS, emit, load_graph, record
from repro.core.access_matrix import access_matrix, locality_fraction
from repro.graphs.partition import balanced_blocks


def run(P: int = DEFAULT_P) -> list:
    rows = []
    for gname in GRAPHS:
        g = load_graph(gname)
        mat = access_matrix(g, balanced_blocks(g, P))
        loc = locality_fraction(mat)
        # paper's "+" criterion: row receives ≥ 1/P of its reads from itself
        frac_self = np.diag(mat) / np.maximum(mat.sum(axis=1), 1)
        plus_workers = int((frac_self >= 1.0 / P).sum())
        rows.append(
            {
                "graph": gname,
                "P": P,
                "locality_fraction": round(loc, 4),
                "workers_self_dominant": plus_workers,
                "row_normalized_diag_mean": float(frac_self.mean()),
            }
        )
        emit(
            f"fig5/{gname}",
            0.0,
            f"loc={loc:.3f};self_dom={plus_workers}/{P}",
        )
    record("fig5_access_matrix", rows)
    return rows


if __name__ == "__main__":
    run()
