"""Paper Fig 5: worker-to-worker access matrices (local vs remote reads).

Kron should be diffuse (low diagonal mass), Web diagonal-clustered (high) —
the paper's explanation for when delaying helps.  The same clustering decides
what the frontier-sharded engine pays per commit, so each row now quantifies
the insight with the partition's edge-cut and halo stats (off-diagonal reads
== cut edges == halo traffic) instead of only plotting it — and compares the
degree-aware greedy partitioner against the paper's balanced split.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import DEFAULT_P, GRAPHS, emit, load_graph, record
from repro.core.access_matrix import access_matrix, partition_report
from repro.graphs.partition import make_partition


def run(P: int = DEFAULT_P) -> list:
    rows = []
    for gname in GRAPHS:
        g = load_graph(gname)
        part = make_partition(g, P, method="balanced")
        mat = access_matrix(g, part)
        rep = partition_report(g, part, mat)
        # paper's "+" criterion: row receives ≥ 1/P of its reads from itself
        frac_self = np.diag(mat) / np.maximum(mat.sum(axis=1), 1)
        plus_workers = int((frac_self >= 1.0 / P).sum())
        greedy = make_partition(g, P, method="greedy_degree")
        row = {
            "graph": gname,
            "P": P,
            "workers_self_dominant": plus_workers,
            "row_normalized_diag_mean": float(frac_self.mean()),
            **rep,
            "greedy_degree_edge_cut": greedy.edge_cut,
            "greedy_degree_halo_total": greedy.halo_total,
        }
        rows.append(row)
        emit(
            f"fig5/{gname}",
            0.0,
            f"loc={rep['locality_fraction']:.3f};self_dom={plus_workers}/{P};"
            f"cut={rep['edge_cut']};halo={rep['halo_total']}",
        )
    record("fig5_access_matrix", rows)
    return rows


if __name__ == "__main__":
    run()
