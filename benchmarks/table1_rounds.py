"""Paper Table I: rounds + avg time/round for PR under sync/async/hybrid."""

from __future__ import annotations

from benchmarks.common import DEFAULT_P, GRAPHS, MIN_CHUNK, emit, load_graph, record
from repro.algorithms import pagerank


def run(deltas=(256,)) -> list:
    rows = []
    for gname in GRAPHS:
        g = load_graph(gname)
        for mode, delta in [("sync", None), ("async", None)] + [
            ("delayed", d) for d in deltas
        ]:
            r = pagerank(
                g, P=DEFAULT_P, mode=mode, delta=delta, min_chunk=MIN_CHUNK
            )
            label = mode if mode != "delayed" else f"delayed{delta}"
            rows.append(
                {
                    "graph": gname,
                    "mode": label,
                    "rounds": r.rounds,
                    "avg_round_time_s": r.avg_round_time_s,
                    "flushes": r.flushes,
                    "flush_bytes": r.flush_bytes,
                    "converged": r.converged,
                    "delta": r.delta,
                }
            )
            emit(
                f"table1/{gname}/{label}",
                r.avg_round_time_s * 1e6,
                f"rounds={r.rounds};flushes={r.flushes}",
            )
    record("table1_rounds", rows)
    return rows


if __name__ == "__main__":
    run()
