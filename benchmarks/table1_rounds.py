"""Paper Table I: rounds + avg time/round for PR under sync/async/hybrid."""

from __future__ import annotations

from benchmarks.common import DEFAULT_P, GRAPHS, MIN_CHUNK, emit, load_graph, record
from repro.solve import Solver, pagerank_problem


def run(deltas=(256,)) -> list:
    rows = []
    for gname in GRAPHS:
        g = load_graph(gname)
        solver = Solver(
            g,
            pagerank_problem(),
            n_workers=DEFAULT_P,
            backend="host",
            min_chunk=MIN_CHUNK,
        )
        for delta in ["sync", "async", *deltas]:
            r = solver.solve(delta=delta)
            label = delta if isinstance(delta, str) else f"delayed{delta}"
            rows.append(
                {
                    "graph": gname,
                    "mode": label,
                    "rounds": r.rounds,
                    "avg_round_time_s": r.avg_round_time_s,
                    "flushes": r.flushes,
                    "flush_bytes": r.flush_bytes,
                    "converged": r.converged,
                    "delta": r.delta,
                }
            )
            emit(
                f"table1/{gname}/{label}",
                r.avg_round_time_s * 1e6,
                f"rounds={r.rounds};flushes={r.flushes}",
            )
    record("table1_rounds", rows)
    return rows


if __name__ == "__main__":
    run()
