"""Paper Figs 3/4: worker scaling — best δ vs worker count (kron, web).

The paper's finding: on Kron the best δ *decreases* as threads increase; on
Web no δ beats async.  We sweep P ∈ {4..32} and report measured rounds plus
the δ* minimizing the modeled TPU total time.
"""

from __future__ import annotations

from benchmarks.common import DELTAS, MIN_CHUNK, emit, load_graph, record
from repro.core.delta_model import fit_delta_model
from repro.solve import Solver, pagerank_problem


def run(graphs=("kron", "web"), Ps=(4, 8, 16, 32)) -> list:
    rows = []
    for gname in graphs:
        g = load_graph(gname)
        for P in Ps:
            solver = Solver(
                g, pagerank_problem(), n_workers=P, backend="host", min_chunk=MIN_CHUNK
            )
            sync = solver.solve(delta="sync")
            asyn = solver.solve(delta="async")
            model = fit_delta_model(g, P, sync.rounds, asyn.rounds, delta_min=MIN_CHUNK)
            best = model.best_delta(DELTAS + [model.B])
            rows.append(
                {
                    "graph": gname,
                    "P": P,
                    "rounds_sync": sync.rounds,
                    "rounds_async": asyn.rounds,
                    "best_delta_modeled": best,
                    "locality": model.locality,
                    "modeled_best_speedup_vs_async": model.total_time_s(
                        model.delta_min
                    )
                    / model.total_time_s(best),
                }
            )
            emit(
                f"fig34/{gname}/P{P}",
                0.0,
                f"delta*={best};sync={sync.rounds};async={asyn.rounds}",
            )
    record("fig34_scaling", rows)
    return rows


if __name__ == "__main__":
    run()
