"""Paper Fig 6: Bellman-Ford SSSP speedups over sync (async + δ sweep).

Paper finding: fewer updates per round than PR → buffering helps less; Road
and Web should show no benefit.

One ``Solver`` per graph serves the sweep from its schedule cache; wall
times come from ``EngineResult.total_time_s`` (compile cost excluded), so
the sync baseline and the δ points compare like with like.
"""

from __future__ import annotations

from benchmarks.common import (
    DEFAULT_P,
    DELTAS,
    GRAPHS,
    MIN_CHUNK,
    emit,
    load_graph,
    record,
)
from repro.core.delta_model import fit_delta_model
from repro.solve import Solver, sssp_problem


def run(P: int = DEFAULT_P) -> list:
    rows = []
    for gname in GRAPHS:
        g = load_graph(gname, kind="sssp")
        solver = Solver(
            g, sssp_problem(), n_workers=P, backend="host", min_chunk=MIN_CHUNK
        )
        sync = solver.solve(delta="sync")
        t_sync = sync.total_time_s
        asyn = solver.solve(delta="async")
        model = fit_delta_model(g, P, sync.rounds, asyn.rounds, delta_min=MIN_CHUNK)
        m_sync = model.total_time_s(model.B)

        def add(label, res, d):
            t = res.total_time_s
            m = model.total_time_s(d)
            rows.append(
                {
                    "graph": gname,
                    "mode": label,
                    "rounds": res.rounds,
                    "wall_speedup_vs_sync": t_sync / t if t else float("nan"),
                    "modeled_speedup_vs_sync": m_sync / m,
                }
            )
            emit(
                f"fig6/{gname}/{label}",
                t * 1e6,
                f"wallx={t_sync / t:.3f};modelx={m_sync / m:.3f};rounds={res.rounds}",
            )

        add("async", asyn, model.delta_min)
        for d in DELTAS:
            r = solver.solve(delta=d)
            add(f"delayed{d}", r, d)
    record("fig6_sssp_speedup", rows)
    return rows


if __name__ == "__main__":
    run()
