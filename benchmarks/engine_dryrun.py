"""Dry-run roofline of the paper's own engine on the production mesh.

Lowers one delayed-async PageRank round (P = 256 schedule workers, sharded
over however many devices the host exposes — 256-wide on the production
mesh, 8-wide on the CI smoke run) for sync / delayed / async schedules on a
kron graph, and counts the flush all-gather bytes — the TPU realisation of
the paper's Table-I flush counts.

    PYTHONPATH=src python -m benchmarks.engine_dryrun [--scale 19]
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
from pathlib import Path

import jax
import numpy as np

from repro.core.engine import make_schedule
from repro.core.semiring import PLUS_TIMES
from repro.dist.compat import make_mesh
from repro.dist.engine_sharded import input_specs_for_engine, sharded_round_fn
from repro.graphs.generators import make_graph
from repro.launch.dryrun import collective_stats

RESULTS = Path(__file__).resolve().parents[1] / "results"
ICI_BW = 50e9
P = 256  # schedule workers (a multiple of every mesh width we run on)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=19, help="kron graph scale")
    args = ap.parse_args(argv)

    g = make_graph("kron", scale=args.scale, efactor=8, kind="pagerank")
    n = g.n
    tele = np.float32(0.15 / n)
    # largest power-of-two mesh width the host supports (always divides P)
    n_dev = len(jax.devices())
    width = 1
    while width * 2 <= min(P, n_dev):
        width *= 2
    mesh = make_mesh((width,), ("data",), devices=jax.devices()[:width])
    rows = []
    for mode, delta in [("async", None), ("delayed", 512), ("sync", None)]:
        sched = make_schedule(g, P, delta, PLUS_TIMES, mode=mode)
        rnd = sharded_round_fn(
            sched, PLUS_TIMES, lambda o, r, w: tele + r, mesh, axis="data"
        )
        specs = input_specs_for_engine(sched, PLUS_TIMES)
        compiled = jax.jit(rnd).lower(*specs).compile()
        coll = collective_stats(compiled.as_text())
        flush_bytes = sched.S * P * sched.delta * 4  # analytic per round
        rows.append(
            {
                "mode": mode,
                "delta": sched.delta,
                "commits_per_round": sched.S,
                "mesh_width": width,
                "hlo_collective_bytes": coll["total_bytes"],
                "analytic_flush_bytes": flush_bytes,
                "flush_time_ms": flush_bytes / (P * ICI_BW) * 1e3
                + sched.S * 1e-3,  # + α=1µs latency per commit
            }
        )
        print(
            f"{mode:8s} δ={sched.delta:6d} commits/round={sched.S:4d} "
            f"HLO coll={coll['total_bytes']/2**20:8.2f} MiB "
            f"flush-term≈{rows[-1]['flush_time_ms']:.3f} ms/round"
        )
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "engine_dryrun.json").write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    main()
