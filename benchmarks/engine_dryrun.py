"""Dry-run roofline of the paper's own engine on the production mesh.

Lowers one delayed-async PageRank round (P = 256 schedule workers, sharded
over however many devices the host exposes — 256-wide on the production
mesh, 8-wide on the CI smoke run) for sync / delayed / async schedules on a
kron graph, and counts the flush all-gather bytes — the TPU realisation of
the paper's Table-I flush counts.

Each row also carries the kernel datapoint: per-round HBM bytes of the
fused Pallas round (:func:`repro.core.engine.round_fn_pallas` — edge stripes
read once, frontier read+written once, everything else VMEM-resident)
against the XLA round, whose every commit step round-trips the frontier
through HBM (``cost_analysis`` of one compiled commit step × S; XLA's
``cost_analysis`` counts loop bodies once, so the full-round number would
undercount — see ``benchmarks/model_costs.py``).

    PYTHONPATH=src python -m benchmarks.engine_dryrun [--scale 19]
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
from functools import partial
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import write_json_atomic

from repro.core.engine import _commit_step, make_schedule, round_fn_pallas
from repro.core.semiring import PLUS_TIMES
from repro.dist.compat import cost_analysis, make_mesh
from repro.dist.engine_sharded import input_specs_for_engine, sharded_round_fn
from repro.graphs.generators import make_graph
from repro.launch.dryrun import collective_stats

RESULTS = Path(__file__).resolve().parents[1] / "results"
ICI_BW = 50e9
P = 256  # schedule workers (a multiple of every mesh width we run on)


def fused_vs_xla_round_bytes(sched, row_update) -> dict:
    """Per-round HBM bytes: the fused Pallas round vs the XLA round.

    Three numbers, two accountings:

    * ``pallas_round_bytes`` — the fused kernel's HBM *contract*: by
      BlockSpec construction its traffic is exactly operands + result (edge
      stripes streamed once, frontier in + out once, commits stay in VMEM),
      measured as the compiled call's argument + output bytes.
    * ``xla_round_model_bytes`` — the XLA round under the *same* contract
      accounting: the S steps together also stream the stripes once, but
      each step re-reads and re-writes the frontier through HBM, so the
      frontier term is ``2·S·F`` instead of ``2·F``.  This is the
      apples-to-apples line the S>1 assertion uses — the fusion win is
      exactly ``2·(S−1)·F`` of frontier traffic.
    * ``xla_commit_step_bytes`` / ``xla_round_bytes`` — XLA's own
      ``cost_analysis`` of one compiled commit step (× S for the round).
      This includes intermediate-buffer traffic (gather/segment-sum temps
      the kernel keeps in VMEM), so it sits above the contract model; kept
      as the measured upper line.
    """
    x_ext = jax.ShapeDtypeStruct((sched.n_slots,), PLUS_TIMES.dtype)
    stripes = (sched.src, sched.val, sched.dst_local, sched.rows)
    stripe_avals = tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in stripes)

    # The stripe arrays are explicit arguments on both sides (rather than
    # compiled-in constants) so both measurements count the edge traffic.
    def with_stripes(fn_of_sched):
        def wrapped(x, src, val, dst, rows):
            s = dataclasses.replace(sched, src=src, val=val, dst_local=dst, rows=rows)
            return fn_of_sched(s)(x)

        return jax.jit(wrapped).lower(x_ext, *stripe_avals).compile()

    step = with_stripes(
        lambda s: partial(
            _commit_step, 0, sched=s, semiring=PLUS_TIMES, row_update=row_update
        )
    )
    step_bytes = float(cost_analysis(step).get("bytes accessed", 0.0))
    fused = with_stripes(lambda s: round_fn_pallas(s, PLUS_TIMES, row_update))
    mem = fused.memory_analysis()
    pallas_bytes = float(mem.argument_size_in_bytes + mem.output_size_in_bytes)
    frontier_bytes = np.dtype(PLUS_TIMES.dtype).itemsize * sched.n_slots
    stripe_bytes = sum(int(a.size) * a.dtype.itemsize for a in stripes)
    model_bytes = stripe_bytes + 2 * sched.S * frontier_bytes
    return {
        "xla_commit_step_bytes": step_bytes,
        "xla_round_bytes": sched.S * step_bytes,
        "xla_round_model_bytes": model_bytes,
        "pallas_round_bytes": pallas_bytes,
        "fused_traffic_ratio": pallas_bytes / max(model_bytes, 1),
        # the frontier term alone: S HBM round-trips vs exactly one
        "xla_frontier_bytes_per_round": 2 * sched.S * frontier_bytes,
        "pallas_frontier_bytes_per_round": 2 * frontier_bytes,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=19, help="kron graph scale")
    args = ap.parse_args(argv)

    g = make_graph("kron", scale=args.scale, efactor=8, kind="pagerank")
    n = g.n
    tele = np.float32(0.15 / n)
    row_update = lambda o, r, w: tele + r
    # largest power-of-two mesh width the host supports (always divides P)
    n_dev = len(jax.devices())
    width = 1
    while width * 2 <= min(P, n_dev):
        width *= 2
    mesh = make_mesh((width,), ("data",), devices=jax.devices()[:width])
    rows = []
    for mode, delta in [("async", None), ("delayed", 512), ("sync", None)]:
        sched = make_schedule(g, P, delta, PLUS_TIMES, mode=mode)
        rnd = sharded_round_fn(sched, PLUS_TIMES, row_update, mesh, axis="data")
        specs = input_specs_for_engine(sched, PLUS_TIMES)
        compiled = jax.jit(rnd).lower(*specs).compile()
        coll = collective_stats(compiled.as_text())
        flush_bytes = sched.S * P * sched.delta * 4  # analytic per round
        kernel = fused_vs_xla_round_bytes(sched, row_update)
        if sched.S > 1:
            # the whole point of the fusion: edge stripes once + frontier
            # once beats S frontier round-trips (same contract accounting)
            assert kernel["pallas_round_bytes"] < kernel["xla_round_model_bytes"], (
                kernel
            )
            if kernel["xla_commit_step_bytes"] > 0:  # cost model may omit bytes
                assert kernel["pallas_round_bytes"] < kernel["xla_round_bytes"], kernel
        rows.append(
            {
                "mode": mode,
                "delta": sched.delta,
                "commits_per_round": sched.S,
                "mesh_width": width,
                "hlo_collective_bytes": coll["total_bytes"],
                "analytic_flush_bytes": flush_bytes,
                "flush_time_ms": flush_bytes / (P * ICI_BW) * 1e3
                + sched.S * 1e-3,  # + α=1µs latency per commit
                **kernel,
            }
        )
        print(
            f"{mode:8s} δ={sched.delta:6d} commits/round={sched.S:4d} "
            f"HLO coll={coll['total_bytes']/2**20:8.2f} MiB "
            f"flush-term≈{rows[-1]['flush_time_ms']:.3f} ms/round  "
            f"round HBM: pallas={kernel['pallas_round_bytes']/2**20:7.2f} MiB "
            f"vs xla model={kernel['xla_round_model_bytes']/2**20:7.2f} MiB "
            f"({kernel['fused_traffic_ratio']:.2f}x, "
            f"frontier 1/{sched.S} of the XLA round's)"
        )
    write_json_atomic(RESULTS / "engine_dryrun.json", rows)
    return rows


if __name__ == "__main__":
    main()
