"""Dry-run roofline of the paper's own engine on the production mesh.

Lowers one delayed-async PageRank round (P = 256 workers = the single-pod
mesh "data"×"model" axes flattened... here: the "data" axis at 16 workers ×
16-way replicated, and a full 256-worker variant) for δ ∈ {128, 1024, B} on
a kron graph, and counts the flush all-gather bytes — the TPU realisation of
the paper's Table-I flush counts.

    PYTHONPATH=src python -m benchmarks.engine_dryrun
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import make_schedule
from repro.core.semiring import PLUS_TIMES
from repro.dist.engine_sharded import input_specs_for_engine, sharded_round_fn
from repro.graphs.generators import make_graph
from repro.launch.dryrun import collective_stats

RESULTS = Path(__file__).resolve().parents[1] / "results"
ICI_BW = 50e9


def main():
    g = make_graph("kron", scale=19, efactor=8, kind="pagerank")
    n = g.n
    tele = np.float32(0.15 / n)
    P = 256
    mesh = jax.make_mesh(
        (P,), ("data",), axis_types=(jax.sharding.AxisType.Auto,)
    )
    rows = []
    for mode, delta in [("async", None), ("delayed", 512), ("sync", None)]:
        sched = make_schedule(g, P, delta, PLUS_TIMES, mode=mode)
        rnd = sharded_round_fn(
            sched, PLUS_TIMES, lambda o, r, w: tele + r, mesh, axis="data"
        )
        with jax.set_mesh(mesh):
            compiled = jax.jit(rnd).lower(*input_specs_for_engine(sched, PLUS_TIMES)).compile()
        coll = collective_stats(compiled.as_text())
        flush_bytes = sched.S * P * sched.delta * 4  # analytic per round
        rows.append(
            {
                "mode": mode,
                "delta": sched.delta,
                "commits_per_round": sched.S,
                "hlo_collective_bytes": coll["total_bytes"],
                "analytic_flush_bytes": flush_bytes,
                "flush_time_ms": flush_bytes / (P * ICI_BW) * 1e3
                + sched.S * 1e-3,  # + α=1µs latency per commit
            }
        )
        print(
            f"{mode:8s} δ={sched.delta:6d} commits/round={sched.S:4d} "
            f"HLO coll={coll['total_bytes']/2**20:8.2f} MiB "
            f"flush-term≈{rows[-1]['flush_time_ms']:.3f} ms/round"
        )
    (RESULTS / "engine_dryrun.json").write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    main()
