"""Trip-count-aware analytic FLOP/byte model of the *compiled* programs.

``compiled.cost_analysis()`` on XLA counts while-loop bodies once (verified
in EXPERIMENTS.md §Dry-run), so scan-over-layers / chunked-attention programs
under-report by the trip count.  This module reconstructs the executed FLOPs
of each cell from the model math, *including* the compiled program's known
overheads:

* remat: backward re-executes the forward of every layer (factor 2 fwd-cost
  in the bwd term → total 3× fwd +  1× extra fwd ≈ 4·fwd per train step
  — 2 fwd (orig + recompute) + 2 fwd-equivalents for grads);
* masked-attention waste: the ``masked`` schedule computes the full q×kv
  square (2× causal work); ``banded`` computes ⌈(i+1)/nk⌉ tiles only;
* MoE capacity slack: expert GEMMs run at ``capacity_factor`` occupancy.

Validated against ``cost_analysis()`` on unrolled reduced configs in
``tests/test_roofline_model.py`` (agreement within tolerance).
"""

from __future__ import annotations

import dataclasses

from repro.configs.shapes import SHAPES, ShapeSpec
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class CellCost:
    flops: float  # executed FLOPs (global, one step)
    model_flops: float  # useful FLOPs = 6·N_active·D (train) / 2·N_active·D
    hbm_bytes: float  # global HBM traffic estimate
    notes: str = ""


def _attn_flops_fwd(cfg: ModelConfig, B, S, causal=True, window=0):
    """QK^T + PV flops for all attention layers at seq S (per fwd)."""
    n_attn = sum(1 for k in cfg.layer_kinds if k == "attn")
    if n_attn == 0:
        return 0.0
    hd, Hq = cfg.hd, cfg.n_heads
    if window:
        eff = min(window, S)
        pairs = S * eff  # banded
    elif causal:
        if cfg.attn_schedule == "banded":
            pairs = S * S / 2  # tile-level banding ≈ causal half
        else:
            pairs = S * S  # masked schedule computes the full square
    else:
        pairs = S * S
    return n_attn * B * Hq * pairs * hd * 2 * 2  # qk + pv, 2 flops/MAC


def _ssm_flops_fwd(cfg: ModelConfig, B, S):
    n_ssm = sum(1 for k in cfg.layer_kinds if k == "ssm")
    if n_ssm == 0:
        return 0.0
    H, P, N, Q = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_chunk
    Qe = min(Q, S)
    # intra-chunk quadratic (CBᵀ then (L∘CB)·X) + inter-chunk state path
    intra = B * (S // max(Qe, 1)) * (Qe * Qe * N + Qe * Qe * H * P) * 2
    state = B * S * H * P * N * 2 * 2  # build + read state
    return n_ssm * (intra + state)


def _param_flops(cfg: ModelConfig, n_active_params, B, S):
    return 2.0 * n_active_params * B * S


def cell_cost(cfg: ModelConfig, shape: ShapeSpec, accum: int = 1) -> CellCost:
    B, S = shape.global_batch, shape.seq_len
    N_act = cfg.active_param_count()
    dtype_bytes = 2  # bf16 compute

    if shape.kind == "train":
        fwd = (
            _param_flops(cfg, N_act, B, S)
            + _attn_flops_fwd(cfg, B, S)
            + _ssm_flops_fwd(cfg, B, S)
        )
        # fwd + remat-recompute-fwd + 2×fwd-equivalent for backward matmuls
        flops = 4.0 * fwd
        model = 6.0 * N_act * B * S
        # HBM: params read ×(fwd+bwd+recompute) + grads + opt states + acts
        n_par = cfg.param_count()
        hbm = (
            3 * n_par * dtype_bytes * accum  # weights per microbatch pass
            + n_par * 4 * 4  # grads + m + v + params update in f32
            + 4 * B * S * cfg.d_model * dtype_bytes * cfg.n_layers
        )
        return CellCost(flops, model, hbm, f"remat×4fwd, accum={accum}")

    if shape.kind == "prefill":
        fwd = (
            _param_flops(cfg, N_act, B, S)
            + _attn_flops_fwd(cfg, B, S)
            + _ssm_flops_fwd(cfg, B, S)
        )
        model = 2.0 * N_act * B * S
        hbm = (
            cfg.param_count() * dtype_bytes
            + 2 * B * S * cfg.d_model * dtype_bytes * cfg.n_layers
        )
        return CellCost(fwd, model, hbm, "single fwd")

    # decode: one token; context = S
    n_attn = sum(1 for k in cfg.layer_kinds if k == "attn")
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    ctx = min(cfg.window, S) if (cfg.pattern and cfg.window) else S
    attn = n_attn * B * Hq * ctx * hd * 2 * 2
    ssm = sum(1 for k in cfg.layer_kinds if k == "ssm") * B * (
        cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state * 2 * 2
    )
    rglru = sum(1 for k in cfg.layer_kinds if k == "rglru") * B * (
        (cfg.lru_width or cfg.d_model) ** 2 * 2 * 2
    )
    flops = _param_flops(cfg, N_act, B, 1) + attn + ssm + rglru
    model = 2.0 * N_act * B
    kv_bytes = n_attn * B * ctx * Hkv * hd * 2 * dtype_bytes
    hbm = cfg.param_count() * dtype_bytes + kv_bytes
    return CellCost(flops, model, hbm, f"ctx={ctx}")
