"""Paper Fig 2: PR speedup over synchronous baseline, async + δ sweep.

Two speedup columns per point:

* ``wall`` — measured wall-clock on this host (captures the rounds effect;
  the cache-contention effect does not exist on a 1-core CPU device, see
  DESIGN.md §9.3);
* ``modeled`` — the TPU cost model ``rounds(δ)·round_cost(δ)`` with the
  explicit commit-collective term (repro.core.delta_model), which is where
  the paper's hump-shaped δ curve lives on this hardware.

One ``Solver`` per graph serves the whole sweep: the sync/async probes warm
the same schedule cache the δ points reuse, and compile cost never pollutes
the wall-clock columns (``EngineResult`` reports it separately).
"""

from __future__ import annotations

from benchmarks.common import (
    DEFAULT_P,
    DELTAS,
    GRAPHS,
    MIN_CHUNK,
    emit,
    load_graph,
    record,
)
from repro.core.delta_model import fit_delta_model
from repro.solve import Solver, pagerank_problem


def run(P: int = DEFAULT_P) -> list:
    rows = []
    for gname in GRAPHS:
        g = load_graph(gname)
        solver = Solver(
            g, pagerank_problem(), n_workers=P, backend="host", min_chunk=MIN_CHUNK
        )
        base = solver.solve(delta="sync")
        t_sync = base.total_time_s
        r_async = solver.solve(delta="async")
        model = fit_delta_model(g, P, base.rounds, r_async.rounds, delta_min=MIN_CHUNK)
        m_sync = model.total_time_s(model.B)

        def add(label, res, delta_for_model):
            t = res.total_time_s
            m = model.total_time_s(delta_for_model)
            rows.append(
                {
                    "graph": gname,
                    "mode": label,
                    "rounds": res.rounds,
                    "wall_speedup_vs_sync": t_sync / t if t else float("nan"),
                    "modeled_speedup_vs_sync": m_sync / m,
                    "flush_bytes": res.flush_bytes,
                }
            )
            emit(
                f"fig2/{gname}/{label}",
                t * 1e6,
                f"wallx={t_sync / t:.3f};modelx={m_sync / m:.3f};rounds={res.rounds}",
            )

        add("async", r_async, model.delta_min)
        for d in DELTAS:
            r = solver.solve(delta=d)
            add(f"delayed{d}", r, d)
    record("fig2_pr_speedup", rows)
    return rows


if __name__ == "__main__":
    run()
