"""Chaos-replay gate: injected faults must never change an answer.

Replays the committed chaos trace (``benchmarks/traces/chaos_smoke.json``)
through three fault domains and pins the recovery contract as committed
booleans the regression guard (and ``--assert-gate``) enforces:

* **serving** — two resident tenants (SSSP road / PPR social) answer the
  trace's queries twice: fault-free, then under injected lane faults,
  kernel-dispatch faults, and torn/corrupt/EIO cache I/O.  Every admitted
  query must still retire with the **bit-identical** answer (zero typed
  failures, zero silent losses — ``accepted == completed``).
* **degrade** — a ``degrade=True`` solver hit by a pallas dispatch fault
  must climb down the degradation ladder and return the bit-identical
  fixed point, recording exactly the expected typed ``Degradation``.
* **checkpoint** — a sharded solve on an 8-wide mesh is killed mid-flight
  (injected ``solver.round`` fault with ``max_restores=0``); a fresh
  solver on a **4-wide mesh** must resume from the committed snapshot and
  land on the bit-identical fixed point, with recovery overhead (replayed
  rounds) bounded by the checkpoint cadence.

All reported fields are deterministic functions of the trace, so the whole
report is CI-diffable::

    PYTHONPATH=src python -m benchmarks.chaos_replay --assert-gate
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
from pathlib import Path

# fixed 8-device host platform so mesh widths (and the committed report)
# are identical locally and in CI
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from benchmarks.common import write_json_atomic
from repro.dist.compat import make_mesh
from repro.ft.elastic import checkpointed_solve
from repro.ft.inject import FaultPlan, InjectedFault, inject
from repro.graphs.generators import make_graph
from repro.launch.serve_graph import GraphService
from repro.launch.service import QueryRequest
from repro.launch.service.scheduler import ContinuousScheduler
from repro.solve import Solver, sssp_problem

RESULTS = Path(__file__).resolve().parents[1] / "results"
TRACES = Path(__file__).resolve().parent / "traces" / "chaos_smoke.json"

# two resident tenants, same shapes as the serve-load smoke: SSSP wants
# length-valued edges, PPR wants pagerank-valued ones
TENANTS = {"road": ("sssp", "sssp"), "social": ("ppr", "pagerank")}


def build_services(args, cache_dir=None) -> dict:
    services = {}
    for tenant, (algo, kind) in TENANTS.items():
        g = make_graph("kron", scale=args.scale, efactor=8, kind=kind)
        services[tenant] = GraphService(
            g,
            n_workers=args.workers,
            delta=args.delta,
            batch_size=args.batch_size,
            min_chunk=args.min_chunk,
            algos=(algo,),
            cache_dir=None if cache_dir is None else str(cache_dir),
            degrade=True,
        )
    return services


def run_queries(args, queries, plan=None):
    """Submit the trace's queries, drain, and account for every admission."""
    # a real cache dir in the chaos run so persist.write/read faults hit
    # actual I/O paths (torn bytes on disk must read back as cache misses)
    cache_dir = tempfile.mkdtemp(prefix="chaos_cache_") if plan else None
    services = build_services(args, cache_dir=cache_dir)
    sched = ContinuousScheduler(services, queue_capacity=args.queue_capacity)
    ids = {}
    results, failures = [], []
    with inject(plan if plan is not None else FaultPlan()):
        for i, q in enumerate(queries):
            adm = sched.submit(
                QueryRequest(algo=q["algo"], payload=q["payload"], graph=q["graph"])
            )
            assert adm.accepted, f"query {i} rejected: {adm.reason}"
            ids[adm.request_id] = i
        results = sched.drain()
        failures = sched.take_failures()
    answers = {ids[r.request_id]: r for r in results}
    stats = sched.stats()
    return answers, failures, stats


def serving_section(args, trace) -> dict:
    queries = trace["queries"]
    baseline, base_failures, _ = run_queries(args, queries)
    assert not base_failures, "fault-free replay must not fail queries"
    plan = FaultPlan.from_json(trace["serving_faults"])
    answers, failures, stats = run_queries(args, queries, plan=plan)

    delivered = sorted(answers)
    bit_identical = delivered == sorted(baseline) and all(
        np.array_equal(answers[i].x, baseline[i].x) for i in delivered
    )
    c = stats["counters"]
    section = {
        "offered": len(queries),
        "accepted": c["accepted"],
        "completed": c["completed"],
        "failed": c["failed"],
        "lane_faults": c["lane_faults"],
        "retries": c["retries"],
        "faults_fired": plan.fired,
        "sites_fired": plan.sites_fired(),
        "zero_lost": c["accepted"] == c["completed"] + c["failed"] and c["failed"] == 0,
        "bit_identical": bool(bit_identical),
    }
    print(
        f"serving: {section['completed']}/{section['offered']} answered under "
        f"{section['faults_fired']} faults at {section['sites_fired']}  "
        f"lane_faults={section['lane_faults']} retries={section['retries']}  "
        f"bit-identical={section['bit_identical']}"
    )
    return section


def degrade_section(args, trace) -> dict:
    g = make_graph("kron", scale=args.scale, efactor=8, kind="sssp")
    ref = Solver(g, sssp_problem(), n_workers=args.workers, delta=args.delta).solve(
        backend="jit"
    )
    solver = Solver(
        g, sssp_problem(), n_workers=args.workers, delta=args.delta, degrade=True
    )
    plan = FaultPlan.from_json(trace["degrade_faults"])
    with inject(plan):
        out = solver.solve(backend="pallas")
    d = solver.degradations[0] if solver.degradations else None
    section = {
        "rounds": out.rounds,
        "faults_fired": plan.fired,
        "degradations": len(solver.degradations),
        "ladder": None if d is None else f"{d.from_backend}->{d.to_backend}",
        "bit_identical": bool(
            out.rounds == ref.rounds and np.array_equal(out.x, ref.x)
        ),
    }
    print(
        f"degrade: pallas dispatch fault -> {section['ladder']} in "
        f"{section['rounds']} rounds  bit-identical={section['bit_identical']}"
    )
    return section


def checkpoint_section(args, trace) -> dict:
    g = make_graph("kron", scale=args.ckpt_scale, efactor=8, kind="sssp")

    def solver_on(width: int) -> Solver:
        mesh = make_mesh((width,), ("data",), devices=jax.devices()[:width])
        return Solver(
            g,
            sssp_problem(),
            n_workers=args.ckpt_workers,
            delta=args.delta,
            backend="sharded",
            mesh=mesh,
        )

    ref = solver_on(8).solve(backend="sharded")
    plan = FaultPlan.from_json(trace["checkpoint_faults"])
    killed_at = plan.specs[0].match["round"]
    ckpt_dir = tempfile.mkdtemp(prefix="chaos_ckpt_")
    killed = False
    try:
        with inject(plan):
            checkpointed_solve(
                solver_on(8),
                backend="sharded",
                ckpt_dir=ckpt_dir,
                every=args.every,
                max_restores=0,  # the injected fault kills this "process"
            )
    except InjectedFault:
        killed = True
    out = checkpointed_solve(
        solver_on(4), backend="sharded", ckpt_dir=ckpt_dir, every=args.every
    )
    overhead = killed_at + out.rounds_executed - ref.rounds
    section = {
        "baseline_rounds": ref.rounds,
        "killed_at_round": killed_at,
        "killed": killed,
        "resumed_at": out.resumed_at,
        "resumed_mesh_width": 4,
        "rounds_after_resume": out.rounds_executed,
        "recovery_overhead_rounds": overhead,
        "checkpoint_every": args.every,
        "resumed_from_checkpoint": out.resumed_at is not None,
        "overhead_bounded": 0 <= overhead <= args.every,
        "bit_identical": bool(
            out.result.rounds == ref.rounds and np.array_equal(out.result.x, ref.x)
        ),
    }
    print(
        f"checkpoint: killed at round {killed_at} on 8-wide mesh, resumed at "
        f"round {out.resumed_at} on 4-wide mesh, +{overhead} replayed rounds  "
        f"bit-identical={section['bit_identical']}"
    )
    return section


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default=str(TRACES))
    ap.add_argument("--scale", type=int, default=8, help="log2 vertices per tenant")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--delta", type=int, default=32)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--min-chunk", type=int, default=8)
    ap.add_argument("--queue-capacity", type=int, default=16)
    ap.add_argument("--ckpt-scale", type=int, default=10)
    ap.add_argument("--ckpt-workers", type=int, default=8)
    ap.add_argument("--every", type=int, default=4, help="checkpoint cadence")
    ap.add_argument("--out", default=str(RESULTS / "chaos_replay.json"))
    ap.add_argument(
        "--assert-gate",
        action="store_true",
        help="fail (exit 1) unless every recovery contract held (the CI gate)",
    )
    args = ap.parse_args(argv)

    trace = json.loads(Path(args.trace).read_text())
    serving = serving_section(args, trace)
    degrade = degrade_section(args, trace)
    checkpoint = checkpoint_section(args, trace)

    gate = {
        "zero_lost": serving["zero_lost"],
        "serving_bit_identical": serving["bit_identical"],
        "serving_chaos_exercised": serving["lane_faults"] > 0
        and serving["faults_fired"] >= 3,
        "degraded_bit_identical": degrade["bit_identical"]
        and degrade["degradations"] == 1,
        "resumed_from_checkpoint": checkpoint["killed"]
        and checkpoint["resumed_from_checkpoint"],
        "elastic_bit_identical": checkpoint["bit_identical"],
        "recovery_overhead_bounded": checkpoint["overhead_bounded"],
    }
    report = {
        "trace": Path(args.trace).name,
        "config": {
            "scale": args.scale,
            "workers": args.workers,
            "delta": args.delta,
            "batch_size": args.batch_size,
            "queue_capacity": args.queue_capacity,
            "ckpt_scale": args.ckpt_scale,
            "ckpt_workers": args.ckpt_workers,
            "checkpoint_every": args.every,
        },
        "serving": serving,
        "degrade": degrade,
        "checkpoint": checkpoint,
        "gate": gate,
    }
    write_json_atomic(args.out, report)
    print(f"wrote {args.out}  gate={gate}")
    if args.assert_gate and not all(gate.values()):
        raise SystemExit(f"chaos gate failed: {gate}")
    return report


if __name__ == "__main__":
    main()
