"""Matrix-frontier smoke: the (n, F) engine vs the vector engine, gated.

Three deterministic claims, pinned to the committed baseline by the CI
regression guard:

* **F=1 parity** — a ``(n, 1)`` PageRank solve is bit-identical to the
  ``(n,)`` vector solve (values, rounds, flushes) on every backend;
* **RWR scaling** — an F-column random-walk-with-restart embedding solve
  converges and publishes exactly F× the flush bytes of its F=1 run per
  round (features ride the same commits, no extra flushes);
* **label propagation** — the F-class matrix solve converges under sync /
  async / delayed disciplines, anchors keep their labels, and the hard
  labels agree across disciplines.

Wall-clock fields are suffixed ``_s`` so the guard skips them by name.

    PYTHONPATH=src python -m benchmarks.matrix_frontier [--scale 12]
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import numpy as np

from benchmarks.common import write_json_atomic
from repro.graphs.generators import make_graph
from repro.solve import (
    Solver,
    default_landmarks,
    label_propagation_problem,
    pagerank_problem,
    rwr_embedding_problem,
)

RESULTS = Path(__file__).resolve().parents[1] / "results"

BACKENDS = ("host", "jit", "pallas", "sharded")


def f1_parity(graph, n_workers: int, delta: int) -> list[dict]:
    """(n, 1) vs (n,) PageRank on every backend: bits, rounds, flushes."""
    prob = pagerank_problem()
    rows = []
    for backend in BACKENDS:
        s = Solver(graph, prob, n_workers=n_workers, delta=delta, backend=backend)
        t0 = time.perf_counter()
        r_vec = s.solve()
        r_mat = s.solve(np.asarray(prob.x0(graph)).reshape(-1, 1))
        rows.append(
            {
                "backend": backend,
                "bit_identical": bool(
                    np.array_equal(np.asarray(r_mat.x)[:, 0], np.asarray(r_vec.x))
                ),
                "rounds_equal": r_mat.rounds == r_vec.rounds,
                "flushes_equal": r_mat.flushes == r_vec.flushes,
                "rounds": int(r_vec.rounds),
                "solve_pair_s": time.perf_counter() - t0,
            }
        )
    return rows


def rwr_scaling(graph, n_workers: int, delta: int, F: int) -> dict:
    """F restart columns in one matrix solve: converges, flush bytes ×F."""
    t0 = time.perf_counter()
    p1 = rwr_embedding_problem(feature_dim=1)
    pF = rwr_embedding_problem(feature_dim=F)
    r1 = Solver(graph, p1, n_workers=n_workers, delta=delta, backend="jit").solve()
    rF = Solver(graph, pF, n_workers=n_workers, delta=delta, backend="jit").solve()
    per_round_1 = r1.flush_bytes / r1.rounds
    per_round_f = rF.flush_bytes / rF.rounds
    return {
        "feature_dim": F,
        "converged": bool(rF.converged),
        "rounds": int(rF.rounds),
        "flush_bytes_per_round_ratio": per_round_f / per_round_1,
        "total_s": time.perf_counter() - t0,
    }


def labelprop_disciplines(graph, n_workers: int, F: int) -> dict:
    """F-class label propagation under the paper's three disciplines."""
    prob = label_propagation_problem(feature_dim=F)
    anchors = default_landmarks(graph.n, F)
    t0 = time.perf_counter()
    hard, rows = [], []
    for label, delta in (("sync", "sync"), ("async", "async"), ("delayed", 64)):
        r = Solver(
            graph, prob, n_workers=n_workers, delta=delta, backend="jit"
        ).solve()
        lab = np.asarray(r.x)
        hard.append(np.argmax(lab, axis=1))
        rows.append(
            {
                "discipline": label,
                "delta": int(r.delta),
                "rounds": int(r.rounds),
                "converged": bool(r.converged),
                "anchors_kept": bool(
                    np.array_equal(np.argmax(lab[anchors], axis=1), np.arange(F))
                ),
            }
        )
    agree = float(np.mean([(h == hard[0]).mean() for h in hard[1:]]))
    return {
        "feature_dim": F,
        "disciplines": rows,
        "hard_label_agreement": agree,
        "all_converged": all(row["converged"] for row in rows),
        "total_s": time.perf_counter() - t0,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=12, help="log2 vertices")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--delta", type=int, default=64)
    ap.add_argument("--feature-dim", type=int, default=4)
    args = ap.parse_args(argv)

    g_pr = make_graph("twitter", scale=args.scale, efactor=8, kind="pagerank")
    g_web = make_graph("web", scale=args.scale, efactor=8, kind="pagerank")

    parity = f1_parity(g_pr, args.workers, args.delta)
    for row in parity:
        print(
            f"f1-parity {row['backend']:8s} bit={row['bit_identical']} "
            f"rounds={row['rounds']} ({row['solve_pair_s']:.2f} s)"
        )
    rwr = rwr_scaling(g_pr, args.workers, args.delta, args.feature_dim)
    print(
        f"rwr F={rwr['feature_dim']}: converged={rwr['converged']} "
        f"rounds={rwr['rounds']} flush ratio={rwr['flush_bytes_per_round_ratio']:.1f}"
    )
    lp = labelprop_disciplines(g_web, args.workers, args.feature_dim)
    print(
        f"labelprop F={lp['feature_dim']}: all converged={lp['all_converged']} "
        f"hard-label agreement={lp['hard_label_agreement']:.3f}"
    )

    report = {
        "scale": args.scale,
        "f1_parity": parity,
        "f1_all_bit_identical": all(r["bit_identical"] for r in parity),
        "rwr": rwr,
        "labelprop": lp,
    }
    write_json_atomic(RESULTS / "matrix_frontier.json", report)
    return report


if __name__ == "__main__":
    main()
