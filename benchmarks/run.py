# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark orchestrator.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--skip-roofline]

Sections (one per paper artifact, DESIGN.md §10):
  table2  graph statistics              (paper Table II)
  table1  rounds + avg round time       (paper Table I)
  fig2    PR speedup vs sync, δ sweep   (paper Fig 2)
  fig34   δ* vs worker count            (paper Figs 3/4)
  fig5    access-matrix locality        (paper Fig 5)
  fig6    SSSP speedup vs sync          (paper Fig 6)
  delta_model  analytic δ-selector validation (beyond paper)
  roofline     dry-run roofline table   (assignment §Roofline; needs
               results/dryrun — run repro.launch.dryrun first)
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small graph set")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    t0 = time.time()

    from benchmarks import (
        delta_model_validation,
        fig2_pr_speedup,
        fig5_access_matrix,
        fig6_sssp_speedup,
        fig34_scaling,
        table1_rounds,
        table2_graphs,
    )

    table2_graphs.run()
    table1_rounds.run()
    fig5_access_matrix.run()
    fig2_pr_speedup.run()
    fig34_scaling.run(Ps=(4, 8, 16) if args.quick else (4, 8, 16, 32))
    fig6_sssp_speedup.run()
    delta_model_validation.run()

    if not args.skip_roofline:
        try:
            from benchmarks import roofline

            rows = roofline.main(["--mesh", "single"])
            for r in rows:
                print(
                    f"roofline/{r['arch']}/{r['shape']},0.0,"
                    f"dom={r['dominant']};frac={r['roofline_frac']:.3f}"
                )
        except Exception as e:  # dry-run results absent
            print(f"roofline/skipped,0.0,{type(e).__name__}", file=sys.stderr)

    print(f"# total bench time {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
