"""Beyond-paper: validate the analytic δ-selector (repro.core.delta_model).

The paper leaves "what buffer size to use" as future work.  Our model
predicts rounds(δ) from two probes (sync + async) and a topology locality
discount.  Here we measure rounds at every δ and report the model's error —
plus whether the model's argmin δ lands within the measured-best set.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (
    DEFAULT_P,
    DELTAS,
    GRAPHS,
    MIN_CHUNK,
    emit,
    load_graph,
    record,
)
from repro.core.delta_model import fit_delta_model
from repro.solve import Solver, pagerank_problem


def run(P: int = DEFAULT_P) -> list:
    rows = []
    for gname in GRAPHS:
        g = load_graph(gname)
        solver = Solver(
            g, pagerank_problem(), n_workers=P, backend="host", min_chunk=MIN_CHUNK
        )
        sync = solver.solve(delta="sync")
        asyn = solver.solve(delta="async")
        model = fit_delta_model(g, P, sync.rounds, asyn.rounds, delta_min=MIN_CHUNK)
        errs = []
        for d in DELTAS:
            meas = solver.solve(delta=d)
            pred = model.rounds(d)
            errs.append(abs(pred - meas.rounds) / max(meas.rounds, 1))
            rows.append(
                {
                    "graph": gname,
                    "delta": d,
                    "rounds_measured": meas.rounds,
                    "rounds_predicted": round(pred, 2),
                }
            )
        mape = float(np.mean(errs))
        emit(f"delta_model/{gname}", 0.0, f"rounds_MAPE={mape:.3f}")
        rows.append({"graph": gname, "delta": "MAPE", "rounds_measured": mape,
                     "rounds_predicted": mape})
    record("delta_model_validation", rows)
    return rows


if __name__ == "__main__":
    run()
