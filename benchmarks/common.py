"""Shared benchmark helpers: standard graph set + timing."""

from __future__ import annotations

import json
import time
from pathlib import Path

from repro.graphs.generators import make_graph

RESULTS = Path(__file__).resolve().parents[1] / "results" / "bench"

# synthetic analogues of the 5 GAP graphs (paper Table II), laptop scale
GRAPHS = ["kron", "urand", "road", "twitter", "web"]
SCALE = 13
EFACTOR = 8
DEFAULT_P = 16
DELTAS = [64, 256, 1024, 4096]
MIN_CHUNK = 16  # "async" commit granularity (finest vectorizable chunk)


def load_graph(name: str, kind: str = "pagerank"):
    scale = SCALE
    return make_graph(name, scale=scale, efactor=EFACTOR, kind=kind)


def record(table: str, rows: list):
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{table}.json").write_text(json.dumps(rows, indent=1))


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
