"""Shared benchmark helpers: standard graph set + timing + atomic results."""

from __future__ import annotations

import json
import os
from pathlib import Path

from repro.graphs.generators import make_graph

RESULTS = Path(__file__).resolve().parents[1] / "results" / "bench"

# synthetic analogues of the 5 GAP graphs (paper Table II), laptop scale
GRAPHS = ["kron", "urand", "road", "twitter", "web"]
SCALE = 13
EFACTOR = 8
DEFAULT_P = 16
DELTAS = [64, 256, 1024, 4096]
MIN_CHUNK = 16  # "async" commit granularity (finest vectorizable chunk)


def load_graph(name: str, kind: str = "pagerank"):
    scale = SCALE
    return make_graph(name, scale=scale, efactor=EFACTOR, kind=kind)


def write_json_atomic(path, obj) -> Path:
    """Write ``obj`` as JSON via tmp file + atomic rename.

    Creates parent directories as needed.  The rename means a mid-write kill
    (CI timeout, OOM) can never leave a truncated baseline for
    ``benchmarks/check_regression.py`` to trip on — the previous file stays
    intact until the new one is fully on disk.
    """
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(f"{path.name}.tmp-{os.getpid()}")
    tmp.write_text(json.dumps(obj, indent=1))
    os.replace(tmp, path)
    return path


def record(table: str, rows: list):
    write_json_atomic(RESULTS / f"{table}.json", rows)


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
