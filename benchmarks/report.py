"""Generate the data-driven sections of EXPERIMENTS.md from results/.

    PYTHONPATH=src python -m benchmarks.report > results/report_sections.md
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.roofline import analyze, improvement_hint
from repro.configs import ALIASES

ROOT = Path(__file__).resolve().parents[1]
DRY = ROOT / "results" / "dryrun"
BENCH = ROOT / "results" / "bench"


def dryrun_table() -> str:
    out = [
        "| arch | shape | mesh | compile s | GiB/dev | HLO flops (reported) | collective GiB | AG/AR/RS/A2A/CP |",
        "|------|-------|------|-----------|---------|----------------------|----------------|-----------------|",
    ]
    for f in sorted(DRY.glob("*.json")):
        if f.name.startswith("FAILED"):
            continue
        r = json.loads(f.read_text())
        pk = r["collectives"]["per_kind"]
        ops = "/".join(
            str(pk[k]["count"])
            for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                      "collective-permute")
        )
        out.append(
            f"| {ALIASES[r['arch']]} | {r['shape']} | {r['mesh']} | {r['compile_s']} | "
            f"{r['bytes_per_device']/2**30:.2f} | {r['cost'].get('flops', 0):.3g} | "
            f"{r['collectives']['total_bytes']/2**30:.2f} | {ops} |"
        )
    return "\n".join(out)


def roofline_table(mesh: str) -> str:
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant | useful ratio | roofline frac | what would move the dominant term |",
        "|------|-------|-----------|----------|--------------|----------|--------------|---------------|-----------------------------------|",
    ]
    rows = []
    for f in sorted(DRY.glob("*.json")):
        if f.name.startswith("FAILED"):
            continue
        r = json.loads(f.read_text())
        if r["mesh"] != mesh:
            continue
        rows.append(analyze(r))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | {r['memory_s']:.2e} | "
            f"{r['collective_s']:.2e} | {r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.2f} | {improvement_hint(r)} |"
        )
    return "\n".join(out)


def bench_table(name: str, cols: list) -> str:
    rows = json.loads((BENCH / f"{name}.json").read_text())
    out = ["| " + " | ".join(cols) + " |", "|" + "---|" * len(cols)]
    for r in rows:
        out.append("| " + " | ".join(str(r.get(c, "")) for c in cols) + " |")
    return "\n".join(out)


def main():
    print("## §Dry-run (auto-generated)\n")
    print(dryrun_table())
    print("\n## §Roofline single-pod (auto-generated)\n")
    print(roofline_table("single"))
    print("\n## §Roofline multi-pod (auto-generated)\n")
    print(roofline_table("multi"))
    for name, cols in [
        (
            "table2_graphs",
            ["name", "vertices", "edges", "avg_in_degree", "locality_fraction"],
        ),
        (
            "table1_rounds",
            ["graph", "mode", "rounds", "avg_round_time_s", "flushes", "flush_bytes"],
        ),
        (
            "fig2_pr_speedup",
            [
                "graph",
                "mode",
                "rounds",
                "wall_speedup_vs_sync",
                "modeled_speedup_vs_sync",
            ],
        ),
        (
            "fig34_scaling",
            [
                "graph",
                "P",
                "rounds_sync",
                "rounds_async",
                "best_delta_modeled",
                "locality",
            ],
        ),
        ("fig5_access_matrix", ["graph", "locality_fraction", "workers_self_dominant"]),
        (
            "fig6_sssp_speedup",
            [
                "graph",
                "mode",
                "rounds",
                "wall_speedup_vs_sync",
                "modeled_speedup_vs_sync",
            ],
        ),
        (
            "delta_model_validation",
            ["graph", "delta", "rounds_measured", "rounds_predicted"],
        ),
    ]:
        print(f"\n## {name} (auto-generated)\n")
        try:
            print(bench_table(name, cols))
        except FileNotFoundError:
            print("(missing — run benchmarks first)")


if __name__ == "__main__":
    main()
