"""Paper Table II: statistics of the (synthetic) GAP-analogue graphs."""

from __future__ import annotations

import numpy as np

from benchmarks.common import DEFAULT_P, GRAPHS, emit, load_graph, record
from repro.core.access_matrix import access_matrix, locality_fraction
from repro.graphs.partition import balanced_blocks


def run() -> list:
    rows = []
    for gname in GRAPHS:
        g = load_graph(gname)
        bounds = balanced_blocks(g, DEFAULT_P)
        loc = locality_fraction(access_matrix(g, bounds))
        s = g.stats()
        s["locality_fraction"] = round(loc, 4)
        s["block_sizes_minmax"] = [
            int(np.diff(bounds).min()),
            int(np.diff(bounds).max()),
        ]
        rows.append(s)
        emit(
            f"table2/{gname}",
            0.0,
            f"V={s['vertices']};E={s['edges']};loc={s['locality_fraction']}",
        )
    record("table2_graphs", rows)
    return rows


if __name__ == "__main__":
    run()
