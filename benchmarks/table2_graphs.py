"""Paper Table II: statistics of the (synthetic) GAP-analogue graphs.

Extended with the :class:`repro.graphs.partition.Partition` distribution
stats of the default balanced partition — edge cut, halo sizes, replication
factor — so "how partitionable is this graph" is a recorded number next to
the paper's vertex/edge counts.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import DEFAULT_P, GRAPHS, emit, load_graph, record
from repro.core.access_matrix import access_matrix, locality_fraction
from repro.graphs.partition import Partition, balanced_blocks


def run() -> list:
    rows = []
    for gname in GRAPHS:
        g = load_graph(gname)
        bounds = balanced_blocks(g, DEFAULT_P)
        part = Partition.from_bounds(g, bounds)
        loc = locality_fraction(access_matrix(g, part))
        s = g.stats()
        s["locality_fraction"] = round(loc, 4)
        s["block_sizes_minmax"] = [
            int(np.diff(bounds).min()),
            int(np.diff(bounds).max()),
        ]
        s.update(part.stats())
        rows.append(s)
        emit(
            f"table2/{gname}",
            0.0,
            f"V={s['vertices']};E={s['edges']};loc={s['locality_fraction']};"
            f"cut={s['cut_fraction']};halo={s['halo_total']}",
        )
    record("table2_graphs", rows)
    return rows


if __name__ == "__main__":
    run()
