"""§Perf cell 3: delayed gradient commit on the multi-pod mesh (granite-8b).

The paper's technique at training scale: pods buffer δ local optimizer steps
before committing the averaged parameter delta over DCN.  We lower the
*local* phase and the *commit* phase separately on the (2,16,16) mesh and
count collective bytes in each HLO, then report the amortised per-step
collective cost

    bytes(δ) = local_bytes + commit_bytes / δ

for δ ∈ {1, 2, 4, 8}, with f32 vs int8 wire compression, against the plain
synchronous-DP baseline (grads all-reduced over the pod axis every step).

Run (needs ~3 compiles at 512 host devices)::

    PYTHONPATH=src python -m benchmarks.delayed_commit_dryrun

With fewer than 512 devices (CI runs 8 fake ones) the sweep drops to smoke
mode automatically: reduced config, small shape, a (2, D/4, 2) mesh — same
HLO structure, CPU-sized compiles.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
from functools import partial
from pathlib import Path

import jax
from jax.sharding import PartitionSpec as P

from benchmarks.common import write_json_atomic

from repro.configs import get_config, get_reduced
from repro.configs.shapes import SHAPES, ShapeSpec
from repro.dist.compat import make_mesh, set_mesh
from repro.dist.delayed_commit import (
    DelayedCommitConfig,
    DelayedCommitState,
    init_delayed_state,
    make_delayed_commit_step,
    pod_prefix_specs,
)
from repro.dist.sharding import tree_param_specs, use_rules
from repro.launch.dryrun import collective_stats, named, rules_for
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_specs
from repro.train.optimizer import AdamW, constant

RESULTS = Path(__file__).resolve().parents[1] / "results"


def smoke_cell():
    """(cfg, shape, mesh) for hosts too small for the production mesh."""
    n_dev = len(jax.devices())
    assert n_dev >= 4 and n_dev % 4 == 0, f"smoke mesh needs 4k devices, got {n_dev}"
    mesh = make_mesh((2, n_dev // 4, 2), ("pod", "data", "model"))
    return get_reduced("granite-8b"), ShapeSpec("train_smoke", "train", 128, 8), mesh


def lower_phase(phase: str, compress: str, smoke: bool):
    if smoke:
        cfg, shape, mesh = smoke_cell()
    else:
        cfg = get_config("granite-8b")
        shape = SHAPES["train_4k"]
        mesh = make_production_mesh(multi_pod=True)
    rules = rules_for(cfg, mesh, "train")
    cc = DelayedCommitConfig(n_pods=2, delta=4, compress=compress)
    opt = AdamW(schedule=constant(3e-4))
    key = jax.random.PRNGKey(0)

    specs, shards = batch_specs(cfg, shape, with_labels=True)
    # batch gains a leading pod axis
    pod_specs = {
        k: jax.ShapeDtypeStruct((2, v.shape[0] // 2) + v.shape[1:], v.dtype)
        for k, v in specs.items()
    }
    # drop "pod" from the inner batch axis mapping
    pod_shards = {}
    for k, s in shards.items():
        inner = tuple(
            tuple(a for a in ax if a != "pod") if isinstance(ax, tuple) else ax
            for ax in s
        )
        pod_shards[k] = P("pod", *inner)

    with use_rules(rules), set_mesh(mesh):
        state_sds = jax.eval_shape(partial(init_delayed_state, cfg, opt, cc), key)
        pspecs = tree_param_specs(state_sds.global_params, rules, mesh)
        podspecs = pod_prefix_specs(pspecs)
        state_spec = DelayedCommitState(
            global_params=pspecs,
            local_delta=podspecs,
            opt_state={"m": podspecs, "v": podspecs, "step": P()},
            step=P(),
        )
        state_sh = named(mesh, state_spec)
        batch_sh = named(mesh, pod_shards, pod_specs)
        step = make_delayed_commit_step(cfg, opt, cc, phase=phase, param_specs=pspecs)
        jitted = jax.jit(
            step, in_shardings=(state_sh, batch_sh), donate_argnums=(0,)
        )
        compiled = jitted.lower(state_sds, pod_specs).compile()
    mem = compiled.memory_analysis()
    coll = collective_stats(compiled.as_text())
    return {
        "phase": phase,
        "compress": compress,
        "collective_bytes": coll["total_bytes"],
        "per_kind": coll["per_kind"],
        "bytes_per_device": int(mem.argument_size_in_bytes + mem.temp_size_in_bytes),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + small mesh (auto when <512 devices)")
    args = ap.parse_args(argv)
    smoke = args.smoke or len(jax.devices()) < 512

    rows = {}
    for phase, compress in [("local", "none"), ("commit", "none"), ("commit", "int8")]:
        r = lower_phase(phase, compress, smoke)
        rows[f"{phase}_{compress}"] = r
        print(
            f"{phase:7s} {compress:5s}: coll={r['collective_bytes']/2**30:.2f} GiB "
            f"bytes/dev={r['bytes_per_device']/2**30:.2f} GiB"
        )
    local = rows["local_none"]["collective_bytes"]
    commit = rows["commit_none"]["collective_bytes"] - local
    commit_i8 = rows["commit_int8"]["collective_bytes"] - local
    # The int8 row counts the collectives the HLO actually runs, so since the
    # pod reduction moved into the integer domain this is true wire cost —
    # s8 elements on the DCN all-reduce — not f32 plus extra quant ops.
    i8_frac = commit_i8 / commit if commit else float("nan")
    print(f"\nint8 commit wire = {i8_frac:.3f}× f32 commit wire")
    print("\nAmortised per-step collective bytes (GiB) vs δ:")
    print(f"{'δ':>4s} {'f32 commit':>12s} {'int8 commit':>12s}")
    table = []
    for d in (1, 2, 4, 8):
        f32b = local + commit / d
        i8b = local + commit_i8 / d
        table.append({"delta": d, "f32_gib": f32b / 2**30, "int8_gib": i8b / 2**30})
        print(f"{d:4d} {f32b/2**30:12.2f} {i8b/2**30:12.2f}")
    out = {
        "smoke": smoke,
        "phases": rows,
        "amortised": table,
        "int8_commit_wire_frac_of_f32": i8_frac,
        "int8_commit_wire_below_f32": bool(commit_i8 < commit),
    }
    write_json_atomic(RESULTS / "delayed_commit_dryrun.json", out)
    return out


if __name__ == "__main__":
    main()
