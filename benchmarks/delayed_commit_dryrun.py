"""§Perf cell 3: delayed gradient commit on the multi-pod mesh (granite-8b).

The paper's technique at training scale: pods buffer δ local optimizer steps
before committing the averaged parameter delta over DCN.  We lower the
*local* phase and the *commit* phase separately on the (2,16,16) mesh and
count collective bytes in each HLO, then report the amortised per-step
collective cost

    bytes(δ) = local_bytes + commit_bytes / δ

for δ ∈ {1, 2, 4, 8}, with f32 vs int8 wire compression, against the plain
synchronous-DP baseline (grads all-reduced over the pod axis every step).

Run (needs ~3 compiles at 512 host devices)::

    PYTHONPATH=src python -m benchmarks.delayed_commit_dryrun
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import json
from functools import partial
from pathlib import Path

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.dist.delayed_commit import (
    DelayedCommitConfig,
    DelayedCommitState,
    init_delayed_state,
    make_delayed_commit_step,
    pod_prefix_specs,
)
from repro.dist.sharding import tree_param_specs, use_rules
from repro.launch.dryrun import collective_stats, named, rules_for
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_specs
from repro.train.optimizer import AdamW, constant

RESULTS = Path(__file__).resolve().parents[1] / "results"
ICI_BW = 50e9


def lower_phase(phase: str, compress: str):
    cfg = get_config("granite-8b")
    shape = SHAPES["train_4k"]
    mesh = make_production_mesh(multi_pod=True)
    rules = rules_for(cfg, mesh, "train")
    cc = DelayedCommitConfig(n_pods=2, delta=4, compress=compress)
    opt = AdamW(schedule=constant(3e-4))
    key = jax.random.PRNGKey(0)

    specs, shards = batch_specs(cfg, shape, with_labels=True)
    # batch gains a leading pod axis
    pod_specs = {
        k: jax.ShapeDtypeStruct((2, v.shape[0] // 2) + v.shape[1:], v.dtype)
        for k, v in specs.items()
    }
    pod_shards = {k: P(*(("pod",) + tuple(s))) for k, s in shards.items()}
    # drop "pod" from the inner batch axis mapping
    fixed = {}
    for k, s in shards.items():
        inner = tuple(
            tuple(a for a in ax if a != "pod") if isinstance(ax, tuple) else ax
            for ax in s
        )
        fixed[k] = P("pod", *inner)
    pod_shards = fixed

    with use_rules(rules), jax.set_mesh(mesh):
        state_sds = jax.eval_shape(partial(init_delayed_state, cfg, opt, cc), key)
        pspecs = tree_param_specs(state_sds.global_params, rules, mesh)
        podspecs = pod_prefix_specs(pspecs)
        state_spec = DelayedCommitState(
            global_params=pspecs,
            local_delta=podspecs,
            opt_state={"m": podspecs, "v": podspecs, "step": P()},
            step=P(),
        )
        state_sh = named(mesh, state_spec)
        batch_sh = named(mesh, pod_shards, pod_specs)
        step = make_delayed_commit_step(cfg, opt, cc, phase=phase, param_specs=pspecs)
        jitted = jax.jit(
            step, in_shardings=(state_sh, batch_sh), donate_argnums=(0,)
        )
        compiled = jitted.lower(state_sds, pod_specs).compile()
    mem = compiled.memory_analysis()
    coll = collective_stats(compiled.as_text())
    return {
        "phase": phase,
        "compress": compress,
        "collective_bytes": coll["total_bytes"],
        "per_kind": coll["per_kind"],
        "bytes_per_device": int(mem.argument_size_in_bytes + mem.temp_size_in_bytes),
    }


def main():
    rows = {}
    for phase, compress in [("local", "none"), ("commit", "none"), ("commit", "int8")]:
        r = lower_phase(phase, compress)
        rows[f"{phase}_{compress}"] = r
        print(
            f"{phase:7s} {compress:5s}: coll={r['collective_bytes']/2**30:.2f} GiB "
            f"bytes/dev={r['bytes_per_device']/2**30:.2f} GiB"
        )
    local = rows["local_none"]["collective_bytes"]
    commit = rows["commit_none"]["collective_bytes"] - local
    commit_i8 = rows["commit_int8"]["collective_bytes"] - local
    print("\nAmortised per-step collective bytes (GiB) vs δ:")
    print(f"{'δ':>4s} {'f32 commit':>12s} {'int8 commit':>12s}")
    table = []
    for d in (1, 2, 4, 8):
        f32b = local + commit / d
        i8b = local + commit_i8 / d
        table.append({"delta": d, "f32_gib": f32b / 2**30, "int8_gib": i8b / 2**30})
        print(f"{d:4d} {f32b/2**30:12.2f} {i8b/2**30:12.2f}")
    out = {"phases": rows, "amortised": table}
    (RESULTS / "delayed_commit_dryrun.json").write_text(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
