"""Dry-run: replicated vs frontier-sharded commit wire across device counts.

Lowers one delayed-async PageRank round on a 2-block *clustered* graph (two
communities, sparse cross edges — the Fig-5 "diagonal" regime) for both
distribution disciplines at every power-of-two mesh width the host exposes,
and counts the per-round commit wire:

* replicated frontier — each commit all-gathers every worker's chunk:
  ``S · P · δ`` elements per round regardless of topology;
* sharded frontier + halo exchange — each commit ships only boundary rows:
  ``S · D · H`` elements per round, collapsing with the edge cut.

Device-count adaptive like ``engine_dryrun``: 8-wide on the CI smoke mesh,
wider wherever more devices exist.

    PYTHONPATH=src python -m benchmarks.sharded_scaling [--scale 14]
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_json_atomic

from repro.core.engine import make_schedule
from repro.core.semiring import PLUS_TIMES
from repro.dist.compat import cost_analysis, make_mesh
from repro.dist.engine_sharded import (
    frontier_ef_init,
    frontier_pallas_round_fn,
    frontier_sharded_round_fn,
    input_specs_for_engine,
    make_frontier_plan,
    sharded_round_fn,
)
from repro.graphs.formats import CSRGraph
from repro.graphs.generators import pagerank_values
from repro.kernels.round_block import fused_halo_step_fn
from repro.launch.dryrun import collective_stats

RESULTS = Path(__file__).resolve().parents[1] / "results"
P = 32  # schedule workers (a multiple of every mesh width we run on)


def clustered_graph(
    scale: int, blocks: int = 2, efactor: int = 8, cross: float = 0.02, seed: int = 0
):
    """``blocks`` equal contiguous communities; ``cross`` fraction of edges
    lands in a random *other* community (the Fig-5 diagonal regime)."""
    n = 2**scale
    m = n * efactor
    rng = np.random.default_rng(seed)
    size = n // blocks
    block = rng.integers(0, blocks, m)
    src = rng.integers(0, size, m) + block * size
    dst = rng.integers(0, size, m) + block * size
    flip = rng.random(m) < cross
    shift = rng.integers(1, blocks, m) if blocks > 1 else np.zeros(m, np.int64)
    dst = np.where(flip, (dst + shift * size) % n, dst)
    vals = pagerank_values(n, src, 0.85)
    return CSRGraph.from_edges(n, src, dst, vals, name=f"cluster{blocks}-s{scale}")


def fused_halo_step_gate(sched, plan, row_update_q) -> dict:
    """Per-shard, per-round HBM bytes: fused Pallas halo step vs XLA's.

    Same two accountings as ``engine_dryrun.fused_vs_xla_round_bytes``:
    the fused kernel's traffic is its HBM *contract* — arguments + outputs
    of the compiled call, everything between (gather temps, ⊗ products,
    segment-sum partials) stays in VMEM — while the XLA commit step is
    priced by its own ``cost_analysis``, which includes exactly those
    intermediate round-trips.  Both are one commit step; ``× S`` per round.
    """
    delta, S = sched.delta, sched.S
    P_loc, M, L, H = plan.P_loc, sched.M, plan.L, plan.H
    avals = (
        jax.ShapeDtypeStruct((L,), jnp.float32),
        jax.ShapeDtypeStruct((P_loc, M), jnp.int32),
        jax.ShapeDtypeStruct((P_loc, M), jnp.float32),
        jax.ShapeDtypeStruct((P_loc, M), jnp.int32),
        jax.ShapeDtypeStruct((P_loc, delta), jnp.int32),
        jax.ShapeDtypeStruct((P_loc, delta), jnp.int32),
        jax.ShapeDtypeStruct((H,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    step = fused_halo_step_fn(
        PLUS_TIMES, row_update_q, P_loc=P_loc, M=M, delta=delta, L=L, H=H
    )
    mem = jax.jit(step).lower(*avals).compile().memory_analysis()
    pallas_step = float(mem.argument_size_in_bytes + mem.output_size_in_bytes)

    def xla_step(x, src_s, val_s, dst_s, rg_s, rl_s, snd_s, q):
        # one commit step of frontier_sharded_round_fn's body, collectives
        # excluded on both sides (the wire is gated separately below)
        contrib = PLUS_TIMES.mul(x[src_s], val_s)
        seg = dst_s + (jnp.arange(P_loc, dtype=jnp.int32) * (delta + 1))[:, None]
        reduced = PLUS_TIMES.segment_reduce(
            contrib.reshape(-1), seg.reshape(-1), P_loc * (delta + 1)
        ).reshape(P_loc, delta + 1)[:, :delta]
        new = row_update_q(x[rl_s], reduced, rg_s, q)
        newv = new.reshape(-1).astype(x.dtype)
        x = x.at[rl_s.reshape(-1)].set(newv, mode="drop", unique_indices=False)
        return x, newv[snd_s]

    xla_c = jax.jit(xla_step).lower(*avals).compile()
    xla_step_b = float(cost_analysis(xla_c).get("bytes accessed", 0.0))
    return {
        "pallas_halo_step_bytes": pallas_step,
        "pallas_halo_round_bytes": S * pallas_step,
        "xla_halo_step_bytes": xla_step_b,
        "xla_halo_round_bytes": S * xla_step_b,
        "fused_halo_hbm_below_xla": bool(
            xla_step_b > 0 and S * pallas_step < S * xla_step_b
        ),
    }


def quantized_wire_gate(sched, plan, mesh, row_update_q, x_loc) -> dict:
    """Halo wire bytes of the fused pallas round at f32 vs int8.

    Counted from the lowered HLO's collectives, so the int8 number is true
    wire cost — s8 boundary rows plus one f32 scale per (shard, commit) —
    not f32 plus bookkeeping.  Per commit the ratio is ``(H + 4) / 4H``,
    i.e. → 1/4 as the boundary grows; the committed gate is ≤ 0.3.
    """
    ef0 = frontier_ef_init(plan)
    tail = (
        plan.src_loc,
        sched.val,
        sched.dst_local,
        sched.rows,
        plan.rows_loc,
        plan.send_idx,
        plan.recv_idx,
        jnp.zeros((), jnp.int32),
    )
    wire = {}
    for dt in ("f32", "int8"):
        rnd = frontier_pallas_round_fn(
            sched, plan, PLUS_TIMES, row_update_q, mesh, axis="data", halo_dtype=dt
        )
        compiled = jax.jit(rnd).lower(x_loc, ef0, *tail).compile()
        wire[dt] = collective_stats(compiled.as_text())["total_bytes"]
    frac = wire["int8"] / wire["f32"] if wire["f32"] else float("nan")
    return {
        "halo_wire_f32_hlo_bytes": wire["f32"],
        "halo_wire_int8_hlo_bytes": wire["int8"],
        "int8_halo_wire_frac_of_f32": frac,
        "int8_halo_wire_le_030": bool(wire["f32"] > 0 and frac <= 0.3),
    }


def _timed_round(compiled, args, repeats: int = 3) -> float:
    out = compiled(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = compiled(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=14, help="log2 vertices")
    ap.add_argument("--delta", type=int, default=128)
    ap.add_argument("--cross", type=float, default=0.02)
    ap.add_argument(
        "--blocks",
        type=int,
        default=None,
        help="communities in the clustered graph (default: widest mesh run)",
    )
    ap.add_argument("--timed", action="store_true", help="also time the rounds")
    args = ap.parse_args(argv)

    n_dev = len(jax.devices())
    widths, w = [], 1
    while w <= min(P, n_dev):
        widths.append(w)
        w *= 2
    blocks = args.blocks or max(2, widths[-1])

    g = clustered_graph(args.scale, blocks=blocks, cross=args.cross)
    sched = make_schedule(g, P, args.delta, PLUS_TIMES)
    tele = np.float32(0.15 / g.n)
    row_update = lambda o, r, w: tele + r
    row_update_q = lambda o, r, w, q: tele + r
    x_ext = jnp.concatenate(
        [jnp.full((g.n,), 1.0 / g.n, jnp.float32), jnp.zeros((1,), jnp.float32)]
    )

    rows = []
    for width in widths:
        mesh = make_mesh((width,), ("data",), devices=jax.devices()[:width])

        rep = sharded_round_fn(sched, PLUS_TIMES, row_update, mesh, axis="data")
        rep_c = jax.jit(rep).lower(*input_specs_for_engine(sched, PLUS_TIMES)).compile()
        rep_coll = collective_stats(rep_c.as_text())

        plan = make_frontier_plan(sched, width)
        halo = frontier_sharded_round_fn(
            sched, plan, PLUS_TIMES, row_update_q, mesh, axis="data"
        )
        halo_args = (
            plan.scatter_x(x_ext),
            plan.src_loc,
            sched.val,
            sched.dst_local,
            sched.rows,
            plan.rows_loc,
            plan.send_idx,
            plan.recv_idx,
            jnp.zeros((), jnp.int32),
        )
        halo_c = jax.jit(halo).lower(*halo_args).compile()
        halo_coll = collective_stats(halo_c.as_text())

        row = {
            "devices": width,
            "delta": sched.delta,
            "commits_per_round": sched.S,
            "replicated_analytic_bytes": plan.replicated_bytes_per_round(4),
            "halo_analytic_bytes": plan.halo_bytes_per_round(4),
            "halo_boundary_rows": plan.boundary_entries_per_round,
            "halo_H": plan.H,
            "halo_L": plan.L,
            "replicated_hlo_bytes": rep_coll["total_bytes"],
            "halo_hlo_bytes": halo_coll["total_bytes"],
        }
        row.update(fused_halo_step_gate(sched, plan, row_update_q))
        if width > 1:  # 1-wide halos are dump-only; wire ratio is meaningless
            row.update(
                quantized_wire_gate(sched, plan, mesh, row_update_q, halo_args[0])
            )
        if args.timed:
            rep_args = (x_ext, sched.src, sched.val, sched.dst_local, sched.rows)
            row["replicated_round_s"] = _timed_round(rep_c, rep_args)
            row["halo_round_s"] = _timed_round(halo_c, halo_args)
        rows.append(row)
        rep_kib = row["replicated_analytic_bytes"] / 2**10
        print(
            f"D={width:3d}  replicated: analytic={rep_kib:9.1f} KiB "
            f"hlo={row['replicated_hlo_bytes']/2**10:9.1f} KiB   "
            f"halo: analytic={row['halo_analytic_bytes']/2**10:9.1f} KiB "
            f"hlo={row['halo_hlo_bytes']/2**10:9.1f} KiB  (H={plan.H}, L={plan.L})"
        )
        line = (
            f"      fused halo step: pallas={row['pallas_halo_step_bytes']/2**10:.1f}"
            f" KiB vs xla={row['xla_halo_step_bytes']/2**10:.1f} KiB"
        )
        if "int8_halo_wire_frac_of_f32" in row:
            line += f"   int8 wire = {row['int8_halo_wire_frac_of_f32']:.3f}× f32"
        print(line)

    # Where every device owns whole clusters (width ≤ blocks), halo commits
    # must move strictly less than the replicated all-gather.  Wider meshes
    # split inside communities and are reported but not asserted.
    aligned = [r for r in rows if 1 < r["devices"] <= blocks]
    if aligned:
        worst = max(
            r["halo_analytic_bytes"] / r["replicated_analytic_bytes"] for r in aligned
        )
        print(f"halo/replicated commit-wire ratio (worst aligned width): {worst:.3f}")
        assert worst < 1.0, "halo exchange should move strictly less than replication"
    # ISSUE-8 gates, committed as regression-checked booleans: the fused
    # pallas halo step must beat the XLA step's HBM bytes wherever the cost
    # model prices it, and quantizing the boundary rows must shrink the wire
    # to ≤ 0.3× f32 at every multi-device width.
    for r in rows:
        if r["xla_halo_step_bytes"] > 0:
            assert r["fused_halo_hbm_below_xla"], r
        if "int8_halo_wire_le_030" in r:
            assert r["int8_halo_wire_le_030"], r
    write_json_atomic(RESULTS / "sharded_scaling.json", rows)
    return rows


if __name__ == "__main__":
    main()
