"""Incremental re-solve benchmark: replay a streaming edge-update trace.

Replays the committed update trace (``benchmarks/traces/incremental_smoke.json``)
through two solvers per workload sharing one mutating graph:

* **incremental** — ``Solver.resolve(updates=batch)``: apply the batch,
  repair the previous fixed point (``repro.evolve``), converge;
* **cold**        — ``Solver.apply_updates(batch)`` then a from-scratch
  ``solve()`` on the same mutated snapshot (the counterfactual).

Every event checks the incremental result against the cold one (bit-exact
for min-plus, allclose for plus-times) and records both round counts.  The
summary buckets p50/p99 rounds by batch size; the committed win condition —
median incremental rounds strictly below median cold rounds over the
*small* events (total ops ≤ ``small_frac`` of the initial edge count) —
is a boolean the regression guard enforces, and ``--assert-gate`` turns a
violation into a nonzero exit for CI.  All reported fields except the
``*_wall_s`` timings are deterministic functions of the trace.

    PYTHONPATH=src python -m benchmarks.incremental \\
        --trace benchmarks/traces/incremental_smoke.json --assert-gate

Regenerate the committed trace with ``--write-trace`` after changing scale
or batch sizes (then re-commit ``results/incremental.json``).
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import write_json_atomic
from repro.evolve import EdgeBatch
from repro.graphs.generators import make_graph
from repro.solve import Solver, pagerank_problem, sssp_problem

RESULTS = Path(__file__).resolve().parents[1] / "results"
TRACES = Path(__file__).resolve().parent / "traces" / "incremental_smoke.json"

DAMPING = 0.85


# --------------------------------------------------------------------- #
# trace generation (--write-trace)
# --------------------------------------------------------------------- #
def _edge_list(g):
    dst = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(g.indptr))
    return g.indices.astype(np.int64), dst


def _sssp_event(g, k: int, rng) -> tuple[dict, object]:
    """Mixed insert/delete/reweight batch with GAP-style integer weights."""
    src, dst = _edge_list(g)
    n_del = k // 2
    n_rw = k // 4
    n_ins = k - n_del - n_rw
    pick = rng.choice(g.nnz, size=n_del + n_rw, replace=False)
    deletes = [[int(src[e]), int(dst[e])] for e in pick[:n_del]]
    reweights = [
        [int(src[e]), int(dst[e]), int(rng.integers(1, 256))] for e in pick[n_del:]
    ]
    keys = set((dst * g.n + src).tolist())
    inserts: list[list[int]] = []
    while len(inserts) < n_ins:
        s, d = (int(v) for v in rng.integers(0, g.n, size=2))
        key = d * g.n + s
        if s == d or key in keys:
            continue
        keys.add(key)
        inserts.append([s, d, int(rng.integers(1, 256))])
    ev = {
        "batch_size": k,
        "inserts": inserts,
        "deletes": deletes,
        "reweights": reweights,
    }
    g2, _ = g.apply_updates(
        EdgeBatch.from_ops(inserts=inserts, deletes=deletes, reweights=reweights)
    )
    return ev, g2


def _pagerank_event(g, k: int, rng) -> tuple[dict, object]:
    """Mass-conserving deletes: every touched source's surviving out-edges
    are reweighted to ``damping / outdeg_new`` so the graph stays a scaled
    column-stochastic operator (the perturbation is local, not a global
    damping change)."""
    src, dst = _edge_list(g)
    pick = rng.choice(g.nnz, size=k, replace=False)
    gone = np.zeros(g.nnz, dtype=bool)
    gone[pick] = True
    deletes = [[int(src[e]), int(dst[e])] for e in pick]
    reweights = []
    for s in np.unique(src[pick]):
        kept = np.flatnonzero((src == s) & ~gone)
        for e in kept:
            reweights.append([int(s), int(dst[e]), DAMPING / len(kept)])
    ev = {"batch_size": k, "inserts": [], "deletes": deletes, "reweights": reweights}
    g2, _ = g.apply_updates(EdgeBatch.from_ops(deletes=deletes, reweights=reweights))
    return ev, g2


def write_trace(args) -> dict:
    rng = np.random.default_rng(args.seed)
    sizes = [int(s) for s in args.batch_sizes.split(",")]
    trace = {
        "meta": {
            "graph": args.graph,
            "scale": args.scale,
            "efactor": args.efactor,
            "graph_seed": args.graph_seed,
            "seed": args.seed,
            "delta": args.delta,
            "workers": args.workers,
            "small_frac": args.small_frac,
        },
        "workloads": {},
    }
    for wname, kind in (("sssp", "sssp"), ("pagerank", "pagerank")):
        g = make_graph(
            args.graph,
            scale=args.scale,
            efactor=args.efactor,
            kind=kind,
            seed=args.graph_seed,
        )
        events = []
        for size in sizes:
            for _ in range(args.events_per_size):
                make_event = _sssp_event if kind == "sssp" else _pagerank_event
                ev, g = make_event(g, size, rng)
                events.append(ev)
        trace["workloads"][wname] = {
            "kind": kind,
            # an argmax-degree source: kron graphs have isolated vertices,
            # so a fixed source id would often solve an empty problem
            "source": int(np.argmax(g.out_degree)) if kind == "sssp" else None,
            "events": events,
        }
    path = Path(args.trace)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(trace, indent=1))
    print(f"wrote {path}")
    return trace


# --------------------------------------------------------------------- #
# replay
# --------------------------------------------------------------------- #
def _batch_of(ev: dict) -> EdgeBatch:
    return EdgeBatch.from_ops(
        inserts=[tuple(t) for t in ev["inserts"]],
        deletes=[tuple(t) for t in ev["deletes"]],
        reweights=[tuple(t) for t in ev["reweights"]],
    )


def _quantiles(vals) -> dict:
    arr = np.asarray(vals, dtype=np.float64)
    return {"p50": float(np.median(arr)), "p99": float(np.quantile(arr, 0.99))}


def replay_workload(wname: str, wl: dict, meta: dict, backend: str) -> dict:
    kind = wl["kind"]
    g = make_graph(
        meta["graph"],
        scale=meta["scale"],
        efactor=meta["efactor"],
        kind=kind,
        seed=meta["graph_seed"],
    )
    nnz0 = g.nnz
    if kind == "sssp":
        problem = sssp_problem(source=int(wl["source"]))
    else:
        problem = pagerank_problem(damping=DAMPING)
    mk = lambda: Solver(  # noqa: E731
        g, problem, n_workers=meta["workers"], delta=meta["delta"], backend=backend
    )
    inc, cold = mk(), mk()
    r0 = inc.solve()
    c0 = cold.solve()
    rows = []
    for ev in wl["events"]:
        batch = _batch_of(ev)
        t0 = time.perf_counter()
        ri = inc.resolve(updates=batch)
        inc_wall = time.perf_counter() - t0
        t0 = time.perf_counter()
        cold.apply_updates(batch)
        rc = cold.solve()
        cold_wall = time.perf_counter() - t0
        xi, xc = np.asarray(ri.x), np.asarray(rc.x)
        if kind == "sssp":
            match = bool(np.array_equal(xi, xc))
        else:
            # each run stops at L1 residual ≤ tol, i.e. within tol/(1-d) of
            # the fixed point — two converged states differ by ≤ 2·tol/(1-d)
            match = bool(np.abs(xi - xc).sum() <= 2 * problem.tol / (1 - DAMPING))
        ops = batch.size
        rows.append(
            {
                "batch_size": ev["batch_size"],
                "ops": ops,
                "small": ops <= meta["small_frac"] * nnz0,
                "affected_rows": int(inc._last_report.affected_rows.size),
                "inc_rounds": int(ri.rounds),
                "cold_rounds": int(rc.rounds),
                "match": match,
                "inc_wall_s": inc_wall,
                "cold_wall_s": cold_wall,
            }
        )
    by_size: dict[str, dict] = {}
    for size in sorted({r["batch_size"] for r in rows}):
        sub = [r for r in rows if r["batch_size"] == size]
        by_size[str(size)] = {
            "events": len(sub),
            "inc_rounds": _quantiles([r["inc_rounds"] for r in sub]),
            "cold_rounds": _quantiles([r["cold_rounds"] for r in sub]),
            "inc_wall_s": _quantiles([r["inc_wall_s"] for r in sub]),
            "cold_wall_s": _quantiles([r["cold_wall_s"] for r in sub]),
        }
    small = [r for r in rows if r["small"]]
    inc_p50 = float(np.median([r["inc_rounds"] for r in small]))
    cold_p50 = float(np.median([r["cold_rounds"] for r in small]))
    print(
        f"{wname}: n={g.n} nnz={nnz0} cold0={c0.rounds}r  "
        f"small-batch p50 inc={inc_p50:.1f}r cold={cold_p50:.1f}r  "
        f"matches={sum(r['match'] for r in rows)}/{len(rows)}"
    )
    return {
        "n": g.n,
        "edges": nnz0,
        "initial_cold_rounds": int(c0.rounds),
        "initial_inc_solver_rounds": int(r0.rounds),
        "events": rows,
        "by_batch_size": by_size,
        "small_batch_inc_rounds_p50": inc_p50,
        "small_batch_cold_rounds_p50": cold_p50,
        "all_match": all(r["match"] for r in rows),
        "beats_cold": inc_p50 < cold_p50,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default=str(TRACES))
    ap.add_argument("--out", default=str(RESULTS / "incremental.json"))
    ap.add_argument("--backend", default="jit", choices=["jit", "host", "sharded"])
    ap.add_argument("--write-trace", action="store_true")
    ap.add_argument("--graph", default="kron")
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--efactor", type=int, default=8)
    ap.add_argument("--graph-seed", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--delta", type=int, default=128)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--batch-sizes", default="4,16,64")
    ap.add_argument("--events-per-size", type=int, default=5)
    ap.add_argument(
        "--small-frac",
        type=float,
        default=0.01,
        help="events with total ops ≤ this fraction of the initial edge "
        "count define the small-batch win condition",
    )
    ap.add_argument(
        "--assert-gate",
        action="store_true",
        help="fail (exit 1) unless every workload matched the cold solve "
        "and beat it on small-batch median rounds (the CI gate)",
    )
    args = ap.parse_args(argv)

    if args.write_trace:
        trace = write_trace(args)
    else:
        trace = json.loads(Path(args.trace).read_text())

    meta = trace["meta"]
    report = {"trace": Path(args.trace).name, "meta": meta, "workloads": {}}
    for wname, wl in trace["workloads"].items():
        report["workloads"][wname] = replay_workload(wname, wl, meta, args.backend)
    report["gate"] = {
        "all_match": all(w["all_match"] for w in report["workloads"].values()),
        "incremental_beats_cold": all(
            w["beats_cold"] for w in report["workloads"].values()
        ),
    }
    write_json_atomic(args.out, report)
    print(f"wrote {args.out}  gate={report['gate']}")
    if args.assert_gate and not all(report["gate"].values()):
        raise SystemExit(f"incremental gate failed: {report['gate']}")
    return report


if __name__ == "__main__":
    main()
