"""Roofline analysis of every dry-run cell (EXPERIMENTS.md §Roofline).

Three terms per (arch × shape × mesh), all in seconds **per device**:

    compute    = FLOPs / (chips × 197 TFLOP/s bf16)
    memory     = HBM bytes / (chips × 819 GB/s)
    collective = collective bytes / (chips × 50 GB/s per ICI link)

FLOPs and HBM bytes come from the trip-count-aware analytic model
(``model_costs.py`` — XLA's ``cost_analysis()`` counts loop bodies once, see
§Dry-run), collective bytes from the compiled HLO's collective ops (parsed by
``dryrun.py``).  MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D
(inference); the ratio MODEL_FLOPS/FLOPs exposes remat & masking waste.

Run::

    PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--csv out.csv]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.model_costs import cell_cost
from repro.configs import ALIASES, get_config
from repro.configs.shapes import SHAPES

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e)
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def analyze(rec: dict) -> dict:
    rec = dict(rec, arch=ALIASES[rec["arch"]])  # normalize id forms
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = rec["n_devices"]
    accum = 8 if cfg.param_count() > 60e9 else (2 if cfg.param_count() > 9e9 else 1)
    cost = cell_cost(cfg, shape, accum=accum)

    compute_s = cost.flops / (chips * PEAK_FLOPS)
    memory_s = cost.hbm_bytes / (chips * HBM_BW)
    # HLO collective bytes are whole-program (all devices): per-device share
    coll_bytes = rec["collectives"]["total_bytes"]
    collective_s = coll_bytes / (chips * ICI_BW)

    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    # roofline fraction: useful compute time / dominant-term time
    useful_s = cost.model_flops / (chips * PEAK_FLOPS)
    frac = useful_s / bound_s if bound_s > 0 else 0.0
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": cost.model_flops,
        "hlo_flops": cost.flops,
        "useful_ratio": cost.model_flops / cost.flops if cost.flops else 0.0,
        "roofline_frac": frac,
        "bytes_per_device_gib": rec["bytes_per_device"] / 2**30,
        "hlo_reported_flops": rec["cost"].get("flops", 0.0),
        "notes": cost.notes,
    }


def improvement_hint(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.4:
            return "cut remat/mask waste (banded attention, selective remat)"
        return "already compute-bound near useful flops — raise MXU occupancy"
    if d == "memory":
        return "fuse/shard cache reads; bigger per-chip batch amortizes weight streaming"
    return "fewer/larger flushes: raise δ, overlap collective with compute"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--csv", default=None)
    args = ap.parse_args(argv)

    rows = []
    for f in sorted(RESULTS.glob("*.json")):
        if f.name.startswith("FAILED"):
            continue
        rec = json.loads(f.read_text())
        if args.mesh != "both" and rec["mesh"] != args.mesh:
            continue
        rows.append(analyze(rec))

    hdr = (
        f"{'arch':22s} {'shape':12s} {'mesh':6s} "
        f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
        f"{'dominant':>10s} {'useful':>7s} {'roofline':>9s}"
    )
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        print(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
            f"{r['compute_s']:10.2e} {r['memory_s']:10.2e} {r['collective_s']:10.2e} "
            f"{r['dominant']:>10s} {r['useful_ratio']:7.2f} {r['roofline_frac']:9.2f}"
        )
    if args.csv:
        import csv as _csv

        with open(args.csv, "w", newline="") as fh:
            w = _csv.DictWriter(fh, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
        print(f"wrote {args.csv}")
    return rows


if __name__ == "__main__":
    main()
