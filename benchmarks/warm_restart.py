"""Cold vs warm restart: what the persistent solver cache buys a new process.

Simulates the ``serve_graph`` restart regime in one process: a *cold* Solver
pointed at an empty ``cache_dir`` pays stripe builds, the δ="auto" probes,
and trace+compile; a second, fresh Solver pointed at the same directory (a
restarted process, as far as the cache is concerned) must construct warm —
zero stripe builds, zero probe solves, zero retraces — and produce a
**bit-identical** fixed point.  Counters are asserted here and in
``tests/test_persist.py``; the same round trip gates CI via
``serve_graph --assert-warm``.

    PYTHONPATH=src python -m benchmarks.warm_restart [--scale 12]
"""

from __future__ import annotations

import argparse
import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import write_json_atomic
from repro.graphs.generators import make_graph
from repro.solve import Solver, pagerank_problem, sssp_problem

RESULTS = Path(__file__).resolve().parents[1] / "results"


def one_restart(graph, problem, cache_dir, n_workers: int) -> dict:
    t0 = time.perf_counter()
    cold = Solver(
        graph, problem, n_workers=n_workers, delta="auto", cache_dir=cache_dir
    )
    r_cold = cold.solve()
    cold_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    warm = Solver(
        graph, problem, n_workers=n_workers, delta="auto", cache_dir=cache_dir
    )
    r_warm = warm.solve()
    warm_s = time.perf_counter() - t0

    assert warm.stats["schedule_builds"] == 0, warm.stats
    assert warm.stats["traces"] == 0, warm.stats
    return {
        "problem": problem.name,
        "delta_star": cold.resolve_delta("auto"),
        "rounds": r_cold.rounds,
        "bit_identical": bool(np.array_equal(r_cold.x, r_warm.x)),
        "cold_first_solve_s": cold_s,
        "warm_first_solve_s": warm_s,
        # "time" in the name keeps the regression guard's wall-clock skip
        # rule matching this ratio of two wall-clock measurements
        "wall_time_speedup": cold_s / warm_s if warm_s > 0 else float("inf"),
        # deterministic counters (the regression guard checks these; the
        # wall-clock fields above are skipped by name)
        "cold_schedule_builds": cold.stats["schedule_builds"],
        "cold_traces": cold.stats["traces"],
        "warm_schedule_builds": warm.stats["schedule_builds"],
        "warm_traces": warm.stats["traces"],
        "warm_cache_loads": warm.stats["cache_loads"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=12, help="log2 vertices")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument(
        "--cache-dir",
        default=None,
        help="reuse a cache directory (default: fresh tempdir, removed after)",
    )
    args = ap.parse_args(argv)

    cache_dir = args.cache_dir or tempfile.mkdtemp(prefix="repro-warm-restart-")
    rows = []
    try:
        problems = [(pagerank_problem(), "pagerank"), (sssp_problem(), "sssp")]
        for problem, kind in problems:
            g = make_graph("kron", scale=args.scale, efactor=8, kind=kind)
            row = one_restart(g, problem, cache_dir, args.workers)
            rows.append(row)
            print(
                f"{row['problem']:9s} δ*={row['delta_star']:5d} "
                f"cold={row['cold_first_solve_s'] * 1e3:8.1f} ms "
                f"warm={row['warm_first_solve_s'] * 1e3:8.1f} ms "
                f"({row['wall_time_speedup']:.1f}x, warm builds="
                f"{row['warm_schedule_builds']}, warm traces={row['warm_traces']}, "
                f"bit-identical={row['bit_identical']})"
            )
    finally:
        if args.cache_dir is None:
            shutil.rmtree(cache_dir, ignore_errors=True)
    write_json_atomic(RESULTS / "warm_restart.json", rows)
    return rows


if __name__ == "__main__":
    main()
